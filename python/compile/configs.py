"""Model configurations for the FLYING SERVING reproduction.

Three tiny analogs of the paper's evaluation models (§6.1.2), chosen to keep
the *architectural stressors* the paper picked each model for:

  * ``llama-tiny``   — dense GQA transformer (analog of Llama-3-70B): stresses
    compute + all-reduce volume under TP.
  * ``moe-tiny``     — top-2 Mixture-of-Experts FFN (analog of GPT-OSS-120B):
    stresses routing and per-expert sharding.
  * ``longctx-tiny`` — small-width, long-context dense model (analog of
    Nemotron-8B 1M-token): stresses KV-cache capacity, the Use-Case-3 regime.

All shapes are static (AOT): decode batch ``B_DEC`` padded slots, prefill
chunk ``C_PREFILL`` tokens (chunked prefill), per-layer KV pool of ``n_blocks``
physical blocks of ``block_base`` tokens in DP mode.  Under TP degree ``p``
the same pool bytes are reinterpreted with block capacity ``p * block_base``
and local KV width ``(n_kv_heads/p) * d_head`` — the paper's Eq. (2)/(3).
"""

from dataclasses import dataclass, field
from typing import List, Optional

# Static serving shapes shared by all artifacts.
B_DEC = 8  # decode batch slots per engine step (padded; block 0 is trash)
C_PREFILL = 64  # chunked-prefill chunk size in tokens
TP_DEGREES = (1, 2, 4)  # supported TP widths (powers of two, paper §4.3)

VOCAB = 258  # byte-level: 256 bytes + BOS(256) + EOS(257)
BOS, EOS = 256, 257


@dataclass(frozen=True)
class ModelCfg:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    ffn_hidden: int  # dense FFN hidden size (per expert for MoE)
    n_blocks: int  # physical KV blocks per engine per layer
    block_base: int  # tokens per block in DP mode (B_base)
    max_ctx: int  # max context length reachable at the widest TP degree
    rope_theta: float = 10000.0
    vocab: int = VOCAB
    n_experts: int = 0  # 0 => dense FFN
    top_k: int = 0
    rms_eps: float = 1e-5

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def qkv_dims(self):
        return (
            self.n_heads * self.d_head,
            self.n_kv_heads * self.d_head,
            self.n_kv_heads * self.d_head,
        )

    def kv_width(self, p: int) -> int:
        """Per-device KV hidden width D_local(p) (paper §4.2.1)."""
        assert self.n_kv_heads % p == 0
        return (self.n_kv_heads // p) * self.d_head

    def block_tokens(self, p: int) -> int:
        """Adaptive block token capacity B(p) = p * B_base (paper Eq. 3)."""
        return p * self.block_base

    def pool_elems(self) -> int:
        """Flat f32 element count of one (K or V) per-layer pool.

        Invariant across modes: n_blocks * B(p) * kv_width(p) is constant
        (paper Eq. 2 with M_block fixed).
        """
        return self.n_blocks * self.block_base * self.n_kv_heads * self.d_head

    def max_blocks_per_seq(self, p: int) -> int:
        """Static block-table width at degree p (full pool to one request)."""
        return self.n_blocks

    def dp_token_capacity(self) -> int:
        """Tokens one engine can cache for a single request in DP mode."""
        return self.n_blocks * self.block_base

    def tp_token_capacity(self, p: int) -> int:
        """Tokens a p-way TP group can cache for one request (Use Case 3)."""
        return self.n_blocks * self.block_tokens(p)

    def weight_names(self) -> List[str]:
        """Ordered tensor names; defines the *_weights.bin layout."""
        names = ["emb", "final_norm", "lm_head"]
        for layer in range(self.n_layers):
            names += [f"l{layer}.{n}" for n in ("attn_norm", "wq", "wk", "wv", "wo", "ffn_norm")]
            if self.is_moe:
                names += [f"l{layer}.{n}" for n in ("router", "wg", "wu", "wd")]
            else:
                names += [f"l{layer}.{n}" for n in ("wg", "wu", "wd")]
        return names

    def weight_shape(self, name: str):
        d, dh, hq, hkv, f = self.d_model, self.d_head, self.n_heads, self.n_kv_heads, self.ffn_hidden
        base = name.split(".")[-1]
        shapes = {
            "emb": (self.vocab, d),
            "final_norm": (d,),
            "lm_head": (d, self.vocab),
            "attn_norm": (d,),
            "wq": (d, hq * dh),
            "wk": (d, hkv * dh),
            "wv": (d, hkv * dh),
            "wo": (hq * dh, d),
            "ffn_norm": (d,),
        }
        if self.is_moe:
            shapes.update(
                router=(d, self.n_experts),
                wg=(self.n_experts, d, f),
                wu=(self.n_experts, d, f),
                wd=(self.n_experts, f, d),
            )
        else:
            shapes.update(wg=(d, f), wu=(d, f), wd=(f, d))
        return shapes[base]


LLAMA_TINY = ModelCfg(
    name="llama-tiny",
    d_model=256,
    n_layers=4,
    n_heads=8,
    n_kv_heads=4,
    d_head=32,
    ffn_hidden=512,
    n_blocks=128,
    block_base=8,
    max_ctx=4096,  # = tp_token_capacity(4)
)

MOE_TINY = ModelCfg(
    name="moe-tiny",
    d_model=256,
    n_layers=4,
    n_heads=8,
    n_kv_heads=4,
    d_head=32,
    ffn_hidden=256,
    n_experts=4,
    top_k=2,
    n_blocks=128,
    block_base=8,
    max_ctx=4096,
)

LONGCTX_TINY = ModelCfg(
    name="longctx-tiny",
    d_model=128,
    n_layers=2,
    n_heads=4,
    n_kv_heads=4,
    d_head=32,
    ffn_hidden=256,
    n_blocks=256,
    block_base=8,
    max_ctx=8192,  # = tp_token_capacity(4)
)

MODELS = {m.name: m for m in (LLAMA_TINY, MOE_TINY, LONGCTX_TINY)}
