"""Pallas paged-attention decode kernel (flash-decoding over a block pool).

This is the serving hot spot: one decode step attends over a request's KV
state stored in non-contiguous fixed-size physical blocks (vLLM
PagedAttention), indexed through a block table maintained by the Rust-side
KV Cache Adaptor.

The *same* kernel source serves every parallelism mode: the pool ref arrives
already reshaped to the mode's logical layout
``[n_blocks * B(p), Hkv/p, dh]`` where ``B(p) = p * B_base`` — the paper's
adaptive block sizing (Eq. 2/3).  Physical bytes are identical across modes;
only the static shape baked into each AOT artifact differs.

Grid: one program per batch slot.  Inside, an online-softmax (flash) loop
streams KV blocks via the block table; invalid tail blocks and padded batch
slots are masked by position (padded slots carry seq_len = 0 and their table
rows point at the reserved trash block 0, so reads are always in-bounds).

Hardware adaptation: on TPU the block loop is the HBM->VMEM pipeline
(BlockSpec would double-buffer `bt x dh` tiles); under interpret=True the
loop lowers to an XLA while-loop on the CPU backend.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


def _kernel(
    q_ref,  # [B, Hq, dh]
    kp_ref,  # [n_slots, Hkv, dh]
    vp_ref,  # [n_slots, Hkv, dh]
    bt_ref,  # [B, max_blocks] i32
    sl_ref,  # [B] i32 (valid tokens incl. current; 0 => padded slot)
    o_ref,  # [B, Hq, dh]
    *,
    block_tokens: int,
    max_blocks: int,
):
    i = pl.program_id(0)
    q = q_ref[i]  # [Hq, dh]
    hq, dh = q.shape
    hkv = kp_ref.shape[1]
    group = hq // hkv
    seq_len = sl_ref[i]
    scale = 1.0 / (dh**0.5)

    def body(b, carry):
        m, l, acc = carry  # [Hq,1], [Hq,1], [Hq,dh]
        blk = bt_ref[i, b]
        k = kp_ref[pl.dslice(blk * block_tokens, block_tokens)]  # [bt,Hkv,dh]
        v = vp_ref[pl.dslice(blk * block_tokens, block_tokens)]
        # GQA: repeat each kv head over its query-head group.
        k = jnp.repeat(k, group, axis=1)  # [bt, Hq, dh]
        v = jnp.repeat(v, group, axis=1)
        s = jnp.einsum("hd,thd->ht", q, k) * scale  # [Hq, bt]
        pos = b * block_tokens + jnp.arange(block_tokens)  # global positions
        valid = (pos < seq_len)[None, :]  # [1, bt]
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        # Guard: for fully-masked rows s - m_new is 0 - 0; force p to 0.
        p_ = jnp.where(valid, jnp.exp(s - m_new), 0.0)  # [Hq, bt]
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p_, axis=1, keepdims=True)
        acc_new = alpha * acc + jnp.einsum("ht,thd->hd", p_, v)
        return m_new, l_new, acc_new

    m0 = jnp.full((hq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((hq, 1), jnp.float32)
    a0 = jnp.zeros((hq, dh), jnp.float32)
    n_blocks_used = (seq_len + block_tokens - 1) // block_tokens
    # Static trip count (AOT shape) with per-iteration masking; blocks past
    # n_blocks_used contribute nothing but still execute.  The fori upper
    # bound is dynamic where supported to skip dead tail blocks.
    m, l, acc = jax.lax.fori_loop(0, n_blocks_used, body, (m0, l0, a0))
    out = jnp.where(l > 0.0, acc / jnp.where(l > 0.0, l, 1.0), 0.0)
    o_ref[i] = out


def paged_attention(q, k_pool, v_pool, block_table, seq_lens, block_tokens: int):
    """Decode attention over the paged pool.

    q:             [B, Hq_local, dh]
    k_pool/v_pool: [n_slots, Hkv_local, dh], n_slots = n_blocks * block_tokens
    block_table:   [B, max_blocks] i32
    seq_lens:      [B] i32
    Returns [B, Hq_local, dh].
    """
    b = q.shape[0]
    max_blocks = block_table.shape[1]
    kern = functools.partial(_kernel, block_tokens=block_tokens, max_blocks=max_blocks)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=(b,),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, k_pool, v_pool, block_table, seq_lens)
