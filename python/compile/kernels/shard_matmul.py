"""Pallas shard-view matmul — the kernel-level form of the paper's
Model Weights Manager (§4.1).

The kernel input is always the FULL weight matrix; the active TP shard is a
*window* selected inside the kernel from the runtime ``rank`` scalar:

    W_active^(r) = View(W_full, dim, r, p)        (paper Eq. 1)

No sliced copy of the weight is ever materialized at the HLO level: the
operand is the full (loaded-once) matrix, and the kernel reads only the
``1/p`` window it needs.  This mirrors vLLM's ``linear.py`` patch (a
``narrow()`` view over the CUDA tensor) in TPU terms: on real hardware the
window is what BlockSpec stages HBM->VMEM, so deactivated columns never move.

Hardware adaptation note (DESIGN.md §Hardware-Adaptation): on a real TPU this
kernel would use ``PrefetchScalarGridSpec`` so the rank scalar feeds the
``index_map`` and the MXU consumes aligned (128x128) bf16 tiles of the
window.  Under ``interpret=True`` (mandatory for CPU PJRT execution) we
express the same access pattern with ``pl.dslice`` on the weight ref.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COL, ROW = 1, 0  # shard dimensions (Megatron column-/row-parallel)


def _kernel_col(x_ref, w_ref, rank_ref, o_ref, *, shard_n: int):
    """Column-parallel: activate output-column window [rank*shard_n, +shard_n)."""
    r = rank_ref[0]
    w = w_ref[:, pl.dslice(r * shard_n, shard_n)]  # zero-copy window
    o_ref[...] = x_ref[...] @ w


def _kernel_row(x_ref, w_ref, rank_ref, o_ref, *, shard_k: int):
    """Row-parallel: activate input-row window; x is the local [T, K/p] slice.

    Produces a *partial* [T, N] result that the coordinator all-reduces
    across the TP group (paper §4.1.1, one sync per pair of linear layers).
    """
    r = rank_ref[0]
    w = w_ref[pl.dslice(r * shard_k, shard_k), :]
    o_ref[...] = x_ref[...] @ w


def shard_matmul(x, w_full, rank, p: int, shard_dim: int):
    """x @ View(w_full, shard_dim, rank, p), as a Pallas call.

    x:      [T, K]  (shard_dim=COL)  or  [T, K/p]  (shard_dim=ROW)
    w_full: [K, N]  — the full, loaded-once matrix
    rank:   i32[1]  — runtime TP rank of this engine
    Returns [T, N/p] (COL) or partial [T, N] (ROW).
    """
    t = x.shape[0]
    k_full, n_full = w_full.shape
    if shard_dim == COL:
        assert n_full % p == 0
        shard_n = n_full // p
        out_shape = jax.ShapeDtypeStruct((t, shard_n), x.dtype)
        kern = functools.partial(_kernel_col, shard_n=shard_n)
    else:
        assert k_full % p == 0
        shard_k = k_full // p
        assert x.shape[1] == shard_k, (x.shape, w_full.shape, p)
        out_shape = jax.ShapeDtypeStruct((t, n_full), x.dtype)
        kern = functools.partial(_kernel_row, shard_k=shard_k)
    return pl.pallas_call(
        kern,
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w_full, rank)
