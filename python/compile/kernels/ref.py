"""Pure-jnp reference oracles for the Pallas kernels.

Everything here is deliberately naive and obviously-correct; the pytest suite
asserts the Pallas kernels (and the sharded model composition) against these.
"""

import jax
import jax.numpy as jnp
import numpy as np


def rmsnorm_ref(x, w, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(var + eps)) * w


def shard_matmul_ref(x, w_full, rank, p, shard_dim):
    """x @ W_active where W_active is rank r's 1/p window of w_full.

    shard_dim=1 (column-parallel): slice output columns -> [*, N/p].
    shard_dim=0 (row-parallel): slice input rows; x is already the local
    [*, K/p] activation slice -> partial [*, N] to be all-reduced.
    """
    if shard_dim == 1:
        n = w_full.shape[1] // p
        w = w_full[:, rank * n : (rank + 1) * n]
        return x @ w
    else:
        k = w_full.shape[0] // p
        w = w_full[rank * k : (rank + 1) * k, :]
        return x @ w


def rope_ref(x, positions, theta=10000.0):
    """Rotary embedding; x: [T, H, dh], positions: [T] int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [T, half]
    cos, sin = jnp.cos(ang)[:, None, :], jnp.sin(ang)[:, None, :]  # [T,1,half]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def paged_attention_ref(q, k_pool, v_pool, block_table, seq_lens, block_tokens):
    """Decode attention oracle over a paged KV pool.

    q:             [B, Hq_local, dh]
    k_pool/v_pool: [n_slots, Hkv_local, dh] (n_slots = n_blocks * block_tokens)
    block_table:   [B, max_blocks] int32 physical block ids
    seq_lens:      [B] int32 valid tokens per request (including the current
                   token, whose k/v must already be in the pool); 0 => padded
                   slot, output is zeros.
    Returns [B, Hq_local, dh].
    """
    b, hq, dh = q.shape
    hkv = k_pool.shape[1]
    group = hq // hkv
    scale = 1.0 / np.sqrt(dh)
    outs = []
    for i in range(b):
        t = int(seq_lens[i])
        if t == 0:
            outs.append(jnp.zeros((hq, dh), jnp.float32))
            continue
        slots = []
        for tok in range(t):
            blk = int(block_table[i, tok // block_tokens])
            slots.append(blk * block_tokens + tok % block_tokens)
        slots = jnp.array(slots, dtype=jnp.int32)
        k = k_pool[slots]  # [t, hkv, dh]
        v = v_pool[slots]
        head_outs = []
        for h in range(hq):
            kv_h = h // group
            s = (q[i, h] @ k[:, kv_h, :].T) * scale  # [t]
            a = jnp.exp(s - jnp.max(s))
            a = a / jnp.sum(a)
            head_outs.append(a @ v[:, kv_h, :])
        outs.append(jnp.stack(head_outs))
    return jnp.stack(outs)


def prefill_attention_ref(q, k, v, start):
    """Causal prefill over contiguous kv (history + chunk concatenated).

    q: [C, Hq, dh] queries for absolute positions start..start+C-1
    k/v: [T, Hkv, dh] cached tokens 0..T-1 (T >= start + C)
    """
    c, hq, dh = q.shape
    t, hkv, _ = k.shape
    group = hq // hkv
    scale = 1.0 / np.sqrt(dh)
    pos_q = np.arange(c) + start
    pos_k = np.arange(t)
    mask = pos_k[None, :] <= pos_q[:, None]  # [C, T]
    outs = []
    for h in range(hq):
        kv_h = h // group
        s = (q[:, h, :] @ k[:, kv_h, :].T) * scale  # [C, T]
        s = jnp.where(mask, s, -1e30)
        a = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
        a = a / jnp.sum(a, axis=-1, keepdims=True)
        outs.append(a @ v[:, kv_h, :])
    return jnp.stack(outs, axis=1)  # [C, Hq, dh]


def ffn_ref(x, wg, wu, wd):
    """Gated-SiLU FFN, unsharded."""
    g = x @ wg
    u = x @ wu
    return (g * (1.0 / (1.0 + jnp.exp(-g))) * u) @ wd


def moe_ffn_ref(x, router, wg, wu, wd, top_k):
    """Top-k MoE FFN oracle: dense per-expert evaluation + gated mixture."""
    logits = x @ router  # [T, E]
    n_experts = logits.shape[-1]
    top_vals, top_idx = jax.lax.top_k(logits, top_k)
    gate = jax.nn.softmax(top_vals, axis=-1)  # softmax over selected experts
    expert_outs = jnp.stack(
        [ffn_ref(x, wg[e], wu[e], wd[e]) for e in range(n_experts)]
    )  # [E, T, D]
    out = jnp.zeros_like(x)
    for j in range(top_k):
        sel = jnp.take_along_axis(expert_outs, top_idx[:, j][None, :, None], axis=0)[0]
        out = out + gate[:, j][:, None] * sel
    return out


def model_forward_ref(cfg, weights, tokens):
    """Full unsharded forward with contiguous KV — ground truth for the
    paged/sharded serving path.  tokens: np [T] -> logits [T, V]."""
    t = len(tokens)
    positions = jnp.arange(t, dtype=jnp.int32)
    x = jnp.asarray(weights["emb"])[jnp.asarray(tokens, jnp.int32)]
    for layer in range(cfg.n_layers):
        lw = {k.split(".", 1)[1]: jnp.asarray(v) for k, v in weights.items() if k.startswith(f"l{layer}.")}
        xn = rmsnorm_ref(x, lw["attn_norm"], cfg.rms_eps)
        q = (xn @ lw["wq"]).reshape(t, cfg.n_heads, cfg.d_head)
        k = (xn @ lw["wk"]).reshape(t, cfg.n_kv_heads, cfg.d_head)
        v = (xn @ lw["wv"]).reshape(t, cfg.n_kv_heads, cfg.d_head)
        q = rope_ref(q, positions, cfg.rope_theta)
        k = rope_ref(k, positions, cfg.rope_theta)
        o = prefill_attention_ref(q, k, v, 0)  # causal full attention
        x = x + o.reshape(t, -1) @ lw["wo"]
        xn2 = rmsnorm_ref(x, lw["ffn_norm"], cfg.rms_eps)
        if cfg.is_moe:
            x = x + moe_ffn_ref(xn2, lw["router"], lw["wg"], lw["wu"], lw["wd"], cfg.top_k)
        else:
            x = x + ffn_ref(xn2, lw["wg"], lw["wu"], lw["wd"])
    xn = rmsnorm_ref(x, jnp.asarray(weights["final_norm"]), cfg.rms_eps)
    return xn @ jnp.asarray(weights["lm_head"])
