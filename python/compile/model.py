"""L2 — the sharded transformer forward, built on the L1 kernels.

Every function here is *rank-parameterized*: it receives the FULL weight
tensors plus a runtime ``rank`` scalar, and computes exactly the work of one
TP shard by activating kernel-level views (see kernels/shard_matmul.py).
Cross-rank synchronization (the two all-reduces per layer) happens OUTSIDE
these functions, in the Rust coordinator's communicator pool — the artifacts
return *partial* activations, which is what makes one kernel source serve
every (p, rank) combination.

Shapes are AOT-static per artifact; the KV pool always enters and leaves as
a FLAT f32 vector so the same physical PJRT buffer can be consumed by any
parallelism mode (the paper's KV Cache Adaptor invariant: bytes fixed,
interpretation per-mode).
"""

import jax
import jax.numpy as jnp

from .configs import ModelCfg
from .kernels.shard_matmul import shard_matmul, COL, ROW
from .kernels.paged_attention import paged_attention


def rmsnorm(x, w, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(var + eps)) * w


def rope(x, positions, theta):
    """x: [T, H, dh], positions: [T] i32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    cos, sin = jnp.cos(ang)[:, None, :], jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def pool_view(cfg: ModelCfg, pool_flat, p: int):
    """Reinterpret the flat pool under TP degree p: [n_slots, Hkv/p, dh].

    Pure reshape — the physical buffer is never copied or moved; this is the
    paper's constant-time logical re-interpretation (§4.2.2).
    """
    bt = cfg.block_tokens(p)
    n_slots = cfg.n_blocks * bt
    return pool_flat.reshape(n_slots, cfg.n_kv_heads // p, cfg.d_head)


def kv_append(cfg: ModelCfg, pool_flat, new, slot_ids, p: int):
    """Scatter [T, Hkv/p, dh] new entries at flat slot ids; returns flat pool.

    Padded tokens carry slot ids inside the reserved trash block 0, so the
    scatter needs no conditionals.
    """
    v = pool_view(cfg, pool_flat, p)
    v = v.at[slot_ids].set(new)
    return v.reshape(-1)


def attn_shard(
    cfg: ModelCfg,
    p: int,
    rank,  # i32[1]
    x,  # [T, D] residual-stream input (replicated across ranks)
    attn_norm,
    wq,
    wk,
    wv,
    wo,  # FULL weights
    k_pool,
    v_pool,  # flat f32 pools
    slot_ids,  # [T] i32 flat write slots (computed by the Rust adaptor)
    positions,  # [T] i32 absolute token positions (0 for padded slots)
    *,
    decode_block_table=None,  # [B, max_blocks] i32 (decode only)
    decode_seq_lens=None,  # [B] i32 (decode only)
    prefill_block_table=None,  # [max_blocks] i32 (prefill only)
    prefill_start=None,  # i32[1] (prefill only)
    prefill_seq_len=None,  # i32[1] total tokens incl. this chunk (prefill)
):
    """One attention sub-layer for TP rank ``rank`` of degree ``p``.

    Returns (partial_out [T, D], k_new [T, Hkv/p * dh], v_new [T, ...]).
    partial_out must be all-reduced across the TP group before the residual
    add.  The *pools are input-only*: the kernel scatters the new k/v
    internally for its own attention read, but returns just the new rows —
    the Rust KV Cache Adaptor performs the authoritative host-side scatter at
    the slot ids it computed (the PJRT C API returns results as one fused
    tuple literal, so returning whole pools would force a full pool copy
    D2H+H2D per step; see DESIGN.md §Perf).
    """
    t = x.shape[0]
    hq_l = cfg.n_heads // p
    hkv_l = cfg.n_kv_heads // p
    dh = cfg.d_head

    xn = rmsnorm(x, attn_norm, cfg.rms_eps)
    q = shard_matmul(xn, wq, rank, p, COL).reshape(t, hq_l, dh)
    k = shard_matmul(xn, wk, rank, p, COL).reshape(t, hkv_l, dh)
    v = shard_matmul(xn, wv, rank, p, COL).reshape(t, hkv_l, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    k_pool = kv_append(cfg, k_pool, k, slot_ids, p)
    v_pool = kv_append(cfg, v_pool, v, slot_ids, p)

    if decode_block_table is not None:
        o = paged_attention(
            q,
            pool_view(cfg, k_pool, p),
            pool_view(cfg, v_pool, p),
            decode_block_table,
            decode_seq_lens,
            cfg.block_tokens(p),
        )  # [B, hq_l, dh]
    else:
        o = _prefill_attention(
            cfg, p, q, k_pool, v_pool, prefill_block_table, prefill_start, prefill_seq_len
        )

    partial = shard_matmul(o.reshape(t, hq_l * dh), wo, rank, p, ROW)  # [T, D]
    return partial, k.reshape(t, hkv_l * dh), v.reshape(t, hkv_l * dh)


def _prefill_attention(cfg, p, q, k_pool, v_pool, block_table, start, seq_len):
    """Chunked-prefill attention: causal over (cached history + this chunk).

    Gathers the request's logical token order from the pool via its block
    table (dense gather — prefill is compute-bound, this is the GEMM-friendly
    formulation), then masked attention.
    """
    c, hq_l, dh = q.shape
    bt = cfg.block_tokens(p)
    t_max = cfg.n_blocks * bt  # static upper bound on cached tokens
    group = hq_l // (cfg.n_kv_heads // p)

    slot_idx = (block_table[:, None] * bt + jnp.arange(bt)[None, :]).reshape(-1)  # [t_max]
    kp = pool_view(cfg, k_pool, p)[slot_idx]  # [t_max, hkv_l, dh]
    vp = pool_view(cfg, v_pool, p)[slot_idx]
    kp = jnp.repeat(kp, group, axis=1)  # [t_max, hq_l, dh]
    vp = jnp.repeat(vp, group, axis=1)

    pos_q = start[0] + jnp.arange(c)  # absolute query positions
    pos_k = jnp.arange(t_max)
    mask = (pos_k[None, :] <= pos_q[:, None]) & (pos_k[None, :] < seq_len[0])  # [C, t_max]

    scale = 1.0 / (dh**0.5)
    s = jnp.einsum("chd,thd->cht", q, kp) * scale  # [C, hq_l, t_max]
    s = jnp.where(mask[:, None, :], s, -1.0e30)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    a = jnp.exp(s)
    a = a / jnp.sum(a, axis=-1, keepdims=True)
    return jnp.einsum("cht,thd->chd", a, vp)  # [C, hq_l, dh]


def ffn_shard(cfg: ModelCfg, p: int, rank, x, ffn_norm, wg, wu, wd):
    """Dense gated-SiLU FFN shard; returns partial [T, D] (all-reduce next)."""
    xn = rmsnorm(x, ffn_norm, cfg.rms_eps)
    g = shard_matmul(xn, wg, rank, p, COL)
    u = shard_matmul(xn, wu, rank, p, COL)
    h = g * jax.nn.sigmoid(g) * u
    return shard_matmul(h, wd, rank, p, ROW)


def _topk_argmax(logits, k):
    """Iterative arg-max top-k.  ``jax.lax.top_k`` lowers to an HLO sort
    with a ``largest`` attribute that xla_extension 0.5.1's text parser
    rejects; k sequential argmax+mask rounds lower to plain reduces and
    parse cleanly (k is 2 here, so this is also cheap)."""
    vals, idxs = [], []
    x = logits
    for _ in range(k):
        i = jnp.argmax(x, axis=-1)  # [T]
        v = jnp.take_along_axis(x, i[:, None], axis=-1)[:, 0]
        vals.append(v)
        idxs.append(i)
        x = x - jax.nn.one_hot(i, x.shape[-1], dtype=x.dtype) * 1e30
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def moe_ffn_shard(cfg: ModelCfg, p: int, rank, x, ffn_norm, router, wg, wu, wd):
    """Top-k MoE FFN shard.

    The router is replicated (tiny), so every rank computes identical gates;
    each expert's FFN is sharded exactly like the dense case, so the partial
    mixture still sums to the full output across ranks.
    """
    xn = rmsnorm(x, ffn_norm, cfg.rms_eps)
    logits = xn @ router  # [T, E] replicated
    top_vals, top_idx = _topk_argmax(logits, cfg.top_k)
    gate = jax.nn.softmax(top_vals, axis=-1)  # [T, top_k]

    expert_partials = []
    for e in range(cfg.n_experts):
        g = shard_matmul(xn, wg[e], rank, p, COL)
        u = shard_matmul(xn, wu[e], rank, p, COL)
        h = g * jax.nn.sigmoid(g) * u
        expert_partials.append(shard_matmul(h, wd[e], rank, p, ROW))
    stacked = jnp.stack(expert_partials)  # [E, T, D]

    out = jnp.zeros_like(x)
    for j in range(cfg.top_k):
        sel = jnp.take_along_axis(stacked, top_idx[:, j][None, :, None], axis=0)[0]
        out = out + gate[:, j][:, None] * sel
    return out


def ffn_dispatch(cfg, p, rank, x, weights):
    if cfg.is_moe:
        return moe_ffn_shard(
            cfg, p, rank, x, weights["ffn_norm"], weights["router"], weights["wg"], weights["wu"], weights["wd"]
        )
    return ffn_shard(cfg, p, rank, x, weights["ffn_norm"], weights["wg"], weights["wu"], weights["wd"])


def lm_head(cfg: ModelCfg, x, final_norm, w_lm):
    """Final norm + logits projection (replicated; vocab is tiny)."""
    return rmsnorm(x, final_norm, cfg.rms_eps) @ w_lm


# ---------------------------------------------------------------------------
# Fused single-engine (DP, p=1) step functions — the common-mode fast path.
# All layers + LM head in one executable: zero host round-trips per step.
# ---------------------------------------------------------------------------


def _layer_weights(weights, layer):
    return {k.split(".", 1)[1]: v for k, v in weights.items() if k.startswith(f"l{layer}.")}


def dp_decode_step(cfg: ModelCfg, tokens, positions, seq_lens, block_tables, slot_ids, weights, pools):
    """One fused DP decode step for a padded batch.

    tokens/positions/seq_lens/slot_ids: [B] i32; block_tables: [B, n_blocks].
    pools: list of 2L flat f32 pools (k0, v0, k1, v1, ...), input-only.
    Returns (logits [B, V], k0_new, v0_new, ..., k_{L-1}_new, v_{L-1}_new)
    where each *_new is [B, Hkv*dh] — the Rust adaptor scatters them.
    """
    rank = jnp.zeros((1,), jnp.int32)
    x = weights["emb"][tokens]  # [B, D]
    new_kv = []
    for layer in range(cfg.n_layers):
        lw = _layer_weights(weights, layer)
        kp, vp = pools[2 * layer], pools[2 * layer + 1]
        partial, kn, vn = attn_shard(
            cfg, 1, rank, x,
            lw["attn_norm"], lw["wq"], lw["wk"], lw["wv"], lw["wo"],
            kp, vp, slot_ids, positions,
            decode_block_table=block_tables, decode_seq_lens=seq_lens,
        )
        x = x + partial  # p=1: partial IS the full output
        x = x + ffn_dispatch(cfg, 1, rank, x, lw)
        new_kv += [kn, vn]
    logits = lm_head(cfg, x, weights["final_norm"], weights["lm_head"])
    return (logits, *new_kv)


def dp_prefill_step(cfg: ModelCfg, tokens, positions, slot_ids, block_table, start, seq_len, weights, pools):
    """One fused DP chunked-prefill step for a single request.

    tokens/positions/slot_ids: [C] i32; block_table: [n_blocks] i32;
    start/seq_len: i32[1] (chunk's first absolute position; total tokens
    incl. this chunk).  Returns (logits [C, V], k0_new, v0_new, ...) with
    each *_new [C, Hkv*dh] for the host-side scatter.
    """
    rank = jnp.zeros((1,), jnp.int32)
    x = weights["emb"][tokens]
    new_kv = []
    for layer in range(cfg.n_layers):
        lw = _layer_weights(weights, layer)
        kp, vp = pools[2 * layer], pools[2 * layer + 1]
        partial, kn, vn = attn_shard(
            cfg, 1, rank, x,
            lw["attn_norm"], lw["wq"], lw["wk"], lw["wv"], lw["wo"],
            kp, vp, slot_ids, positions,
            prefill_block_table=block_table, prefill_start=start, prefill_seq_len=seq_len,
        )
        x = x + partial
        x = x + ffn_dispatch(cfg, 1, rank, x, lw)
        new_kv += [kn, vn]
    logits = lm_head(cfg, x, weights["final_norm"], weights["lm_head"])
    return (logits, *new_kv)


# ---------------------------------------------------------------------------
# Per-layer TP shard step functions — compiled once per (phase, p); the Rust
# coordinator chains them with all-reduces from the Communicator Pool.
# ---------------------------------------------------------------------------


def tp_attn_decode(cfg, p, x, block_tables, slot_ids, positions, seq_lens, rank,
                   attn_norm, wq, wk, wv, wo, k_pool, v_pool):
    return attn_shard(
        cfg, p, rank, x, attn_norm, wq, wk, wv, wo, k_pool, v_pool, slot_ids, positions,
        decode_block_table=block_tables, decode_seq_lens=seq_lens,
    )


def tp_attn_prefill(cfg, p, x, block_table, slot_ids, positions, start, seq_len, rank,
                    attn_norm, wq, wk, wv, wo, k_pool, v_pool):
    return attn_shard(
        cfg, p, rank, x, attn_norm, wq, wk, wv, wo, k_pool, v_pool, slot_ids, positions,
        prefill_block_table=block_table, prefill_start=start, prefill_seq_len=seq_len,
    )


def tp_ffn(cfg, p, x, rank, weights):
    """FFN shard step (phase-independent)."""
    return ffn_dispatch(cfg, p, rank, x, weights)
