"""AOT pipeline: lower every (model, phase, TP degree) step function to HLO
text, emit deterministic synthetic weights, and write a manifest that the
Rust runtime follows mechanically.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(what the published ``xla`` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Artifact surface per model (DESIGN.md §Artifacts):

  * ``{m}_dp_decode``  — fused all-layers+head decode step, p=1 (DP fast path)
  * ``{m}_dp_prefill`` — fused chunked-prefill step, p=1
  * ``{m}_attn_{phase}_tp{p}`` / ``{m}_ffn_{phase}_tp{p}`` for p in {2,4} —
    per-layer shard steps; the Rust coordinator inserts the two all-reduces
    per layer through its Communicator Pool.
  * ``{m}_lmhead_dec`` / ``{m}_lmhead_pre`` — final norm + logits (replicated)

Usage: ``python -m compile.aot --out-dir ../artifacts [--models a,b] [--force]``
"""

import argparse
import functools
import hashlib
import json
import os
import sys

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import MODELS, ModelCfg, B_DEC, C_PREFILL, TP_DEGREES
from . import model as M

F32, I32 = "f32", "i32"


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Deterministic synthetic weights
# ---------------------------------------------------------------------------


def make_weights(cfg: ModelCfg, seed: int = 1234):
    """Seeded init; norms at 1.0, projections scaled ~1/sqrt(fan_in)."""
    rng = np.random.default_rng(seed + len(cfg.name))
    out = {}
    for name in cfg.weight_names():
        shape = cfg.weight_shape(name)
        base = name.split(".")[-1]
        if base in ("attn_norm", "ffn_norm", "final_norm"):
            w = np.ones(shape, np.float32)
        elif base == "emb":
            w = rng.standard_normal(shape).astype(np.float32) * 0.02
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[0]
            w = (rng.standard_normal(shape) / np.sqrt(fan_in)).astype(np.float32)
        out[name] = w
    return out


def write_weights_bin(cfg, weights, path):
    entries, off = [], 0
    with open(path, "wb") as f:
        for name in cfg.weight_names():
            w = weights[name]
            f.write(w.astype("<f4").tobytes())
            entries.append(
                {"name": name, "shape": list(w.shape), "offset_elems": off, "n_elems": int(w.size)}
            )
            off += int(w.size)
    return entries


# ---------------------------------------------------------------------------
# Artifact specs: (callable over flat positional args, ordered arg descriptors,
#                  ordered output descriptors, donate_argnums)
# Arg kinds the Rust runtime understands:
#   dyn          — per-step host literal (tokens, tables, slots, ...)
#   weight       — concrete weight tensor, device-resident buffer (fused DP)
#   weight_role  — per-layer weight by role; Rust substitutes the layer
#   kpool/vpool  — per-layer KV pool buffer (layer index for fused; -1 = the
#                  layer currently being executed for per-layer artifacts)
# ---------------------------------------------------------------------------


def _dyn(name, shape, dtype=I32):
    return {"kind": "dyn", "name": name, "shape": list(shape), "dtype": dtype}


def _w(role):
    return {"kind": "weight", "role": role}


def _wr(role):
    return {"kind": "weight_role", "role": role}


def _kp(layer):
    return {"kind": "kpool", "layer": layer}


def _vp(layer):
    return {"kind": "vpool", "layer": layer}


def _kv_new_outs(cfg, t):
    """Output descriptors for the per-layer new-KV rows (fused artifacts)."""
    w = cfg.n_kv_heads * cfg.d_head
    out = []
    for layer in range(cfg.n_layers):
        out.append({"kind": "knew", "layer": layer, "shape": [t, w]})
        out.append({"kind": "vnew", "layer": layer, "shape": [t, w]})
    return out


def _weight_args_fused(cfg):
    return [_w(n) for n in cfg.weight_names()]


def _pool_args_fused(cfg):
    out = []
    for layer in range(cfg.n_layers):
        out += [_kp(layer), _vp(layer)]
    return out


def _layer_roles(cfg, part):
    if part == "attn":
        return ["attn_norm", "wq", "wk", "wv", "wo"]
    if cfg.is_moe:
        return ["ffn_norm", "router", "wg", "wu", "wd"]
    return ["ffn_norm", "wg", "wu", "wd"]


def build_specs(cfg: ModelCfg):
    """Return {artifact_name: (fn, args, outputs, donate)} for one model."""
    d, v, nblk = cfg.d_model, cfg.vocab, cfg.n_blocks
    pool = [cfg.pool_elems()]
    specs = {}

    # ---- fused DP decode -------------------------------------------------
    nw = len(cfg.weight_names())

    def dp_decode(tokens, positions, seq_lens, block_tables, slot_ids, *rest):
        weights = dict(zip(cfg.weight_names(), rest[:nw]))
        pools = list(rest[nw:])
        return M.dp_decode_step(cfg, tokens, positions, seq_lens, block_tables, slot_ids, weights, pools)

    args = [
        _dyn("tokens", [B_DEC]),
        _dyn("positions", [B_DEC]),
        _dyn("seq_lens", [B_DEC]),
        _dyn("block_tables", [B_DEC, nblk]),
        _dyn("slot_ids", [B_DEC]),
        *_weight_args_fused(cfg),
        *_pool_args_fused(cfg),
    ]
    outs = [{"kind": "logits", "shape": [B_DEC, v]}, *_kv_new_outs(cfg, B_DEC)]
    specs["dp_decode"] = (dp_decode, args, outs, (), {"tp": 1, "phase": "decode"})

    # ---- fused DP prefill ------------------------------------------------
    def dp_prefill(tokens, positions, slot_ids, block_table, start, seq_len, *rest):
        weights = dict(zip(cfg.weight_names(), rest[:nw]))
        pools = list(rest[nw:])
        return M.dp_prefill_step(cfg, tokens, positions, slot_ids, block_table, start, seq_len, weights, pools)

    args = [
        _dyn("tokens", [C_PREFILL]),
        _dyn("positions", [C_PREFILL]),
        _dyn("slot_ids", [C_PREFILL]),
        _dyn("block_table", [nblk]),
        _dyn("start", [1]),
        _dyn("seq_len", [1]),
        *_weight_args_fused(cfg),
        *_pool_args_fused(cfg),
    ]
    outs = [{"kind": "logits", "shape": [C_PREFILL, v]}, *_kv_new_outs(cfg, C_PREFILL)]
    specs["dp_prefill"] = (dp_prefill, args, outs, (), {"tp": 1, "phase": "prefill"})

    # ---- per-layer TP shards ----------------------------------------------
    for p in TP_DEGREES:
        if p == 1:
            continue
        if cfg.n_kv_heads % p or cfg.n_heads % p:
            continue

        def attn_dec(x, block_tables, slot_ids, positions, seq_lens, rank,
                     attn_norm, wq, wk, wv, wo, kp, vp, p=p):
            return M.tp_attn_decode(cfg, p, x, block_tables, slot_ids, positions,
                                    seq_lens, rank, attn_norm, wq, wk, wv, wo, kp, vp)

        args = [
            _dyn("x", [B_DEC, d], F32),
            _dyn("block_tables", [B_DEC, nblk]),
            _dyn("slot_ids", [B_DEC]),
            _dyn("positions", [B_DEC]),
            _dyn("seq_lens", [B_DEC]),
            _dyn("rank", [1]),
            *[_wr(r) for r in _layer_roles(cfg, "attn")],
            _kp(-1),
            _vp(-1),
        ]
        w_kv = (cfg.n_kv_heads // p) * cfg.d_head
        outs = [
            {"kind": "partial", "shape": [B_DEC, d]},
            {"kind": "knew", "layer": -1, "shape": [B_DEC, w_kv]},
            {"kind": "vnew", "layer": -1, "shape": [B_DEC, w_kv]},
        ]
        specs[f"attn_decode_tp{p}"] = (attn_dec, args, outs, (), {"tp": p, "phase": "decode"})

        def attn_pre(x, block_table, slot_ids, positions, start, seq_len, rank,
                     attn_norm, wq, wk, wv, wo, kp, vp, p=p):
            return M.tp_attn_prefill(cfg, p, x, block_table, slot_ids, positions,
                                     start, seq_len, rank, attn_norm, wq, wk, wv, wo, kp, vp)

        args = [
            _dyn("x", [C_PREFILL, d], F32),
            _dyn("block_table", [nblk]),
            _dyn("slot_ids", [C_PREFILL]),
            _dyn("positions", [C_PREFILL]),
            _dyn("start", [1]),
            _dyn("seq_len", [1]),
            _dyn("rank", [1]),
            *[_wr(r) for r in _layer_roles(cfg, "attn")],
            _kp(-1),
            _vp(-1),
        ]
        outs = [
            {"kind": "partial", "shape": [C_PREFILL, d]},
            {"kind": "knew", "layer": -1, "shape": [C_PREFILL, w_kv]},
            {"kind": "vnew", "layer": -1, "shape": [C_PREFILL, w_kv]},
        ]
        specs[f"attn_prefill_tp{p}"] = (attn_pre, args, outs, (), {"tp": p, "phase": "prefill"})

        ffn_roles = _layer_roles(cfg, "ffn")

        for phase, t in (("decode", B_DEC), ("prefill", C_PREFILL)):
            def ffn(x, rank, *ws, p=p, roles=tuple(ffn_roles)):
                weights = dict(zip(roles, ws))
                return M.tp_ffn(cfg, p, x, rank, weights)

            args = [_dyn("x", [t, d], F32), _dyn("rank", [1]), *[_wr(r) for r in ffn_roles]]
            outs = [{"kind": "partial", "shape": [t, d]}]
            specs[f"ffn_{phase}_tp{p}"] = (ffn, args, outs, (), {"tp": p, "phase": phase})

    # ---- LM head (replicated) ---------------------------------------------
    for suffix, t in (("dec", B_DEC), ("pre", C_PREFILL)):
        def head(x, final_norm, w_lm):
            return (M.lm_head(cfg, x, final_norm, w_lm),)

        args = [_dyn("x", [t, d], F32), _w("final_norm"), _w("lm_head")]
        outs = [{"kind": "logits", "shape": [t, v]}]
        specs[f"lmhead_{suffix}"] = (head, args, outs, (), {"tp": 0, "phase": suffix})

    return specs


def example_arg(cfg: ModelCfg, a):
    """ShapeDtypeStruct for one arg descriptor."""
    pool = (cfg.pool_elems(),)
    if a["kind"] == "dyn":
        dt = jnp.float32 if a["dtype"] == F32 else jnp.int32
        return jax.ShapeDtypeStruct(tuple(a["shape"]), dt)
    if a["kind"] == "weight":
        return jax.ShapeDtypeStruct(cfg.weight_shape(a["role"]), jnp.float32)
    if a["kind"] == "weight_role":
        return jax.ShapeDtypeStruct(cfg.weight_shape("l0." + a["role"]), jnp.float32)
    if a["kind"] in ("kpool", "vpool"):
        return jax.ShapeDtypeStruct(pool, jnp.float32)
    raise ValueError(a)


def lower_artifact(cfg, name, fn, args, donate, out_dir, force):
    path = os.path.join(out_dir, f"{cfg.name}_{name}.hlo.txt")
    if os.path.exists(path) and not force:
        return path, False
    examples = [example_arg(cfg, a) for a in args]
    lowered = jax.jit(fn, donate_argnums=donate).lower(*examples)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return path, True


def arg_manifest(a):
    """Manifest entry for one arg (adds shapes for pools/weights at runtime)."""
    return a


def build_model(cfg: ModelCfg, out_dir, force):
    weights = make_weights(cfg)
    bin_path = os.path.join(out_dir, f"{cfg.name}_weights.bin")
    wentries = write_weights_bin(cfg, weights, bin_path)

    artifacts = {}
    for name, (fn, args, outs, donate, meta) in build_specs(cfg).items():
        path, fresh = lower_artifact(cfg, name, fn, args, donate, out_dir, force)
        artifacts[name] = {
            "path": os.path.basename(path),
            "args": [arg_manifest(a) for a in args],
            "outputs": outs,
            **meta,
        }
        print(f"  {cfg.name}/{name}: {'lowered' if fresh else 'cached'}")

    return {
        "cfg": {
            "name": cfg.name,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads,
            "d_head": cfg.d_head,
            "ffn_hidden": cfg.ffn_hidden,
            "n_experts": cfg.n_experts,
            "top_k": cfg.top_k,
            "n_blocks": cfg.n_blocks,
            "block_base": cfg.block_base,
            "max_ctx": cfg.max_ctx,
            "vocab": cfg.vocab,
            "rope_theta": cfg.rope_theta,
            "rms_eps": cfg.rms_eps,
            "pool_elems": cfg.pool_elems(),
        },
        "weights_bin": os.path.basename(bin_path),
        "weights": wentries,
        "artifacts": artifacts,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=",".join(MODELS))
    ap.add_argument("--force", action="store_true")
    ns = ap.parse_args()

    os.makedirs(ns.out_dir, exist_ok=True)
    # Merge into an existing manifest so `--models a` doesn't drop others.
    mpath0 = os.path.join(ns.out_dir, "manifest.json")
    if os.path.exists(mpath0):
        with open(mpath0) as f:
            manifest = json.load(f)
    else:
        manifest = {"models": {}}
    manifest["static"] = {"b_dec": B_DEC, "c_prefill": C_PREFILL, "tp_degrees": list(TP_DEGREES)}
    for mname in ns.models.split(","):
        cfg = MODELS[mname]
        print(f"model {mname}:")
        manifest["models"][mname] = build_model(cfg, ns.out_dir, ns.force)

    mpath = os.path.join(ns.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
