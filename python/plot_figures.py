#!/usr/bin/env python3
"""Render the paper's figures from the bench CSVs.

Usage: after `cargo bench`, run `python python/plot_figures.py [bench_out]`.
Produces fig8_<model>.png (three stacked panels: concurrency, p90 TTFT,
queue time — the layout of the paper's Figure 8), fig9.png (TPOT + peak
throughput bars) and fig10.png if matplotlib is available; otherwise prints
ASCII sparklines so the shapes are inspectable in a terminal.
"""

import csv
import os
import sys


def read_csv(path):
    with open(path) as f:
        rows = list(csv.reader(f))
    header, data = rows[0], rows[1:]
    cols = {h: [] for h in header}
    for r in data:
        for h, v in zip(header, r):
            try:
                cols[h].append(float(v) if v else float("nan"))
            except ValueError:
                cols[h].append(v)
    return header, cols


def ascii_spark(values, width=60):
    import math

    vals = [v for v in values if isinstance(v, float) and not math.isnan(v)]
    if not vals:
        return "(no data)"
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    chars = " .:-=+*#%@"
    step = max(1, len(values) // width)
    out = []
    for i in range(0, len(values), step):
        v = values[i]
        if isinstance(v, float) and not math.isnan(v):
            out.append(chars[min(9, int((v - lo) / span * 9))])
        else:
            out.append(" ")
    return "".join(out) + f"   [{lo:.2g} .. {hi:.2g}]"


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "bench_out"
    if not os.path.isdir(out_dir):
        sys.exit(f"{out_dir} not found — run `cargo bench` first")
    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt

        have_mpl = True
    except Exception:
        have_mpl = False

    models = ["llama_3_70b", "gpt_oss_120b", "nemotron_8b"]
    panels = [("concurrency", "in-flight"), ("ttft_p90", "P90 TTFT (s)"), ("queue", "queue time (s)")]
    for m in models:
        series = {}
        for panel, _ in panels:
            path = os.path.join(out_dir, f"fig8_{m}_{panel}.csv")
            if os.path.exists(path):
                series[panel] = read_csv(path)
        if not series:
            continue
        if have_mpl:
            fig, axes = plt.subplots(len(series), 1, figsize=(9, 8), sharex=True)
            axes = axes if hasattr(axes, "__len__") else [axes]
            for ax, (panel, label) in zip(axes, [p for p in panels if p[0] in series]):
                header, cols = series[panel]
                for sysname in header[1:]:
                    ax.plot(cols["t"], cols[sysname], label=sysname, linewidth=1.2)
                ax.set_ylabel(label)
                ax.legend(fontsize=7)
            axes[-1].set_xlabel("trace time (s)")
            fig.suptitle(f"Fig 8 — {m}")
            out = os.path.join(out_dir, f"fig8_{m}.png")
            fig.savefig(out, dpi=130, bbox_inches="tight")
            print(f"wrote {out}")
        else:
            print(f"\n== Fig 8 {m} (ascii) ==")
            for panel, label in panels:
                if panel not in series:
                    continue
                header, cols = series[panel]
                print(f" {label}:")
                for sysname in header[1:]:
                    print(f"  {sysname:18} {ascii_spark(cols[sysname])}")

    for slug in ["fig9_tpot_throughput", "fig10_long_context", "table1_priority", "table2_paper_scale"]:
        path = os.path.join(out_dir, f"{slug}.csv")
        if os.path.exists(path):
            header, cols = read_csv(path)
            print(f"\n== {slug} ==")
            widths = [max(len(str(x)) for x in [h] + cols[h]) for h in header]
            print("  ".join(h.rjust(w) for h, w in zip(header, widths)))
            n = len(next(iter(cols.values())))
            for i in range(n):
                print("  ".join(str(cols[h][i]).rjust(w) for h, w in zip(header, widths)))


if __name__ == "__main__":
    main()
