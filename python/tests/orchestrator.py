"""Python-side mirror of the Rust engine/coordinator step protocol.

This module re-creates, in numpy/jax, exactly what the Rust side does with
the AOT artifacts: per-request block allocation + slot mapping (the KV Cache
Adaptor), chunked prefill, padded decode batches, and — for TP — the
per-layer shard calls with manual all-reduce (partial-sum) between them.

It exists so the pytest suite can validate the *artifact contract* end to
end before any Rust runs: if these tests pass, the Rust engine only has to
reproduce this call sequence mechanically.
"""

import numpy as np
import jax.numpy as jnp

from compile import model as M
from compile.configs import ModelCfg, B_DEC, C_PREFILL

TRASH_BLOCK = 0  # physical block 0 is reserved; padded tokens write here


class Adaptor:
    """Minimal KV Cache Adaptor: free list + per-request block lists.

    Block ids are physical and mode-agnostic (fixed bytes per block); only
    the token capacity B(p) = p * B_base is mode-dependent.
    """

    def __init__(self, cfg: ModelCfg):
        self.cfg = cfg
        self.free = list(range(1, cfg.n_blocks))  # block 0 reserved (trash)
        self.blocks = {}  # req id -> [block ids]
        self.layout = {}  # req id -> TP degree its KV was written under

    def ensure_capacity(self, rid, n_tokens, p):
        """Allocate blocks so request `rid` can hold n_tokens under degree p."""
        bt = self.cfg.block_tokens(p)
        blocks = self.blocks.setdefault(rid, [])
        self.layout[rid] = p
        need = (n_tokens + bt - 1) // bt
        while len(blocks) < need:
            blocks.append(self.free.pop(0))
        return blocks

    def slot(self, rid, pos, p):
        bt = self.cfg.block_tokens(p)
        blk = self.blocks[rid][pos // bt]
        return blk * bt + pos % bt

    def release(self, rid):
        self.free = sorted(self.free + self.blocks.pop(rid, []))
        self.layout.pop(rid, None)

    def table(self, rid, p):
        t = np.zeros(self.cfg.n_blocks, np.int32)
        blocks = self.blocks.get(rid, [])
        t[: len(blocks)] = blocks
        return t


class Engine:
    """One DP engine: full weights + per-layer flat pools (numpy mirrors of
    the device-resident PJRT buffers)."""

    def __init__(self, cfg: ModelCfg, weights):
        self.cfg = cfg
        self.w = {k: jnp.asarray(v) for k, v in weights.items()}
        self.k_pools = [np.zeros(cfg.pool_elems(), np.float32) for _ in range(cfg.n_layers)]
        self.v_pools = [np.zeros(cfg.pool_elems(), np.float32) for _ in range(cfg.n_layers)]
        self.adaptor = Adaptor(cfg)

    def layer_w(self, layer):
        return {k.split(".", 1)[1]: v for k, v in self.w.items() if k.startswith(f"l{layer}.")}

    def scatter_kv(self, layer, k_new, v_new, slots, p):
        """The adaptor-side authoritative KV write (mirrors Rust exactly):
        new rows land at the flat slot ids, under the current layout view."""
        cfg = self.cfg
        hkv_l = cfg.n_kv_heads // p
        n_slots = cfg.n_blocks * cfg.block_tokens(p)
        kp = self.k_pools[layer].reshape(n_slots, hkv_l * cfg.d_head)
        vp = self.v_pools[layer].reshape(n_slots, hkv_l * cfg.d_head)
        for i, s in enumerate(np.asarray(slots)):
            kp[s] = np.asarray(k_new)[i]
            vp[s] = np.asarray(v_new)[i]


def dp_prefill(engine: Engine, rid: int, tokens):
    """Chunked prefill of one request on one DP engine; returns last logits."""
    cfg = engine.cfg
    toks = np.asarray(tokens, np.int32)
    n = len(toks)
    logits = None
    for start in range(0, n, C_PREFILL):
        chunk = toks[start : start + C_PREFILL]
        nv = len(chunk)
        engine.adaptor.ensure_capacity(rid, start + nv, 1)
        tok_pad = np.zeros(C_PREFILL, np.int32)
        tok_pad[:nv] = chunk
        pos = np.zeros(C_PREFILL, np.int32)
        pos[:nv] = start + np.arange(nv)
        slots = np.arange(C_PREFILL, dtype=np.int32) % cfg.block_tokens(1)  # trash
        for i in range(nv):
            slots[i] = engine.adaptor.slot(rid, start + i, 1)
        table = engine.adaptor.table(rid, 1)
        pools = []
        for layer in range(cfg.n_layers):
            pools += [jnp.asarray(engine.k_pools[layer]), jnp.asarray(engine.v_pools[layer])]
        out = M.dp_prefill_step(
            cfg,
            jnp.asarray(tok_pad),
            jnp.asarray(pos),
            jnp.asarray(slots),
            jnp.asarray(table),
            jnp.asarray([start], jnp.int32),
            jnp.asarray([start + nv], jnp.int32),
            engine.w,
            pools,
        )
        logits = np.asarray(out[0])
        for layer in range(cfg.n_layers):
            engine.scatter_kv(layer, out[1 + 2 * layer], out[2 + 2 * layer], slots, 1)
    return logits[len(toks) % C_PREFILL - 1 if n % C_PREFILL else C_PREFILL - 1]


def dp_decode(engine: Engine, reqs):
    """One padded decode step; reqs = [(rid, next_token, position)].

    position = index of next_token (0-based); its kv is appended this step.
    Returns {rid: logits_row}.
    """
    cfg = engine.cfg
    b = len(reqs)
    assert b <= B_DEC
    tokens = np.zeros(B_DEC, np.int32)
    positions = np.zeros(B_DEC, np.int32)
    seq_lens = np.zeros(B_DEC, np.int32)
    slots = np.arange(B_DEC, dtype=np.int32) % cfg.block_tokens(1)
    tables = np.zeros((B_DEC, cfg.n_blocks), np.int32)
    for i, (rid, tok, pos) in enumerate(reqs):
        engine.adaptor.ensure_capacity(rid, pos + 1, 1)
        tokens[i] = tok
        positions[i] = pos
        seq_lens[i] = pos + 1
        slots[i] = engine.adaptor.slot(rid, pos, 1)
        tables[i] = engine.adaptor.table(rid, 1)
    pools = []
    for layer in range(cfg.n_layers):
        pools += [jnp.asarray(engine.k_pools[layer]), jnp.asarray(engine.v_pools[layer])]
    out = M.dp_decode_step(
        cfg,
        jnp.asarray(tokens),
        jnp.asarray(positions),
        jnp.asarray(seq_lens),
        jnp.asarray(tables),
        jnp.asarray(slots),
        engine.w,
        pools,
    )
    logits = np.asarray(out[0])
    for layer in range(cfg.n_layers):
        engine.scatter_kv(layer, out[1 + 2 * layer], out[2 + 2 * layer], slots, 1)
    return {rid: logits[i] for i, (rid, _, _) in enumerate(reqs)}


# ---------------------------------------------------------------------------
# TP orchestration: per-layer shard calls + manual all-reduce, exactly the
# Rust coordinator's data plane.
# ---------------------------------------------------------------------------


class TpGroup:
    """p engines temporarily bound into a TP group (shared block ids)."""

    def __init__(self, engines, p):
        assert len(engines) == p
        self.engines = engines
        self.p = p
        self.cfg = engines[0].cfg
        # Shared adaptor state: the group allocates identical block ids on
        # every member (each member stores its own head slice).
        self.adaptor = engines[0].adaptor

    def _attn_allreduce(self, phase, layer, x, **kw):
        cfg, p = self.cfg, self.p
        partials = []
        for r, eng in enumerate(self.engines):
            lw = eng.layer_w(layer)
            rank = jnp.asarray([r], jnp.int32)
            kp = jnp.asarray(eng.k_pools[layer])
            vp = jnp.asarray(eng.v_pools[layer])
            if phase == "decode":
                partial, kn, vn = M.tp_attn_decode(
                    cfg, p, x, kw["tables"], kw["slots"], kw["positions"], kw["seq_lens"],
                    rank, lw["attn_norm"], lw["wq"], lw["wk"], lw["wv"], lw["wo"], kp, vp,
                )
            else:
                partial, kn, vn = M.tp_attn_prefill(
                    cfg, p, x, kw["table"], kw["slots"], kw["positions"], kw["start"], kw["seq_len"],
                    rank, lw["attn_norm"], lw["wq"], lw["wk"], lw["wv"], lw["wo"], kp, vp,
                )
            eng.scatter_kv(layer, kn, vn, kw["slots"], p)
            partials.append(partial)
        return sum(partials[1:], partials[0])  # all-reduce

    def _ffn_allreduce(self, layer, x):
        partials = []
        for r, eng in enumerate(self.engines):
            lw = eng.layer_w(layer)
            partials.append(M.tp_ffn(self.cfg, self.p, x, jnp.asarray([r], jnp.int32), lw))
        return sum(partials[1:], partials[0])

    def prefill(self, rid, tokens):
        """Chunked TP prefill; KV written in TP-p layout on every member."""
        cfg, p = self.cfg, self.p
        toks = np.asarray(tokens, np.int32)
        n = len(toks)
        w0 = self.engines[0].w
        logits = None
        for start in range(0, n, C_PREFILL):
            chunk = toks[start : start + C_PREFILL]
            nv = len(chunk)
            self.adaptor.ensure_capacity(rid, start + nv, p)
            tok_pad = np.zeros(C_PREFILL, np.int32)
            tok_pad[:nv] = chunk
            pos = np.zeros(C_PREFILL, np.int32)
            pos[:nv] = start + np.arange(nv)
            slots = np.arange(C_PREFILL, dtype=np.int32) % cfg.block_tokens(p)
            for i in range(nv):
                slots[i] = self.adaptor.slot(rid, start + i, p)
            table = self.adaptor.table(rid, p)
            x = np.asarray(w0["emb"])[tok_pad]  # Rust embeds on the host
            x = jnp.asarray(x)
            kw = dict(
                table=jnp.asarray(table),
                slots=jnp.asarray(slots),
                positions=jnp.asarray(pos),
                start=jnp.asarray([start], jnp.int32),
                seq_len=jnp.asarray([start + nv], jnp.int32),
            )
            for layer in range(cfg.n_layers):
                x = x + self._attn_allreduce("prefill", layer, x, **kw)
                x = x + self._ffn_allreduce(layer, x)
            logits = np.asarray(M.lm_head(cfg, x, w0["final_norm"], w0["lm_head"]))
        return logits[n % C_PREFILL - 1 if n % C_PREFILL else C_PREFILL - 1]

    def decode(self, reqs):
        """One padded TP decode step; reqs = [(rid, token, pos)]."""
        cfg, p = self.cfg, self.p
        tokens = np.zeros(B_DEC, np.int32)
        positions = np.zeros(B_DEC, np.int32)
        seq_lens = np.zeros(B_DEC, np.int32)
        slots = np.arange(B_DEC, dtype=np.int32) % cfg.block_tokens(p)
        tables = np.zeros((B_DEC, cfg.n_blocks), np.int32)
        for i, (rid, tok, pos) in enumerate(reqs):
            self.adaptor.ensure_capacity(rid, pos + 1, p)
            tokens[i] = tok
            positions[i] = pos
            seq_lens[i] = pos + 1
            slots[i] = self.adaptor.slot(rid, pos, p)
            tables[i] = self.adaptor.table(rid, p)
        w0 = self.engines[0].w
        x = jnp.asarray(np.asarray(w0["emb"])[tokens])
        kw = dict(
            tables=jnp.asarray(tables),
            slots=jnp.asarray(slots),
            positions=jnp.asarray(positions),
            seq_lens=jnp.asarray(seq_lens),
        )
        for layer in range(cfg.n_layers):
            x = x + self._attn_allreduce("decode", layer, x, **kw)
            x = x + self._ffn_allreduce(layer, x)
        logits = np.asarray(M.lm_head(cfg, x, w0["final_norm"], w0["lm_head"]))
        return {rid: logits[i] for i, (rid, _, _) in enumerate(reqs)}
