"""L2 model correctness: sharded composition vs the unsharded reference.

The decisive tests here validate the *artifact contract*: the fused DP step
functions and the per-layer TP shard functions (orchestrated exactly as the
Rust coordinator will, including the KV Cache Adaptor's block/slot math and
all-reduce placement) must all agree with a contiguous-KV full-model
reference.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.configs import MODELS, ModelCfg, B_DEC, C_PREFILL
from compile.aot import make_weights
from compile.kernels import ref
from compile import model as M

import sys, os

sys.path.insert(0, os.path.dirname(__file__))
from orchestrator import Engine, TpGroup, dp_prefill, dp_decode

# A sub-tiny config keeps these integration tests fast while exercising every
# code path (GQA grouping, multi-layer, paging, chunking).
TEST_CFG = ModelCfg(
    name="test-tiny",
    d_model=32,
    n_layers=2,
    n_heads=8,  # GQA 8q/4kv divides every TP degree in {1,2,4}
    n_kv_heads=4,
    d_head=8,
    ffn_hidden=48,
    n_blocks=64,
    block_base=4,
    max_ctx=1024,
)

TEST_MOE = ModelCfg(
    name="test-moe",
    d_model=32,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    d_head=8,
    ffn_hidden=32,
    n_experts=3,
    top_k=2,
    n_blocks=32,
    block_base=4,
    max_ctx=512,
)


def _tokens(rng, n):
    return rng.integers(0, 256, n).astype(np.int32)


# ---------------------------------------------------------------------------
# Sub-layer shard compositions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", [1, 2])
def test_ffn_shard_partials_sum_to_ref(p):
    cfg = TEST_CFG
    w = make_weights(cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((5, cfg.d_model)).astype(np.float32))
    lw = {k.split(".", 1)[1]: jnp.asarray(v) for k, v in w.items() if k.startswith("l0.")}
    want = ref.ffn_ref(ref.rmsnorm_ref(x, lw["ffn_norm"]), lw["wg"], lw["wu"], lw["wd"])
    acc = np.zeros_like(np.asarray(x))
    for r in range(p):
        acc += np.asarray(
            M.ffn_shard(cfg, p, jnp.asarray([r], jnp.int32), x, lw["ffn_norm"], lw["wg"], lw["wu"], lw["wd"])
        )
    np.testing.assert_allclose(acc, np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("p", [1, 2])
def test_moe_ffn_shard_partials_sum_to_ref(p):
    cfg = TEST_MOE
    w = make_weights(cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((5, cfg.d_model)).astype(np.float32))
    lw = {k.split(".", 1)[1]: jnp.asarray(v) for k, v in w.items() if k.startswith("l0.")}
    xn = ref.rmsnorm_ref(x, lw["ffn_norm"])
    want = ref.moe_ffn_ref(xn, lw["router"], lw["wg"], lw["wu"], lw["wd"], cfg.top_k)
    acc = np.zeros_like(np.asarray(x))
    for r in range(p):
        acc += np.asarray(
            M.moe_ffn_shard(
                cfg, p, jnp.asarray([r], jnp.int32), x, lw["ffn_norm"], lw["router"], lw["wg"], lw["wu"], lw["wd"]
            )
        )
    np.testing.assert_allclose(acc, np.asarray(want), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# End-to-end: paged + sharded serving path vs contiguous full forward
# ---------------------------------------------------------------------------


def _serve_dp(cfg, weights, tokens, n_decode):
    """Prefill then greedy-decode n_decode tokens on a single DP engine."""
    eng = Engine(cfg, weights)
    logits = dp_prefill(eng, rid=1, tokens=tokens)
    hist = list(tokens)
    rows = [logits]
    for _ in range(n_decode):
        nxt = int(np.argmax(rows[-1]))
        hist.append(nxt)
        out = dp_decode(eng, [(1, nxt, len(hist) - 1)])
        rows.append(out[1])
    return hist, rows


def _serve_tp(cfg, weights, tokens, n_decode, p):
    engines = [Engine(cfg, weights) for _ in range(p)]
    # Group members share one adaptor (identical block ids on each member).
    for e in engines[1:]:
        e.adaptor = engines[0].adaptor
    grp = TpGroup(engines, p)
    logits = grp.prefill(rid=1, tokens=tokens)
    hist = list(tokens)
    rows = [logits]
    for _ in range(n_decode):
        nxt = int(np.argmax(rows[-1]))
        hist.append(nxt)
        out = grp.decode([(1, nxt, len(hist) - 1)])
        rows.append(out[1])
    return hist, rows


def _ref_rows(cfg, weights, hist, prompt_len):
    """Reference logits rows at positions prompt_len-1 .. len(hist)-1."""
    full = np.asarray(ref.model_forward_ref(cfg, weights, hist))
    return [full[i] for i in range(prompt_len - 1, len(hist))]


@pytest.mark.parametrize("cfg", [TEST_CFG, TEST_MOE], ids=lambda c: c.name)
def test_dp_serving_matches_reference(cfg):
    w = make_weights(cfg)
    rng = np.random.default_rng(42)
    prompt = _tokens(rng, 19)  # not chunk-aligned on purpose
    hist, rows = _serve_dp(cfg, w, prompt, n_decode=4)
    want = _ref_rows(cfg, w, hist, len(prompt))
    for got, expect in zip(rows, want):
        np.testing.assert_allclose(got, expect, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("p", [2, 4])
def test_tp_serving_matches_reference(p):
    cfg = TEST_CFG
    w = make_weights(cfg)
    rng = np.random.default_rng(43)
    prompt = _tokens(rng, 11)
    hist, rows = _serve_tp(cfg, w, prompt, n_decode=3, p=p)
    want = _ref_rows(cfg, w, hist, len(prompt))
    for got, expect in zip(rows, want):
        np.testing.assert_allclose(got, expect, rtol=2e-3, atol=2e-3)


def test_tp_moe_serving_matches_reference():
    cfg = TEST_MOE
    w = make_weights(cfg)
    rng = np.random.default_rng(44)
    prompt = _tokens(rng, 9)
    hist, rows = _serve_tp(cfg, w, prompt, n_decode=2, p=2)
    want = _ref_rows(cfg, w, hist, len(prompt))
    for got, expect in zip(rows, want):
        np.testing.assert_allclose(got, expect, rtol=2e-3, atol=2e-3)


def test_dp_and_tp_agree_token_for_token():
    """Greedy decode must produce the identical token sequence in both modes
    — the user-visible invariant behind 'switching is transparent'."""
    cfg = TEST_CFG
    w = make_weights(cfg)
    rng = np.random.default_rng(45)
    prompt = _tokens(rng, 13)
    hist_dp, _ = _serve_dp(cfg, w, prompt, n_decode=6)
    hist_tp, _ = _serve_tp(cfg, w, prompt, n_decode=6, p=2)
    assert hist_dp == hist_tp


def test_multi_chunk_prefill_matches_reference():
    """Prompts spanning several prefill chunks (chunked prefill, §3)."""
    cfg = TEST_CFG
    w = make_weights(cfg)
    rng = np.random.default_rng(46)
    prompt = _tokens(rng, C_PREFILL * 2 + 7)
    hist, rows = _serve_dp(cfg, w, prompt, n_decode=2)
    want = _ref_rows(cfg, w, hist, len(prompt))
    for got, expect in zip(rows, want):
        np.testing.assert_allclose(got, expect, rtol=3e-3, atol=3e-3)


def test_batched_decode_requests_are_independent():
    """Two requests decoded in one padded batch == each decoded alone."""
    cfg = TEST_CFG
    w = make_weights(cfg)
    rng = np.random.default_rng(47)
    p1, p2 = _tokens(rng, 6), _tokens(rng, 9)

    # Together:
    eng = Engine(cfg, w)
    l1 = dp_prefill(eng, 1, p1)
    l2 = dp_prefill(eng, 2, p2)
    n1, n2 = int(np.argmax(l1)), int(np.argmax(l2))
    out = dp_decode(eng, [(1, n1, len(p1)), (2, n2, len(p2))])

    # Alone:
    for rid, prompt, tok, got in ((1, p1, n1, out[1]), (2, p2, n2, out[2])):
        e = Engine(cfg, w)
        dp_prefill(e, rid, prompt)
        alone = dp_decode(e, [(rid, tok, len(prompt))])[rid]
        np.testing.assert_allclose(got, alone, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# KV Cache Adaptor invariants at the model level (paper §4.2)
# ---------------------------------------------------------------------------


def test_pool_bytes_invariant_across_modes():
    cfg = TEST_CFG
    sizes = set()
    for p in (1, 2, 4):
        bt = cfg.block_tokens(p)
        sizes.add(cfg.n_blocks * bt * (cfg.n_kv_heads // p) * cfg.d_head)
    assert sizes == {cfg.pool_elems()}


def test_pool_view_is_pure_reshape():
    cfg = TEST_CFG
    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.standard_normal(cfg.pool_elems()).astype(np.float32))
    for p in (1, 2, 4):
        v = M.pool_view(cfg, flat, p)
        np.testing.assert_array_equal(np.asarray(v).reshape(-1), np.asarray(flat))


def test_kv_append_only_touches_named_slots():
    cfg = TEST_CFG
    rng = np.random.default_rng(1)
    flat = jnp.asarray(rng.standard_normal(cfg.pool_elems()).astype(np.float32))
    p = 2
    hkv_l = cfg.n_kv_heads // p
    new = jnp.asarray(rng.standard_normal((3, hkv_l, cfg.d_head)).astype(np.float32))
    slots = jnp.asarray([5, 9, 21], jnp.int32)
    out = M.kv_append(cfg, flat, new, slots, p)
    v_in = np.asarray(M.pool_view(cfg, flat, p))
    v_out = np.asarray(M.pool_view(cfg, out, p))
    np.testing.assert_array_equal(np.asarray(out).shape, np.asarray(flat).shape)
    for s in (5, 9, 21):
        assert not np.array_equal(v_out[s], v_in[s]) or np.allclose(
            v_in[s], new[[5, 9, 21].index(s)]
        )
    mask = np.ones(v_in.shape[0], bool)
    mask[[5, 9, 21]] = False
    np.testing.assert_array_equal(v_out[mask], v_in[mask])


def test_hard_preempt_layout_coexistence():
    """DP-layout KV survives a TP request using disjoint blocks in the same
    physical pool (the Hard Preempt enabler, §5.2.3)."""
    cfg = TEST_CFG
    w = make_weights(cfg)
    rng = np.random.default_rng(48)

    # DP engine serves request 1 partway.
    eng = Engine(cfg, w)
    p1 = _tokens(rng, 7)
    l1 = dp_prefill(eng, 1, p1)
    n1 = int(np.argmax(l1))

    snapshot_k = [kp.copy() for kp in eng.k_pools]

    # A TP request (rid 2) arrives and runs on this engine + a twin, using
    # fresh blocks from the same pools (hard preempt: rid 1 is paused).
    twin = Engine(cfg, w)
    twin.adaptor = eng.adaptor
    twin.k_pools = [kp.copy() for kp in eng.k_pools]
    twin.v_pools = [vp.copy() for vp in eng.v_pools]
    grp = TpGroup([eng, twin], 2)
    grp.prefill(2, _tokens(rng, 10))

    # rid 1's DP blocks are untouched: its flat slots are bit-identical.
    bt1 = cfg.block_tokens(1)
    w1 = cfg.n_kv_heads * cfg.d_head
    for layer in range(cfg.n_layers):
        before = snapshot_k[layer].reshape(cfg.n_blocks, bt1 * w1)
        after = eng.k_pools[layer].reshape(cfg.n_blocks, bt1 * w1)
        for blk in eng.adaptor.blocks[1]:
            np.testing.assert_array_equal(after[blk], before[blk])

    # ... and rid 1 resumes decoding with correct numerics.
    out = dp_decode(eng, [(1, n1, len(p1))])
    hist = list(p1) + [n1]
    want = np.asarray(ref.model_forward_ref(cfg, w, hist))[-1]
    np.testing.assert_allclose(out[1], want, rtol=2e-3, atol=2e-3)
