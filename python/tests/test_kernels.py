"""L1 kernel correctness: Pallas vs pure-jnp oracle, hypothesis-swept."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.shard_matmul import shard_matmul, COL, ROW
from compile.kernels.paged_attention import paged_attention
from compile.kernels import ref

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


# ---------------------------------------------------------------------------
# shard_matmul: the zero-copy weight view (paper §4.1)
# ---------------------------------------------------------------------------


@given(
    t=st.integers(1, 16),
    k=st.sampled_from([8, 16, 32]),
    n_per=st.sampled_from([4, 8, 16]),
    p=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_shard_matmul_col(t, k, n_per, p, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, t, k)
    w = _rand(rng, k, n_per * p)
    for r in range(p):
        got = shard_matmul(x, w, jnp.asarray([r], jnp.int32), p, COL)
        want = ref.shard_matmul_ref(x, w, r, p, COL)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    t=st.integers(1, 16),
    k_per=st.sampled_from([4, 8]),
    n=st.sampled_from([8, 16]),
    p=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_shard_matmul_row(t, k_per, n, p, seed):
    rng = np.random.default_rng(seed)
    w = _rand(rng, k_per * p, n)
    for r in range(p):
        x = _rand(rng, t, k_per)
        got = shard_matmul(x, w, jnp.asarray([r], jnp.int32), p, ROW)
        want = ref.shard_matmul_ref(x, w, r, p, ROW)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_shard_matmul_partials_sum_to_full():
    """Column-then-row shard partial sums == unsharded product chain."""
    rng = np.random.default_rng(7)
    x = _rand(rng, 5, 16)
    w1 = _rand(rng, 16, 32)
    w2 = _rand(rng, 32, 16)
    full = (x @ w1) @ w2
    for p in (1, 2, 4):
        acc = np.zeros((5, 16), np.float32)
        for r in range(p):
            rank = jnp.asarray([r], jnp.int32)
            h = shard_matmul(x, w1, rank, p, COL)
            acc += np.asarray(shard_matmul(h, w2, rank, p, ROW))
        np.testing.assert_allclose(acc, full, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# paged_attention: flash-decoding over the block pool (paper §4.2)
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 4),
    hq_mult=st.sampled_from([1, 2, 4]),
    hkv=st.sampled_from([1, 2]),
    dh=st.sampled_from([4, 8]),
    bt=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_paged_attention_matches_ref(b, hq_mult, hkv, dh, bt, seed):
    rng = np.random.default_rng(seed)
    hq = hkv * hq_mult
    nblk = 8
    nslots = nblk * bt
    kp = _rand(rng, nslots, hkv, dh)
    vp = _rand(rng, nslots, hkv, dh)
    q = _rand(rng, b, hq, dh)
    # Random non-overlapping block assignment per request (block 0 = trash).
    avail = list(range(1, nblk))
    rng.shuffle(avail)
    table = np.zeros((b, nblk), np.int32)
    seq = np.zeros(b, np.int32)
    for i in range(b):
        n_blocks_i = rng.integers(0, min(3, len(avail)) + 1)
        blocks = [avail.pop() for _ in range(n_blocks_i)] if n_blocks_i else []
        table[i, : len(blocks)] = blocks
        seq[i] = 0 if not blocks else rng.integers(1, len(blocks) * bt + 1)
    got = paged_attention(q, kp, vp, jnp.asarray(table), jnp.asarray(seq), bt)
    want = ref.paged_attention_ref(q, kp, vp, table, seq, bt)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_paged_attention_padded_slot_is_zero():
    rng = np.random.default_rng(3)
    kp = _rand(rng, 16, 2, 4)
    vp = _rand(rng, 16, 2, 4)
    q = _rand(rng, 2, 4, 4)
    table = np.zeros((2, 4), np.int32)
    table[0, 0] = 1
    seq = np.asarray([3, 0], np.int32)
    out = np.asarray(paged_attention(q, kp, vp, jnp.asarray(table), jnp.asarray(seq), 4))
    assert np.all(out[1] == 0.0)
    assert np.any(out[0] != 0.0)


def test_paged_attention_single_token():
    """seq_len=1: output must equal v of the single cached token."""
    rng = np.random.default_rng(4)
    kp = _rand(rng, 8, 1, 4)
    vp = _rand(rng, 8, 1, 4)
    q = _rand(rng, 1, 2, 4)
    table = np.asarray([[1, 0]], np.int32)
    seq = np.asarray([1], np.int32)
    out = np.asarray(paged_attention(q, kp, vp, jnp.asarray(table), jnp.asarray(seq), 4))
    want = np.asarray(vp[4])  # block 1, offset 0
    np.testing.assert_allclose(out[0, 0], want[0], rtol=1e-5)
    np.testing.assert_allclose(out[0, 1], want[0], rtol=1e-5)


def test_paged_attention_block_order_irrelevant():
    """Attention must follow the table's logical order, not physical ids."""
    rng = np.random.default_rng(5)
    bt, hkv, dh = 4, 1, 4
    kp = _rand(rng, 8 * bt, hkv, dh)
    vp = _rand(rng, 8 * bt, hkv, dh)
    q = _rand(rng, 1, 1, dh)
    t1 = np.asarray([[5, 2, 0, 0, 0, 0, 0, 0]], np.int32)
    seq = np.asarray([7], np.int32)
    got = np.asarray(paged_attention(q, kp, vp, jnp.asarray(t1), jnp.asarray(seq), bt))
    want = np.asarray(ref.paged_attention_ref(q, kp, vp, t1, seq, bt))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# rope / rmsnorm sanity
# ---------------------------------------------------------------------------


@given(t=st.integers(1, 8), h=st.sampled_from([1, 2]), seed=st.integers(0, 2**31 - 1))
def test_rope_preserves_norm(t, h, seed):
    from compile.model import rope

    rng = np.random.default_rng(seed)
    x = _rand(rng, t, h, 8)
    pos = jnp.asarray(rng.integers(0, 100, t), jnp.int32)
    y = rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1), np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-4
    )


def test_rope_position_zero_identity():
    from compile.model import rope

    rng = np.random.default_rng(0)
    x = _rand(rng, 3, 2, 8)
    y = rope(x, jnp.zeros(3, jnp.int32), 10000.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-6)


def test_rope_matches_ref():
    from compile.model import rope

    rng = np.random.default_rng(1)
    x = _rand(rng, 4, 2, 8)
    pos = jnp.asarray([0, 3, 17, 200], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(rope(x, pos, 10000.0)), np.asarray(ref.rope_ref(x, pos)), rtol=1e-5, atol=1e-6
    )


def test_rmsnorm_matches_ref():
    from compile.model import rmsnorm

    rng = np.random.default_rng(2)
    x = _rand(rng, 4, 16)
    w = _rand(rng, 16)
    np.testing.assert_allclose(
        np.asarray(rmsnorm(x, w)), np.asarray(ref.rmsnorm_ref(x, w)), rtol=1e-5, atol=1e-6
    )
