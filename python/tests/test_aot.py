"""AOT pipeline tests: manifest structure, weights bin layout, HLO emission."""

import json
import os

import numpy as np
import pytest

import jax

from compile.configs import MODELS, ModelCfg, B_DEC, C_PREFILL, TP_DEGREES
from compile.aot import build_specs, example_arg, make_weights, to_hlo_text, write_weights_bin

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_weights_bin_roundtrip(tmp_path):
    cfg = MODELS["llama-tiny"]
    w = make_weights(cfg)
    path = tmp_path / "w.bin"
    entries = write_weights_bin(cfg, w, path)
    blob = np.fromfile(path, dtype="<f4")
    total = sum(e["n_elems"] for e in entries)
    assert len(blob) == total
    for e in entries:
        t = blob[e["offset_elems"] : e["offset_elems"] + e["n_elems"]].reshape(e["shape"])
        np.testing.assert_array_equal(t, w[e["name"]])


def test_weights_deterministic():
    cfg = MODELS["llama-tiny"]
    w1, w2 = make_weights(cfg), make_weights(cfg)
    for k in w1:
        np.testing.assert_array_equal(w1[k], w2[k])


@pytest.mark.parametrize("mname", list(MODELS))
def test_specs_cover_required_surface(mname):
    cfg = MODELS[mname]
    specs = build_specs(cfg)
    assert "dp_decode" in specs and "dp_prefill" in specs
    for p in (2, 4):
        if cfg.n_kv_heads % p == 0 and cfg.n_heads % p == 0:
            for a in (f"attn_decode_tp{p}", f"attn_prefill_tp{p}", f"ffn_decode_tp{p}", f"ffn_prefill_tp{p}"):
                assert a in specs, a
    assert "lmhead_dec" in specs and "lmhead_pre" in specs


@pytest.mark.parametrize("mname", list(MODELS))
def test_spec_args_traceable_shapes(mname):
    """Every arg descriptor maps to a concrete example shape."""
    cfg = MODELS[mname]
    for name, (fn, args, outs, donate, meta) in build_specs(cfg).items():
        for a in args:
            ex = example_arg(cfg, a)
            assert all(d > 0 for d in ex.shape) or ex.shape == (), (name, a)
        for d in donate:
            assert args[d]["kind"] in ("kpool", "vpool"), (name, d, args[d])


def test_hlo_text_emits_and_mentions_entry():
    cfg = MODELS["longctx-tiny"]
    specs = build_specs(cfg)
    fn, args, outs, donate, meta = specs["lmhead_dec"]
    examples = [example_arg(cfg, a) for a in args]
    lowered = jax.jit(fn, donate_argnums=donate).lower(*examples)
    text = to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # Output arity: logits only, wrapped in a 1-tuple.
    assert len(outs) == 1


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")), reason="run `make artifacts` first")
def test_manifest_consistency():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["static"]["b_dec"] == B_DEC
    assert man["static"]["c_prefill"] == C_PREFILL
    for mname, m in man["models"].items():
        cfg = MODELS[mname]
        assert m["cfg"]["pool_elems"] == cfg.pool_elems()
        # Every artifact file exists and every weight role resolves.
        for aname, art in m["artifacts"].items():
            assert os.path.exists(os.path.join(ART, art["path"])), art["path"]
            for a in art["args"]:
                if a["kind"] == "weight":
                    assert any(e["name"] == a["role"] for e in m["weights"]), a
                elif a["kind"] == "weight_role":
                    assert any(e["name"] == "l0." + a["role"] for e in m["weights"]), a
        # Weights bin size matches the manifest entries.
        total = sum(e["n_elems"] for e in m["weights"])
        path = os.path.join(ART, m["weights_bin"])
        assert os.path.getsize(path) == total * 4


def test_pool_capacity_scaling_matches_paper_eq3():
    """B(p) = p * B_base and capacity multiplies by p (paper Use Case 3)."""
    for cfg in MODELS.values():
        for p in TP_DEGREES:
            if cfg.n_kv_heads % p:
                continue
            assert cfg.block_tokens(p) == p * cfg.block_base
            assert cfg.tp_token_capacity(p) == p * cfg.dp_token_capacity()
