//! FLYING SERVING launcher.
//!
//! Subcommands:
//!   serve   — boot the engine cluster and serve the TCP line-JSON protocol
//!   replay  — generate a synthetic trace (§6.1.3) and replay it on the
//!             real cluster, printing the paper's metrics
//!   sim     — run the 8×H200 discrete-event comparison (all systems)
//!   ctrl    — run the adaptive control-plane ablation (controllers ×
//!             scenario library) on the simulator
//!   trace   — summarize a flight-recorder JSONL journal (obs/SCHEMA.md)
//!   info    — print manifest/model inventory
//!
//! Common flags: --artifacts DIR --model NAME --engines N
//!               --policy flying|static-dp|static-tp --static-tp P
//!               --strategy sequential|soft|hard --seed S --requests N
//!               --listen ADDR --verbose
//!               --switch-backfill (drain backfill + incremental settle)
//!               --switch-migrate  (layout-preserving KV migration)
//!               --watchdog        (lockstep watchdog + graceful degradation)
//!               --watchdog-timeout-ms MS (first reply deadline override)
//!               --recover         (engine fail-recover: revive + rejoin;
//!                                  requires --watchdog)
//!               --rejoin-attempts N      (per-engine revive budget, default 3)
//!               --rejoin-backoff-ms MS   (base rejoin backoff, doubles per
//!                                         attempt; default 1000)
//!               --max-step-err-streak N  (step errors before fail-stop,
//!                                         default 32)
//!               --stranded-sweep-iters N (idle iterations before the
//!                                         degraded-cell sweep, default 1000)
//!               --overlap         (double-buffered step pipeline: prebuilt
//!                                  batch arenas, async migration
//!                                  collectives, prefill/decode co-issue;
//!                                  off = byte-identical run)
//!               --prefix-cache    (cross-request shared-prefix KV reuse;
//!                                  off = byte-identical run)
//!               --trace           (flight recorder; off = byte-identical run)
//!               --trace-out PATH  (JSONL base path, suffixed per run)

use anyhow::{bail, Result};

use flying_serving::config::{parse_args, ServeConfig};
use flying_serving::json::Value;
use flying_serving::runtime::Manifest;
use flying_serving::sim::{simulate, CostModel, HwSpec, PaperModel, SimConfig, SimSystem};
use flying_serving::util;
use flying_serving::workload::{generate, WorkloadCfg};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_args(&args)?;
    let cfg = ServeConfig::from_flags(&flags)?;
    if cfg.verbose {
        util::set_log_level(3);
    }
    match pos.first().map(|s| s.as_str()) {
        Some("serve") => serve(&cfg),
        Some("replay") => replay(&cfg),
        Some("sim") => sim(&cfg),
        Some("ctrl") => ctrl(&cfg),
        Some("trace") => trace_summary(&pos),
        Some("info") => print_info(&cfg),
        other => {
            bail!(
                "usage: flying-serving <serve|replay|sim|ctrl|trace|info> [flags]\n  (got {:?})",
                other
            )
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn serve(_cfg: &ServeConfig) -> Result<()> {
    bail!("`serve` needs the PJRT engine backend: rebuild with `--features pjrt`")
}

#[cfg(not(feature = "pjrt"))]
fn replay(_cfg: &ServeConfig) -> Result<()> {
    bail!("`replay` needs the PJRT engine backend: rebuild with `--features pjrt`")
}

#[cfg(feature = "pjrt")]
fn serve(cfg: &ServeConfig) -> Result<()> {
    let manifest = std::sync::Arc::new(Manifest::load(&cfg.artifacts_dir)?);
    let mut cluster = flying_serving::coordinator::Cluster::start(&manifest, &cfg.model, cfg.n_engines)?;
    cluster.set_switch_config(cfg.make_switch_config());
    cluster.set_watchdog_checked(cfg.make_watchdog_config())?;
    cluster.set_overlap_config(cfg.make_overlap_config());
    cluster.set_prefix_cache(cfg.prefix_cache);
    // Calibrate whenever something consumes the cost model on this cluster
    // (`ServeConfig::needs_calibration`): predictions must be denominated
    // in this testbed's measured seconds, not the paper-scale default's.
    let calibrated = if cfg.needs_calibration() { Some(cluster.calibrate()?) } else { None };
    let mut policy = cfg.make_policy_with(calibrated)?;
    flying_serving::server::serve(&mut cluster, policy.as_mut(), cfg.strategy, &cfg.listen)
}

#[cfg(feature = "pjrt")]
fn replay(cfg: &ServeConfig) -> Result<()> {
    use flying_serving::workload::synth_prompt_tokens_family;
    let manifest = std::sync::Arc::new(Manifest::load(&cfg.artifacts_dir)?);
    let mut cluster = flying_serving::coordinator::Cluster::start(&manifest, &cfg.model, cfg.n_engines)?;
    cluster.set_switch_config(cfg.make_switch_config());
    cluster.set_watchdog_checked(cfg.make_watchdog_config())?;
    cluster.set_overlap_config(cfg.make_overlap_config());
    cluster.set_prefix_cache(cfg.prefix_cache);
    // Same calibration rule as `serve` (`ServeConfig::needs_calibration`).
    let calibrated = if cfg.needs_calibration() { Some(cluster.calibrate()?) } else { None };
    let mut policy = cfg.make_policy_with(calibrated)?;

    let wl = WorkloadCfg::paper_scaled(cfg.seed, cfg.n_requests);
    let trace = generate(&wl);
    let serve_trace = trace
        .iter()
        .map(|r| flying_serving::coordinator::ServeRequest {
            id: r.id,
            prompt: synth_prompt_tokens_family(
                r.id,
                r.prompt_len.min(400),
                r.prefix_family.map(|(fid, plen)| (fid, plen.min(200))),
            ),
            max_new: r.output_len.min(32),
            priority: r.priority,
            tp_demand: r.tp_demand,
            arrival: r.arrival * 0.2, // compress the trace for the testbed
        })
        .collect();

    flying_serving::info!("replaying {} requests on {} engines", cfg.n_requests, cfg.n_engines);
    let out = cluster.run_trace(serve_trace, policy.as_mut(), cfg.strategy)?;
    cluster.shutdown();

    let s = out.recorder.summary(None);
    println!("policy={} strategy={}", cfg.policy, cfg.strategy.name());
    println!(
        "requests={} finished={} rejected={} switches={}",
        s.n,
        s.finished,
        out.rejected.len(),
        out.switches.len()
    );
    if cfg.prefix_cache {
        println!(
            "prefix-reuse: {} prompt tokens adopted from cache",
            out.prefill_tokens_avoided
        );
    }
    if cfg.watchdog {
        let f = out.fault_stats;
        println!(
            "faults={} timeouts={} stalls-ridden-out={} step-errors={} recovered={} aborted={}",
            f.engine_faults,
            f.reply_timeouts,
            f.stalls_ridden_out,
            f.step_errors,
            f.requests_recovered,
            f.requests_aborted
        );
        if cfg.recover {
            println!(
                "revives={} rejoin-probes={} rejoins-ok={} rejoins-abandoned={}",
                f.engine_revives, f.rejoin_probes, f.rejoins_ok, f.rejoins_abandoned
            );
        }
    }
    println!(
        "TTFT mean={:.1}ms p90={:.1}ms | TPOT p50={:.1}ms | queue p90={:.1}ms | peak={:.0} tok/s",
        s.mean_ttft * 1e3,
        s.p90_ttft * 1e3,
        s.p50_tpot * 1e3,
        s.p90_queue * 1e3,
        s.peak_throughput
    );
    Ok(())
}

fn sim(cfg: &ServeConfig) -> Result<()> {
    let models = [
        PaperModel::llama70b(),
        PaperModel::gptoss120b(),
        PaperModel::nemotron8b(),
    ];
    for model in models {
        println!("== {} ==", model.name);
        let cm = CostModel::new(HwSpec::default(), model);
        let trace = generate(&WorkloadCfg::paper_full(cfg.seed, cfg.n_requests.max(500)));
        let sim_cfg = SimConfig {
            switch_backfill: cfg.switch_backfill,
            switch_migrate: cfg.switch_migrate,
            trace: cfg.trace,
            overlap: cfg.overlap,
            prefix_cache: cfg.prefix_cache,
            ..SimConfig::default()
        };
        for sys in [
            SimSystem::StaticDp,
            SimSystem::StaticTp(4),
            SimSystem::Shift,
            SimSystem::Flying,
        ] {
            let o = simulate(sys, &cm, &trace, &sim_cfg);
            let s = o.recorder.summary(None);
            println!(
                "  {:18} meanTTFT={:7.2}s p90TTFT={:7.2}s TPOT={:5.1}ms peak={:7.0} tok/s switch-stall={:6.1}s kv-carried={} prefix-reuse={} rejected={}",
                sys.label(),
                s.mean_ttft,
                s.p90_ttft,
                s.p50_tpot * 1e3,
                s.peak_throughput,
                o.switch_stall_s,
                o.recompute_tokens_avoided,
                o.prefill_tokens_avoided,
                o.rejected.len()
            );
            if let Some(j) = &o.journal {
                let meta = Value::obj(vec![
                    ("model", Value::str(cm.model.name)),
                    ("system", Value::str(sys.label())),
                    ("dropped", Value::num(j.dropped() as f64)),
                    ("stall", o.stall.to_value()),
                ]);
                let tag = format!("{}_{}", cm.model.name, sys.label());
                let path = dump_journal(&cfg.trace_out, &tag, j, &meta)?;
                println!("  trace -> {}", path.display());
            }
        }
    }
    Ok(())
}

/// Derive the per-run JSONL path from the `--trace-out` base: insert a
/// sanitized tag before the extension.
fn trace_path(base: &str, tag: &str) -> std::path::PathBuf {
    let p = std::path::Path::new(base);
    let stem = p.file_stem().and_then(|s| s.to_str()).unwrap_or("trace");
    let ext = p.extension().and_then(|s| s.to_str()).unwrap_or("jsonl");
    let tag: String = tag
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '-' })
        .collect();
    p.with_file_name(format!("{stem}_{tag}.{ext}"))
}

/// Drain a journal to its per-run JSONL file (off the critical path: the
/// run is already over).
fn dump_journal(
    base: &str,
    tag: &str,
    j: &flying_serving::obs::Journal,
    meta: &Value,
) -> Result<std::path::PathBuf> {
    use std::io::Write as _;
    let path = trace_path(base, tag);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut w = std::io::BufWriter::new(std::fs::File::create(&path)?);
    j.write_jsonl(&mut w, Some(meta))?;
    w.flush()?;
    Ok(path)
}

/// `trace FILE` — parse a flight-recorder JSONL dump (every line must
/// round-trip through `json::parse`; the CI smoke step runs exactly this)
/// and print the summary.
fn trace_summary(pos: &[String]) -> Result<()> {
    let Some(path) = pos.get(1) else {
        bail!("usage: flying-serving trace FILE.jsonl");
    };
    let text = std::fs::read_to_string(path)?;
    let s = flying_serving::obs::summarize_jsonl(&text)?;
    print!("{s}");
    Ok(())
}

/// Controller ablation on the simulator: every scenario-library workload
/// under the static-DP / static-TP / threshold / cost-model controllers
/// (the compact CLI twin of `benches/ctrl_adapt.rs`).
fn ctrl(cfg: &ServeConfig) -> Result<()> {
    use flying_serving::control::{
        ControlConfig, ControlRuntime, Controller, CostModelController, StaticController,
        ThresholdController,
    };
    use flying_serving::sim::simulate_adaptive;
    use flying_serving::workload::Scenario;

    let cm = CostModel::new(HwSpec::default(), PaperModel::llama70b());
    let n_units = cm.hw.n_gpus / cm.model.min_gpus;
    let n = cfg.n_requests.max(500);
    let sim_cfg = SimConfig { trace: cfg.trace, ..SimConfig::default() };
    for scenario in Scenario::ALL {
        println!("== {scenario} (n={n}) ==");
        let trace = scenario.generate(cfg.seed, n);
        let controllers: [Box<dyn Controller>; 4] = [
            Box::new(StaticController::dp()),
            Box::new(StaticController::tp(n_units)),
            Box::new(ThresholdController::default()),
            Box::new(CostModelController::new(cm.clone())),
        ];
        for controller in controllers {
            let mut rt = ControlRuntime::new(
                controller,
                ControlConfig {
                    long_threshold: cm.kv_capacity_tokens(cm.model.min_gpus),
                    ..ControlConfig::default()
                },
            );
            let o = simulate_adaptive(&cm, &trace, &sim_cfg, &mut rt);
            let s = o.recorder.summary(None);
            let attained = o
                .recorder
                .slo_attained(|r| 5.0 + 3.0 * cm.prefill_s(r.prompt_len, cm.hw.n_gpus));
            println!(
                "  {:14} goodput={:6.2} req/s ttft_p90={:7.2}s rejected={:4} switches={:5} plans={:3}",
                rt.controller_name(),
                attained as f64 / o.recorder.makespan().max(1e-9),
                s.p90_ttft,
                o.rejected.len(),
                o.n_switches,
                rt.plan_changes(),
            );
            if let Some(j) = &o.journal {
                let meta = Value::obj(vec![
                    ("scenario", Value::str(format!("{scenario}"))),
                    ("controller", Value::str(rt.controller_name())),
                    ("dropped", Value::num(j.dropped() as f64)),
                    ("stall", o.stall.to_value()),
                ]);
                let tag = format!("{scenario}_{}", rt.controller_name());
                let path = dump_journal(&cfg.trace_out, &tag, j, &meta)?;
                println!("  trace -> {}", path.display());
            }
        }
    }
    Ok(())
}

fn print_info(cfg: &ServeConfig) -> Result<()> {
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    println!(
        "artifacts: {} (b_dec={}, c_prefill={}, tp={:?})",
        cfg.artifacts_dir.display(),
        manifest.shapes.b_dec,
        manifest.shapes.c_prefill,
        manifest.tp_degrees
    );
    for (name, m) in &manifest.models {
        println!(
            "model {name}: d={} L={} heads={}/{} ffn={} experts={} blocks={}x{} max_ctx={} ({} artifacts)",
            m.cfg.d_model,
            m.cfg.n_layers,
            m.cfg.n_heads,
            m.cfg.n_kv_heads,
            m.cfg.ffn_hidden,
            m.cfg.n_experts,
            m.cfg.n_blocks,
            m.cfg.block_base,
            m.cfg.max_ctx,
            m.artifacts.len()
        );
    }
    Ok(())
}
