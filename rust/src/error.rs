//! Typed cross-thread failure domain (ISSUE 6).
//!
//! The coordinator's lockstep loop talks to engine workers over bounded
//! channels and (through them) to the communicator's shared state.  Every
//! way that conversation can break — a worker thread dying, a reply
//! deadline expiring, a peer panicking while holding a lock — used to
//! surface as an `unwrap` panic or an untyped `anyhow!` string.  This
//! module gives those failures one typed shape so callers can tell a
//! *fault* (degrade: mark the engine failed, recover its requests) from a
//! *bug* (propagate: clean shutdown), and so the server frontend can
//! distinguish "this request failed" from "the cell lost an engine".

use std::fmt;

/// How an engine fault was detected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The reply deadline (plus the bounded retry budget) expired while
    /// the worker was still connected — stall escalated to fail-stop.
    Timeout,
    /// The worker's channel disconnected: the thread exited or panicked.
    Disconnected,
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultKind::Timeout => write!(f, "reply deadline expired"),
            FaultKind::Disconnected => write!(f, "channel disconnected (worker died)"),
        }
    }
}

/// Typed serving-layer failure, carried through `anyhow` so existing
/// `Result` plumbing keeps working — callers downcast to branch on it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// An engine stopped holding up its end of the lockstep protocol.
    /// With the watchdog enabled this is absorbed by graceful degradation;
    /// without it, it propagates as a fatal cluster error.
    EngineFault { engine: usize, kind: FaultKind },
    /// A coordinator-side channel closed unexpectedly.
    ChannelClosed { what: &'static str },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::EngineFault { engine, kind } => {
                write!(f, "engine {engine} fault: {kind}")
            }
            ServeError::ChannelClosed { what } => write!(f, "channel closed: {what}"),
        }
    }
}

impl std::error::Error for ServeError {}

impl ServeError {
    /// Whether the error means the cell can no longer serve (the frontend
    /// should shut down cleanly rather than keep accepting connections).
    pub fn is_fatal(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn downcasts_through_anyhow() {
        let e = anyhow::Error::new(ServeError::EngineFault {
            engine: 3,
            kind: FaultKind::Timeout,
        });
        let se = e.downcast_ref::<ServeError>().unwrap();
        assert!(matches!(se, ServeError::EngineFault { engine: 3, .. }));
        assert!(se.is_fatal());
        assert!(format!("{se}").contains("engine 3"));
    }
}
