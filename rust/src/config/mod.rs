//! Configuration + hand-rolled CLI (clap is not in the offline crate set).
//!
//! The launcher accepts `--key value` / `--flag` pairs; `ServeConfig` is the
//! typed result shared by the binary, the examples, and the benches.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::coordinator::strategy::Strategy;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub artifacts_dir: PathBuf,
    pub model: String,
    pub n_engines: usize,
    pub strategy: Strategy,
    pub policy: String, // flying | static-dp | static-tp
    pub static_tp: usize,
    pub listen: String,
    pub seed: u64,
    pub n_requests: usize,
    pub verbose: bool,
    /// Drain backfill + incremental settle on the switch path
    /// (`coordinator::strategy::SwitchConfig`).  Off by default: the
    /// transition then behaves exactly as PR 1/2.
    pub switch_backfill: bool,
    /// Layout-preserving KV migration on DP→TP promotion
    /// (`SwitchConfig::migrate`).  Off by default: promotion then
    /// re-prefills speculative KV exactly as PR 1/3.
    pub switch_migrate: bool,
    /// Lockstep watchdog + graceful degradation (ISSUE 6,
    /// `coordinator::strategy::WatchdogConfig`).  Off by default: reply
    /// collection then blocks exactly as the pre-watchdog coordinator.
    pub watchdog: bool,
    /// First per-command reply deadline in milliseconds (retries extend
    /// it; see `WatchdogConfig`).  0 keeps the default.
    pub watchdog_timeout_ms: u64,
    /// Engine fail-recover (ISSUE 8, `WatchdogConfig::recover`).  Off by
    /// default: a failed engine then stays fail-stopped exactly as PR 6.
    /// Requires `--watchdog` (validated at startup).
    pub recover: bool,
    /// Rejoin attempts per engine before recovery re-escalates to
    /// permanent fail-stop.  0 keeps the default (3).
    pub rejoin_attempts: u32,
    /// Base rejoin backoff in milliseconds (doubles per attempt).  0 keeps
    /// the default (1000).
    pub rejoin_backoff_ms: u64,
    /// Consecutive degraded step errors before fail-stop
    /// (`WatchdogConfig::max_step_err_streak`).  0 keeps the default (32).
    pub max_step_err_streak: u32,
    /// Idle iterations before the degraded-cell stranded sweep
    /// (`WatchdogConfig::stranded_sweep_iters`).  0 keeps the default (1000).
    pub stranded_sweep_iters: usize,
    /// Step-pipeline overlap (ISSUE 9, `coordinator::strategy::
    /// OverlapConfig` / `SimConfig::overlap`).  Off by default: building,
    /// issuing, and collecting then run the exact pre-overlap lockstep on
    /// both execution paths.  On: double-buffered step arenas, asynchronous
    /// migration collectives, and prefill/decode co-issue.
    pub overlap: bool,
    /// Cross-request prefix cache (ISSUE 10, `KvCacheAdaptor` radix tree).
    /// Off by default: admission never probes the tree and behavior is
    /// byte-identical to pre-PR-10.  On: DP admissions adopt cached
    /// shared-prefix blocks by reference (those tokens never prefill) and
    /// finished DP requests donate their prefix blocks back to the tree.
    pub prefix_cache: bool,
    /// Flight recorder (ISSUE 7).  Off by default: no journal is
    /// allocated and behavior is byte-identical to an untraced run; on,
    /// both execution paths record switch/migration/backfill/fault/
    /// control-tick events into a fixed ring, drained to JSONL after the
    /// run.
    pub trace: bool,
    /// JSONL path the journal is written to when `--trace` is on (the
    /// sim/ctrl subcommands suffix it per run).
    pub trace_out: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            model: "llama-tiny".into(),
            n_engines: 2,
            strategy: Strategy::HardPreempt,
            policy: "flying".into(),
            static_tp: 2,
            listen: "127.0.0.1:7077".into(),
            seed: 42,
            n_requests: 64,
            verbose: false,
            switch_backfill: false,
            switch_migrate: false,
            watchdog: false,
            watchdog_timeout_ms: 0,
            recover: false,
            rejoin_attempts: 0,
            rejoin_backoff_ms: 0,
            max_step_err_streak: 0,
            stranded_sweep_iters: 0,
            overlap: false,
            prefix_cache: false,
            trace: false,
            trace_out: "bench_out/trace.jsonl".into(),
        }
    }
}

/// Minimal `--key value` argument parser; returns (positional, flags).
pub fn parse_args(args: &[String]) -> Result<(Vec<String>, BTreeMap<String, String>)> {
    let mut pos = Vec::new();
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            // `--flag` followed by another flag or end => boolean true.
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(a.clone());
            i += 1;
        }
    }
    Ok((pos, flags))
}

impl ServeConfig {
    pub fn from_flags(flags: &BTreeMap<String, String>) -> Result<Self> {
        let mut c = ServeConfig::default();
        for (k, v) in flags {
            match k.as_str() {
                "artifacts" => c.artifacts_dir = PathBuf::from(v),
                "model" => c.model = v.clone(),
                "engines" => c.n_engines = v.parse()?,
                "strategy" => c.strategy = v.parse()?,
                "policy" => c.policy = v.clone(),
                "static-tp" => c.static_tp = v.parse()?,
                "listen" => c.listen = v.clone(),
                "seed" => c.seed = v.parse()?,
                "requests" => c.n_requests = v.parse()?,
                "verbose" => c.verbose = v == "true",
                "switch-backfill" => c.switch_backfill = v == "true",
                "switch-migrate" => c.switch_migrate = v == "true",
                "watchdog" => c.watchdog = v == "true",
                "watchdog-timeout-ms" => c.watchdog_timeout_ms = v.parse()?,
                "recover" => c.recover = v == "true",
                "rejoin-attempts" => c.rejoin_attempts = v.parse()?,
                "rejoin-backoff-ms" => c.rejoin_backoff_ms = v.parse()?,
                "max-step-err-streak" => c.max_step_err_streak = v.parse()?,
                "stranded-sweep-iters" => c.stranded_sweep_iters = v.parse()?,
                "overlap" => c.overlap = v == "true",
                "prefix-cache" => c.prefix_cache = v == "true",
                "trace" => c.trace = v == "true",
                "trace-out" => c.trace_out = v.clone(),
                _ => bail!("unknown flag --{k}"),
            }
        }
        Ok(c)
    }

    /// Whether this configuration has a cost-model consumer on the real
    /// path — the adaptive policy's `CostModelController`, the wall-clock
    /// backfill predicate, or the migrate gate — and the cluster should
    /// therefore run `Cluster::calibrate()` before serving.  The single
    /// definition both `serve` and `replay` gate on: a future cost-model
    /// consumer is added here, not at each call site.
    pub fn needs_calibration(&self) -> bool {
        self.policy == "adaptive" || self.switch_backfill || self.switch_migrate
    }

    /// Switch-transition tuning for the real coordinator, derived from the
    /// `--switch-backfill` / `--switch-migrate` flags (other knobs keep
    /// their defaults).
    pub fn make_switch_config(&self) -> crate::coordinator::strategy::SwitchConfig {
        crate::coordinator::strategy::SwitchConfig {
            backfill: self.switch_backfill,
            migrate: self.switch_migrate,
            ..Default::default()
        }
    }

    /// Lockstep-watchdog + fail-recover tuning from `--watchdog` /
    /// `--watchdog-timeout-ms` / `--recover` / `--rejoin-attempts` /
    /// `--rejoin-backoff-ms` / `--max-step-err-streak` /
    /// `--stranded-sweep-iters` (a 0 keeps the corresponding default).
    /// Ordering invariants are checked by the cluster's
    /// `set_watchdog_checked` against its real communicator timeout, not
    /// here.
    pub fn make_watchdog_config(&self) -> crate::coordinator::strategy::WatchdogConfig {
        let mut w = crate::coordinator::strategy::WatchdogConfig {
            enabled: self.watchdog,
            recover: self.recover,
            ..Default::default()
        };
        if self.watchdog_timeout_ms > 0 {
            w.reply_timeout = std::time::Duration::from_millis(self.watchdog_timeout_ms);
            w.backoff = w.reply_timeout;
        }
        if self.rejoin_attempts > 0 {
            w.max_rejoin_attempts = self.rejoin_attempts;
        }
        if self.rejoin_backoff_ms > 0 {
            w.rejoin_backoff = std::time::Duration::from_millis(self.rejoin_backoff_ms);
        }
        if self.max_step_err_streak > 0 {
            w.max_step_err_streak = self.max_step_err_streak;
        }
        if self.stranded_sweep_iters > 0 {
            w.stranded_sweep_iters = self.stranded_sweep_iters;
        }
        w
    }

    /// Step-pipeline overlap tuning from `--overlap` (ISSUE 9; the three
    /// sub-mechanisms ship armed and gate on the master switch).
    pub fn make_overlap_config(&self) -> crate::coordinator::strategy::OverlapConfig {
        crate::coordinator::strategy::OverlapConfig {
            enabled: self.overlap,
            ..Default::default()
        }
    }

    /// Instantiate the configured policy with no testbed calibration:
    /// `adaptive` falls back to the scale-free threshold controller.
    pub fn make_policy(&self) -> Result<Box<dyn crate::coordinator::policy::Policy>> {
        self.make_policy_with(None)
    }

    /// Instantiate the configured policy.  For `--policy adaptive`, a
    /// testbed-calibrated [`crate::sim::CostModel`] (from
    /// `Cluster::calibrate`) upgrades the control plane to the
    /// `CostModelController` — layout scoring in this testbed's measured
    /// seconds (ROADMAP open item, resolved in PR 5); without one the
    /// scale-free `ThresholdController` (queue depth and idle fractions)
    /// keeps working on any hardware.
    pub fn make_policy_with(
        &self,
        calibrated: Option<crate::sim::CostModel>,
    ) -> Result<Box<dyn crate::coordinator::policy::Policy>> {
        use crate::baselines::{StaticDpPolicy, StaticTpPolicy};
        use crate::control::{
            AdaptivePolicy, ControlConfig, ControlRuntime, Controller, CostModelController,
            ThresholdController,
        };
        use crate::coordinator::policy::FlyingPolicy;
        Ok(match self.policy.as_str() {
            "flying" => Box::new(FlyingPolicy::default()),
            "static-dp" => Box::new(StaticDpPolicy),
            "static-tp" => Box::new(StaticTpPolicy { p: self.static_tp }),
            "adaptive" => {
                let controller: Box<dyn Controller> = match calibrated {
                    Some(cm) => Box::new(CostModelController::new(cm)),
                    None => Box::new(ThresholdController::default()),
                };
                Box::new(AdaptivePolicy::new(ControlRuntime::new(
                    controller,
                    ControlConfig::default(),
                )))
            }
            p => bail!("unknown policy '{p}' (flying|static-dp|static-tp|adaptive)"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed_args() {
        let (pos, flags) = parse_args(&s(&["serve", "--engines", "4", "--verbose", "--model", "moe-tiny"])).unwrap();
        assert_eq!(pos, vec!["serve"]);
        assert_eq!(flags["engines"], "4");
        assert_eq!(flags["verbose"], "true");
        assert_eq!(flags["model"], "moe-tiny");
    }

    #[test]
    fn config_from_flags() {
        let (_, flags) = parse_args(&s(&["--engines", "4", "--strategy", "soft", "--policy", "static-tp", "--static-tp", "4"])).unwrap();
        let c = ServeConfig::from_flags(&flags).unwrap();
        assert_eq!(c.n_engines, 4);
        assert_eq!(c.strategy, Strategy::SoftPreempt);
        assert_eq!(c.static_tp, 4);
        assert!(c.make_policy().is_ok());
    }

    #[test]
    fn adaptive_policy_constructs() {
        let (_, flags) = parse_args(&s(&["--policy", "adaptive"])).unwrap();
        let c = ServeConfig::from_flags(&flags).unwrap();
        let p = c.make_policy().unwrap();
        assert_eq!(p.name(), "threshold");
    }

    #[test]
    fn switch_backfill_flag_parses() {
        let (_, flags) = parse_args(&s(&["--switch-backfill", "true"])).unwrap();
        let c = ServeConfig::from_flags(&flags).unwrap();
        assert!(c.switch_backfill);
        assert!(c.make_switch_config().backfill);
        assert!(!ServeConfig::default().make_switch_config().backfill);
    }

    #[test]
    fn switch_migrate_flag_parses() {
        let (_, flags) = parse_args(&s(&["--switch-migrate"])).unwrap();
        let c = ServeConfig::from_flags(&flags).unwrap();
        assert!(c.switch_migrate);
        assert!(c.make_switch_config().migrate);
        assert!(!c.make_switch_config().backfill, "flags stay independent");
        assert!(!ServeConfig::default().make_switch_config().migrate);
    }

    #[test]
    fn calibration_gate_covers_every_cost_model_consumer() {
        assert!(!ServeConfig::default().needs_calibration());
        for flags in [
            &["--policy", "adaptive"][..],
            &["--switch-backfill"][..],
            &["--switch-migrate"][..],
        ] {
            let (_, f) = parse_args(&s(flags)).unwrap();
            assert!(
                ServeConfig::from_flags(&f).unwrap().needs_calibration(),
                "{flags:?} must calibrate"
            );
        }
    }

    #[test]
    fn trace_flag_parses() {
        let (_, flags) =
            parse_args(&s(&["--trace", "--trace-out", "out/run.jsonl"])).unwrap();
        let c = ServeConfig::from_flags(&flags).unwrap();
        assert!(c.trace);
        assert_eq!(c.trace_out, "out/run.jsonl");
        // Off by default — the byte-identical discipline's anchor.
        assert!(!ServeConfig::default().trace);
    }

    #[test]
    fn watchdog_flags_parse() {
        let (_, flags) =
            parse_args(&s(&["--watchdog", "--watchdog-timeout-ms", "250"])).unwrap();
        let c = ServeConfig::from_flags(&flags).unwrap();
        assert!(c.watchdog);
        assert_eq!(c.watchdog_timeout_ms, 250);
        let w = c.make_watchdog_config();
        assert!(w.enabled);
        assert_eq!(w.reply_timeout, std::time::Duration::from_millis(250));
        // Off by default, and the default timeouts survive a bare --watchdog.
        let d = ServeConfig::default().make_watchdog_config();
        assert!(!d.enabled);
        assert_eq!(d.reply_timeout, std::time::Duration::from_secs(5));
    }

    #[test]
    fn recover_flags_parse_and_stay_off_by_default() {
        let (_, flags) = parse_args(&s(&[
            "--watchdog",
            "--recover",
            "--rejoin-attempts",
            "5",
            "--rejoin-backoff-ms",
            "200",
            "--max-step-err-streak",
            "8",
            "--stranded-sweep-iters",
            "50",
        ]))
        .unwrap();
        let c = ServeConfig::from_flags(&flags).unwrap();
        let w = c.make_watchdog_config();
        assert!(w.enabled && w.recover);
        assert_eq!(w.max_rejoin_attempts, 5);
        assert_eq!(w.rejoin_backoff, std::time::Duration::from_millis(200));
        assert_eq!(w.max_step_err_streak, 8);
        assert_eq!(w.stranded_sweep_iters, 50);
        // Off by default, with the PR-6 defaults intact — the
        // byte-identical discipline's anchor.
        let d = ServeConfig::default().make_watchdog_config();
        assert!(!d.recover);
        assert_eq!(d.max_rejoin_attempts, 3);
        assert_eq!(d.max_step_err_streak, 32);
        assert_eq!(d.stranded_sweep_iters, 1_000);
        // --recover without --watchdog is rejected by validation.
        let (_, f) = parse_args(&s(&["--recover"])).unwrap();
        let w = ServeConfig::from_flags(&f).unwrap().make_watchdog_config();
        assert!(w.validate(std::time::Duration::from_secs(30)).is_err());
    }

    #[test]
    fn overlap_flag_parses_and_stays_off_by_default() {
        let (_, flags) = parse_args(&s(&["--overlap"])).unwrap();
        let c = ServeConfig::from_flags(&flags).unwrap();
        assert!(c.overlap);
        let o = c.make_overlap_config();
        assert!(o.enabled && o.double_buffer_on() && o.async_migrate_on() && o.co_issue_on());
        // Off by default — the byte-identical discipline's anchor.
        let d = ServeConfig::default().make_overlap_config();
        assert!(!d.enabled && !d.double_buffer_on() && !d.async_migrate_on() && !d.co_issue_on());
    }

    #[test]
    fn prefix_cache_flag_parses_and_stays_off_by_default() {
        let (_, flags) = parse_args(&s(&["--prefix-cache"])).unwrap();
        assert!(ServeConfig::from_flags(&flags).unwrap().prefix_cache);
        // Off by default — the byte-identical discipline's anchor.
        assert!(!ServeConfig::default().prefix_cache);
    }

    #[test]
    fn unknown_flag_rejected() {
        let (_, flags) = parse_args(&s(&["--bogus", "1"])).unwrap();
        assert!(ServeConfig::from_flags(&flags).is_err());
    }
}
