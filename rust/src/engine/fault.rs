//! Deterministic fault injection for the stub engine (ISSUE 6).
//!
//! A [`FaultPlan`] scripts one engine's misbehavior in terms of its own
//! *executed command count* ("steps"): stall windows, a permanent slowdown,
//! dropped replies, and permanent death.  Plans are plain data — seeded,
//! per-engine, and replayable — so every chaos-test failure reproduces from
//! `(seed, engine_id)` alone.
//!
//! Death and dropped replies cannot be expressed as ordinary backend
//! errors (an `EngineReply::Err` is still a reply, and the lockstep
//! coordinator would stay perfectly healthy).  They are signalled through
//! the sentinel error types [`EngineDown`] / [`DropReply`], which the
//! worker loop in `engine/mod.rs` downcasts: `EngineDown` makes the worker
//! thread exit without replying (the reply channel disconnects, exactly
//! like a crashed process), `DropReply` swallows exactly one reply (the
//! coordinator sees silence and must ride it out or escalate).

use std::time::Duration;

use crate::util::rng::Rng;

/// Nominal per-step execution time charged by the stub when a slow-step
/// multiplier is active.  The stub's real step cost is sub-microsecond, so
/// a multiplicative slowdown needs a baseline to multiply.
pub const STUB_NOMINAL_STEP_S: f64 = 0.002;

/// Sentinel: the engine dies permanently — the worker thread exits without
/// sending a reply, so the coordinator observes a channel disconnect.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineDown;

impl std::fmt::Display for EngineDown {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "engine killed by fault plan")
    }
}

impl std::error::Error for EngineDown {}

/// Sentinel: the command's reply is dropped on the floor — the worker
/// keeps running but sends nothing, so the coordinator observes silence
/// for exactly one in-flight command.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DropReply;

impl std::fmt::Display for DropReply {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "engine reply dropped by fault plan")
    }
}

impl std::error::Error for DropReply {}

/// Scripted misbehavior for one engine, indexed by that engine's executed
/// command count (every `EngineCmd` the worker runs advances the clock by
/// one, whatever its kind).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Steps in `[stall_at, stall_at + stall_steps)` sleep `stall_s`
    /// seconds before executing — a transient stall the watchdog should
    /// ride out within its retry budget.
    pub stall_at: Option<u64>,
    pub stall_steps: u64,
    pub stall_s: f64,
    /// From this step on, every command is slowed to
    /// `slow_mult × STUB_NOMINAL_STEP_S` — permanent execution skew.
    pub slow_from: Option<u64>,
    pub slow_mult: f64,
    /// Steps whose reply is dropped (executed or not, the coordinator
    /// never hears back for that command).
    pub drop_reply_at: Vec<u64>,
    /// The engine dies permanently at this step: the worker thread exits
    /// and its channels disconnect.
    pub die_at: Option<u64>,
    /// Revive phase (ISSUE 8): `Some(k)` marks the `die_at` death
    /// *transient* — a recovery-armed coordinator may respawn the engine
    /// with [`FaultPlan::revive_plan`].  The respawned incarnation is
    /// healthy when `k == 0`; otherwise it dies again after `k` executed
    /// commands (crash-loop modeling — the bounded rejoin budget must
    /// re-escalate to permanent fail-stop).  Ignored entirely when
    /// recovery is off, so the field's presence is behavior-invariant on
    /// the PR-6 degradation path.
    pub revive_after: Option<u64>,
}

impl FaultPlan {
    /// A plan that injects nothing (the default).
    pub fn none() -> Self {
        FaultPlan::default()
    }

    /// True when the plan injects nothing — the gate's fast path.
    /// `revive_after` is deliberately excluded: without a `die_at` it is
    /// inert, and with one the plan is already non-none.
    pub fn is_none(&self) -> bool {
        self.stall_at.is_none()
            && self.slow_from.is_none()
            && self.drop_reply_at.is_empty()
            && self.die_at.is_none()
    }

    /// True when the plan's death (if any) is declared transient — the
    /// coordinator's rejoin eligibility test (ISSUE 8).
    pub fn revivable(&self) -> bool {
        self.die_at.is_some() && self.revive_after.is_some()
    }

    /// The respawned incarnation's plan after a transient death: healthy
    /// for `revive_after == Some(0)`, otherwise a crash-looping clone that
    /// dies again after that many executed commands (and stays revivable,
    /// so only the coordinator's attempt budget ends the loop).
    pub fn revive_plan(&self) -> FaultPlan {
        match self.revive_after {
            Some(k) if k > 0 => FaultPlan {
                die_at: Some(k),
                revive_after: Some(k),
                ..FaultPlan::none()
            },
            _ => FaultPlan::none(),
        }
    }

    /// Seeded randomized plan for one engine.  Fault probabilities are
    /// tuned so a small cluster usually sees one or two fault kinds per
    /// run and occasionally a fully healthy or fully dead engine — the
    /// chaos harness must survive all of it.  Stall durations stay well
    /// under typical chaos-test communicator timeouts so transient stalls
    /// are distinguishable from death.
    pub fn randomized(seed: u64, engine_id: usize) -> Self {
        let mut rng = Rng::new(seed ^ (engine_id as u64).wrapping_mul(0x9e3779b97f4a7c15));
        let mut plan = FaultPlan::default();
        if rng.bool(0.35) {
            plan.stall_at = Some(rng.range(2, 80));
            plan.stall_steps = rng.range(1, 3);
            plan.stall_s = rng.uniform(0.02, 0.08);
        }
        if rng.bool(0.3) {
            plan.slow_from = Some(rng.range(2, 120));
            plan.slow_mult = rng.uniform(2.0, 6.0);
        }
        if rng.bool(0.25) {
            plan.drop_reply_at = vec![rng.range(2, 80)];
        }
        if rng.bool(0.25) {
            plan.die_at = Some(rng.range(3, 160));
            // Half the deaths are transient (ISSUE 8): a recovery-armed
            // run revives them into a healthy incarnation; with recovery
            // off the marker is inert and the death stays permanent.
            if rng.bool(0.5) {
                plan.revive_after = Some(0);
            }
        }
        plan
    }
}

/// Per-engine fault clock: owns the plan plus the executed-command count,
/// and turns both into concrete actions at each step.
#[derive(Clone, Debug, Default)]
pub struct FaultClock {
    plan: FaultPlan,
    step: u64,
}

impl FaultClock {
    pub fn new(plan: FaultPlan) -> Self {
        FaultClock { plan, step: 0 }
    }

    /// Advance the clock by one executed command and apply the plan:
    /// sleeps for stall/slow windows, `Err(EngineDown)` at death,
    /// `Err(DropReply)` for dropped-reply steps.
    pub fn tick(&mut self) -> anyhow::Result<()> {
        if self.plan.is_none() {
            return Ok(());
        }
        let step = self.step;
        self.step += 1;
        if let Some(k) = self.plan.die_at {
            if step >= k {
                return Err(anyhow::Error::new(EngineDown));
            }
        }
        if let Some(at) = self.plan.stall_at {
            if step >= at && step < at + self.plan.stall_steps && self.plan.stall_s > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(self.plan.stall_s));
            }
        }
        if let Some(from) = self.plan.slow_from {
            if step >= from && self.plan.slow_mult > 1.0 {
                std::thread::sleep(Duration::from_secs_f64(
                    self.plan.slow_mult * STUB_NOMINAL_STEP_S,
                ));
            }
        }
        if self.plan.drop_reply_at.iter().any(|&d| d == step) {
            return Err(anyhow::Error::new(DropReply));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_never_faults() {
        let mut clock = FaultClock::new(FaultPlan::none());
        for _ in 0..1000 {
            clock.tick().unwrap();
        }
    }

    #[test]
    fn death_is_permanent_from_its_step() {
        let mut clock = FaultClock::new(FaultPlan { die_at: Some(3), ..FaultPlan::none() });
        for _ in 0..3 {
            clock.tick().unwrap();
        }
        for _ in 0..5 {
            let e = clock.tick().unwrap_err();
            assert!(e.is::<EngineDown>());
        }
    }

    #[test]
    fn dropped_reply_hits_exactly_its_step() {
        let mut clock =
            FaultClock::new(FaultPlan { drop_reply_at: vec![2], ..FaultPlan::none() });
        clock.tick().unwrap();
        clock.tick().unwrap();
        assert!(clock.tick().unwrap_err().is::<DropReply>());
        clock.tick().unwrap();
    }

    #[test]
    fn revive_plan_models_healthy_and_crash_loop_incarnations() {
        // No revive marker: permanent death, not revivable.
        let permanent = FaultPlan { die_at: Some(5), ..FaultPlan::none() };
        assert!(!permanent.revivable());
        // Healthy revive: next incarnation injects nothing.
        let transient = FaultPlan {
            die_at: Some(5),
            revive_after: Some(0),
            ..FaultPlan::none()
        };
        assert!(transient.revivable());
        assert!(transient.revive_plan().is_none());
        // Crash loop: next incarnation dies again and stays revivable.
        let looping = FaultPlan {
            die_at: Some(5),
            revive_after: Some(2),
            ..FaultPlan::none()
        };
        let next = looping.revive_plan();
        assert_eq!(next.die_at, Some(2));
        assert!(next.revivable());
        assert_eq!(next.revive_plan().die_at, Some(2));
        // The marker alone (no death) is inert.
        let inert = FaultPlan { revive_after: Some(0), ..FaultPlan::none() };
        assert!(inert.is_none());
        assert!(!inert.revivable());
    }

    #[test]
    fn randomized_is_deterministic_per_seed_and_engine() {
        let a = FaultPlan::randomized(7, 2);
        let b = FaultPlan::randomized(7, 2);
        assert_eq!(a, b);
        // Engines under the same seed get independent plans (some seed will
        // collide on "no faults at all"; 7/0 vs 7/1 differ).
        let plans: Vec<FaultPlan> = (0..8).map(|e| FaultPlan::randomized(7, e)).collect();
        assert!(plans.windows(2).any(|w| w[0] != w[1]));
    }
}
