//! EngineCore: the single-threaded execution state of one DP engine — the
//! paper's "fundamental DP instance" (§3).  It owns:
//!
//!  * one full weight replica, uploaded to device buffers exactly once
//!    (Model Weights Manager invariant, §4.1);
//!  * per-layer host KV pools whose physical bytes never move; the KV Cache
//!    Adaptor's slot ids decide where new rows land (§4.2);
//!  * the compiled executables for every (phase, TP degree), so switching
//!    mode never compiles or loads anything (§4.3's eager-init philosophy
//!    applied to executables as well).
//!
//! `set_mode` — the target of the scheduler's `set_TP_mode`/`reset_TP_mode`
//! collective RPC (Algorithm 1, step 5) — is two field writes.  That is the
//! entire engine-side cost of a DP<->TP switch, measured in the Table-2
//! bench.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::comm::CommunicatorPool;
use crate::model::{StaticShapes, WeightStore};
use crate::runtime::{ArtifactSpec, DynInputs, EngineBuffers, Manifest, Runtime, StepOutputs};

use super::{DecodeSlot, PrefillChunk};

pub struct EngineCore {
    pub id: usize,
    pub model: String,
    rt: Runtime,
    bufs: EngineBuffers,
    ws: Arc<WeightStore>,
    pub shapes: StaticShapes,
    exes: std::collections::BTreeMap<String, (xla::PjRtLoadedExecutable, ArtifactSpec)>,
    pub k_pools: Vec<Vec<f32>>,
    pub v_pools: Vec<Vec<f32>>,
    comm: Arc<CommunicatorPool>,
    /// Current mode: TP degree p (1 = independent DP engine).
    pub mode_p: usize,
    /// Persistent dyn-input arenas for the fused DP fast path: refilled in
    /// place every step (clear + resize keeps capacity), so a warm engine
    /// assembles its step inputs without heap allocation.
    dec_dyns: DynInputs,
    pre_dyns: DynInputs,
    slots_scratch: Vec<u32>,
}

impl EngineCore {
    /// Build one engine: create its PJRT client (PjRtClient is !Send, so
    /// this must run on the engine's own thread), upload weights, compile
    /// every artifact eagerly.
    pub fn new(
        id: usize,
        manifest: &Manifest,
        model: &str,
        ws: Arc<WeightStore>,
        comm: Arc<CommunicatorPool>,
    ) -> Result<Self> {
        let rt = Runtime::cpu()?;
        let mm = manifest.model(model)?;
        let bufs = EngineBuffers::upload(&rt.client, &ws)?;
        let mut exes = std::collections::BTreeMap::new();
        for (name, spec) in &mm.artifacts {
            let exe = rt.compile(spec)?;
            exes.insert(name.clone(), (exe, spec.clone()));
        }
        let cfg = &mm.cfg;
        let pool = vec![0f32; cfg.pool_elems];
        Ok(EngineCore {
            id,
            model: model.to_string(),
            rt,
            bufs,
            ws,
            shapes: manifest.shapes,
            exes,
            k_pools: vec![pool.clone(); cfg.n_layers],
            v_pools: vec![pool; cfg.n_layers],
            comm,
            mode_p: 1,
            dec_dyns: DynInputs::new(),
            pre_dyns: DynInputs::new(),
            slots_scratch: Vec::new(),
        })
    }

    pub fn cfg(&self) -> &crate::model::ModelCfg {
        &self.ws.cfg
    }

    /// The engine-side mode switch: O(1), no weight or KV movement.
    /// (`rank` is implicit: the engine's global id within its aligned group.)
    pub fn set_mode(&mut self, p: usize) -> Result<()> {
        if !self.cfg().supports_tp(p) {
            bail!("model {} does not support TP degree {p}", self.model);
        }
        self.mode_p = p;
        Ok(())
    }

    fn exe(&self, name: &str) -> Result<(&xla::PjRtLoadedExecutable, &ArtifactSpec)> {
        self.exes
            .get(name)
            .map(|(e, s)| (e, s))
            .ok_or_else(|| anyhow::anyhow!("engine {}: no artifact '{name}'", self.id))
    }

    /// Scatter new KV rows (one per batch slot/chunk token) into the host
    /// pools at the adaptor's slot ids — the authoritative KV write.
    /// Writes straight from the step outputs; no intermediate copies.
    fn apply_kv_outputs(&mut self, out: &StepOutputs, p: usize, slots: &[u32], layer_hint: usize) {
        let cfg = &self.ws.cfg;
        let w = (cfg.n_kv_heads / p) * cfg.d_head;
        for (l, k_new, v_new) in &out.kv_new {
            let layer = if *l < 0 { layer_hint } else { *l as usize };
            debug_assert_eq!(k_new.len(), slots.len() * w);
            let kp = &mut self.k_pools[layer];
            let vp = &mut self.v_pools[layer];
            for (i, &s) in slots.iter().enumerate() {
                let dst = s as usize * w;
                kp[dst..dst + w].copy_from_slice(&k_new[i * w..(i + 1) * w]);
                vp[dst..dst + w].copy_from_slice(&v_new[i * w..(i + 1) * w]);
            }
        }
    }

    // ------------------------------------------------------------------
    // DP fast path: fused all-layer executables (p = 1).
    // ------------------------------------------------------------------

    /// One fused DP decode step over up to `b_dec` slots.  Returns the
    /// logits rows for the occupied slots (row i ↔ batch[i]).
    ///
    /// Step inputs are assembled into the engine's persistent arenas —
    /// zero heap allocation once warm (the PJRT upload/readback boundary
    /// still owns its own buffers).
    pub fn dp_decode(&mut self, batch: &[DecodeSlot]) -> Result<Vec<Vec<f32>>> {
        let b = self.shapes.b_dec;
        anyhow::ensure!(batch.len() <= b, "batch too large");
        let n_blocks = self.ws.cfg.n_blocks;
        let bt = self.ws.cfg.block_tokens(1);
        let vocab = self.ws.cfg.vocab;
        {
            let slots = &mut self.slots_scratch;
            slots.clear();
            // Padded slots write into the trash block (slot i % bt).
            slots.extend((0..b).map(|i| (i % bt) as u32));
            let d = &mut self.dec_dyns;
            let tokens = d.i32_mut("tokens");
            tokens.clear();
            tokens.resize(b, 0);
            for (i, s) in batch.iter().enumerate() {
                tokens[i] = s.token;
            }
            let positions = d.i32_mut("positions");
            positions.clear();
            positions.resize(b, 0);
            for (i, s) in batch.iter().enumerate() {
                positions[i] = s.pos as i32;
            }
            let seq_lens = d.i32_mut("seq_lens");
            seq_lens.clear();
            seq_lens.resize(b, 0);
            for (i, s) in batch.iter().enumerate() {
                seq_lens[i] = s.pos as i32 + 1;
            }
            let tables = d.i32_mut("block_tables");
            tables.clear();
            tables.resize(b * n_blocks, 0);
            for (i, s) in batch.iter().enumerate() {
                tables[i * n_blocks..(i + 1) * n_blocks].copy_from_slice(&s.table_row);
                slots[i] = s.slot_id;
            }
            let slot_ids = d.i32_mut("slot_ids");
            slot_ids.clear();
            slot_ids.extend(slots.iter().map(|&s| s as i32));
        }
        let (exe, spec) = self.exe("dp_decode")?;
        let out = self
            .rt
            .execute(exe, spec, &self.bufs, &self.dec_dyns, 0, &self.k_pools, &self.v_pools)?;
        let slots = std::mem::take(&mut self.slots_scratch);
        self.apply_kv_outputs(&out, 1, &slots, 0);
        self.slots_scratch = slots;
        Ok(batch
            .iter()
            .enumerate()
            .map(|(i, _)| out.primary[i * vocab..(i + 1) * vocab].to_vec())
            .collect())
    }

    /// One fused DP prefill chunk.  Returns logits of the chunk's last
    /// actual token.
    pub fn dp_prefill(&mut self, chunk: &PrefillChunk) -> Result<Vec<f32>> {
        let c = self.shapes.c_prefill;
        let nv = chunk.tokens.len();
        anyhow::ensure!(nv >= 1 && nv <= c, "chunk size {nv}");
        anyhow::ensure!(chunk.slot_ids.len() == nv, "slot ids / tokens mismatch");
        let bt = self.ws.cfg.block_tokens(1);
        let vocab = self.ws.cfg.vocab;
        {
            let slots = &mut self.slots_scratch;
            slots.clear();
            slots.extend((0..c).map(|i| (i % bt) as u32));
            let d = &mut self.pre_dyns;
            let tokens = d.i32_mut("tokens");
            tokens.clear();
            tokens.resize(c, 0);
            tokens[..nv].copy_from_slice(&chunk.tokens);
            let positions = d.i32_mut("positions");
            positions.clear();
            positions.resize(c, 0);
            for i in 0..nv {
                positions[i] = (chunk.start + i) as i32;
                slots[i] = chunk.slot_ids[i];
            }
            let slot_ids = d.i32_mut("slot_ids");
            slot_ids.clear();
            slot_ids.extend(slots.iter().map(|&s| s as i32));
            let table = d.i32_mut("block_table");
            table.clear();
            table.extend_from_slice(&chunk.table_row);
            let start = d.i32_mut("start");
            start.clear();
            start.push(chunk.start as i32);
            let seq_len = d.i32_mut("seq_len");
            seq_len.clear();
            seq_len.push((chunk.start + nv) as i32);
        }
        let (exe, spec) = self.exe("dp_prefill")?;
        let out = self
            .rt
            .execute(exe, spec, &self.bufs, &self.pre_dyns, 0, &self.k_pools, &self.v_pools)?;
        let slots = std::mem::take(&mut self.slots_scratch);
        self.apply_kv_outputs(&out, 1, &slots, 0);
        self.slots_scratch = slots;
        Ok(out.primary[(nv - 1) * vocab..nv * vocab].to_vec())
    }

    // ------------------------------------------------------------------
    // TP shard path: per-layer executables + all-reduce through the
    // Communicator Pool.  All group members run these with identical dyn
    // inputs (the scheduler's globally-agreed order guarantees it).
    // ------------------------------------------------------------------

    fn all_reduce(&self, p: usize, data: &mut [f32]) -> Result<()> {
        let group = self.comm.group_of(self.id, p)?;
        group.all_reduce_sum(self.id, data)?;
        Ok(())
    }

    /// One TP decode step for this rank.  Returns logits rows (identical on
    /// every rank; the coordinator reads rank 0's).
    pub fn tp_decode(&mut self, p: usize, batch: &[DecodeSlot]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(self.mode_p == p, "engine {} not in TP-{p} mode", self.id);
        let b = self.shapes.b_dec;
        let cfg = self.cfg().clone();
        let bt = cfg.block_tokens(p);
        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        let mut seq_lens = vec![0i32; b];
        let mut slots: Vec<u32> = (0..b).map(|i| (i % bt) as u32).collect();
        let mut tables = vec![0i32; b * cfg.n_blocks];
        for (i, s) in batch.iter().enumerate() {
            tokens[i] = s.token;
            positions[i] = s.pos as i32;
            seq_lens[i] = s.pos as i32 + 1;
            slots[i] = s.slot_id;
            tables[i * cfg.n_blocks..(i + 1) * cfg.n_blocks].copy_from_slice(&s.table_row);
        }
        // Host-side embedding gather (replicated, identical on all ranks).
        let mut x = self.ws.embed(&tokens)?;
        let rank_in_group = self.id % p;

        for layer in 0..cfg.n_layers {
            let dyns = DynInputs::new()
                .f32("x", x.clone())
                .i32("block_tables", tables.clone())
                .i32("slot_ids", slots.iter().map(|&s| s as i32).collect())
                .i32("positions", positions.clone())
                .i32("seq_lens", seq_lens.clone())
                .i32("rank", vec![rank_in_group as i32]);
            let (exe, spec) = self.exe(&format!("attn_decode_tp{p}"))?;
            let out =
                self.rt
                    .execute(exe, spec, &self.bufs, &dyns, layer, &self.k_pools, &self.v_pools)?;
            self.apply_kv_outputs(&out, p, &slots, layer);
            let mut partial = out.primary;
            self.all_reduce(p, &mut partial)?; // sync #1 (post-attention)
            for (xi, pi) in x.iter_mut().zip(&partial) {
                *xi += *pi;
            }

            let dyns = DynInputs::new()
                .f32("x", x.clone())
                .i32("rank", vec![rank_in_group as i32]);
            let (exe, spec) = self.exe(&format!("ffn_decode_tp{p}"))?;
            let out =
                self.rt
                    .execute(exe, spec, &self.bufs, &dyns, layer, &self.k_pools, &self.v_pools)?;
            let mut partial = out.primary;
            self.all_reduce(p, &mut partial)?; // sync #2 (post-FFN)
            for (xi, pi) in x.iter_mut().zip(&partial) {
                *xi += *pi;
            }
        }

        let dyns = DynInputs::new().f32("x", x);
        let (exe, spec) = self.exe("lmhead_dec")?;
        let out = self
            .rt
            .execute(exe, spec, &self.bufs, &dyns, 0, &self.k_pools, &self.v_pools)?;
        let v = cfg.vocab;
        Ok(batch
            .iter()
            .enumerate()
            .map(|(i, _)| out.primary[i * v..(i + 1) * v].to_vec())
            .collect())
    }

    /// One TP prefill chunk for this rank.  Returns last-token logits.
    pub fn tp_prefill(&mut self, p: usize, chunk: &PrefillChunk) -> Result<Vec<f32>> {
        anyhow::ensure!(self.mode_p == p, "engine {} not in TP-{p} mode", self.id);
        let c = self.shapes.c_prefill;
        let nv = chunk.tokens.len();
        anyhow::ensure!(nv >= 1 && nv <= c, "chunk size {nv}");
        let cfg = self.cfg().clone();
        let bt = cfg.block_tokens(p);
        let mut tokens = vec![0i32; c];
        tokens[..nv].copy_from_slice(&chunk.tokens);
        let mut positions = vec![0i32; c];
        let mut slots: Vec<u32> = (0..c).map(|i| (i % bt) as u32).collect();
        for i in 0..nv {
            positions[i] = (chunk.start + i) as i32;
            slots[i] = chunk.slot_ids[i];
        }
        let mut x = self.ws.embed(&tokens)?;
        let rank_in_group = self.id % p;

        for layer in 0..cfg.n_layers {
            let dyns = DynInputs::new()
                .f32("x", x.clone())
                .i32("block_table", chunk.table_row.clone())
                .i32("slot_ids", slots.iter().map(|&s| s as i32).collect())
                .i32("positions", positions.clone())
                .i32("start", vec![chunk.start as i32])
                .i32("seq_len", vec![(chunk.start + nv) as i32])
                .i32("rank", vec![rank_in_group as i32]);
            let (exe, spec) = self.exe(&format!("attn_prefill_tp{p}"))?;
            let out =
                self.rt
                    .execute(exe, spec, &self.bufs, &dyns, layer, &self.k_pools, &self.v_pools)?;
            self.apply_kv_outputs(&out, p, &slots, layer);
            let mut partial = out.primary;
            self.all_reduce(p, &mut partial)?;
            for (xi, pi) in x.iter_mut().zip(&partial) {
                *xi += *pi;
            }

            let dyns = DynInputs::new()
                .f32("x", x.clone())
                .i32("rank", vec![rank_in_group as i32]);
            let (exe, spec) = self.exe(&format!("ffn_prefill_tp{p}"))?;
            let out =
                self.rt
                    .execute(exe, spec, &self.bufs, &dyns, layer, &self.k_pools, &self.v_pools)?;
            let mut partial = out.primary;
            self.all_reduce(p, &mut partial)?;
            for (xi, pi) in x.iter_mut().zip(&partial) {
                *xi += *pi;
            }
        }

        let dyns = DynInputs::new().f32("x", x);
        let (exe, spec) = self.exe("lmhead_pre")?;
        let out = self
            .rt
            .execute(exe, spec, &self.bufs, &dyns, 0, &self.k_pools, &self.v_pools)?;
        let v = cfg.vocab;
        Ok(out.primary[(nv - 1) * v..nv * v].to_vec())
    }
}

impl super::EngineBackend for EngineCore {
    fn set_mode(&mut self, p: usize) -> Result<()> {
        EngineCore::set_mode(self, p)
    }

    fn dp_decode(&mut self, batch: &[DecodeSlot]) -> Result<Vec<Vec<f32>>> {
        EngineCore::dp_decode(self, batch)
    }

    fn dp_prefill(&mut self, chunk: &PrefillChunk) -> Result<Vec<f32>> {
        EngineCore::dp_prefill(self, chunk)
    }

    fn tp_decode(&mut self, p: usize, batch: &[DecodeSlot]) -> Result<Vec<Vec<f32>>> {
        EngineCore::tp_decode(self, p, batch)
    }

    fn tp_prefill(&mut self, p: usize, chunk: &PrefillChunk) -> Result<Vec<f32>> {
        EngineCore::tp_prefill(self, p, chunk)
    }

    fn migrate_kv(&mut self, p: usize, root: usize, n_elems: usize) -> Result<()> {
        // KV-migration data plane (ISSUE 4): the root's re-tagged pool
        // already holds every member's slice (Eq. 2 keeps block bytes
        // layout-invariant), so the scatter distributes the other ranks'
        // head slices through the pre-built communicator.  The repro's KV
        // pools are host-resident f32 vectors and the command carries only
        // the byte volume, so this models the transfer (correct volume,
        // correct synchronization) without placing the bytes; block-
        // granular placement needs the slot table threaded through the
        // command — extend this alongside the TP engine-path arena work
        // (ROADMAP open item) once a PJRT environment exists to verify
        // against.
        if !self.cfg().supports_tp(p) {
            bail!("model {} does not support TP degree {p}", self.model);
        }
        if self.mode_p != p {
            bail!("engine {} not in TP-{p} mode for kv migration", self.id);
        }
        if p == 1 {
            return Ok(());
        }
        let group = self.comm.group_of(self.id, p)?;
        let send: Vec<f32> = if self.id == root {
            let total = p * n_elems;
            let mut v = vec![0f32; total];
            if let Some(kp) = self.k_pools.first() {
                let take = total.min(kp.len());
                v[..take].copy_from_slice(&kp[..take]);
            }
            v
        } else {
            Vec::new()
        };
        let mut recv = Vec::new();
        group.scatter_into(self.id, root, &send, &mut recv)?;
        anyhow::ensure!(
            recv.len() == n_elems,
            "engine {}: migration slice {} != {n_elems}",
            self.id,
            recv.len()
        );
        // The received slice is deliberately NOT written into the pools
        // yet: without the request's slot table there is no correct
        // destination, and writing to any fixed region would corrupt
        // resident requests' live KV.  The staged buffer is dropped; the
        // coordinator's adaptor metadata stays authoritative until the
        // slot-aware placement lands (see the comment above).
        Ok(())
    }
}
