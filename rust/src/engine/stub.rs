//! Deterministic stub execution backend: the full engine-worker contract
//! (modes, lockstep TP collectives, logits shapes) with a hash-based token
//! function instead of real kernels.
//!
//! Purpose-built for two jobs the PJRT core can't do in CI:
//!
//!  * run the *entire* coordinator/scheduler path — binding, KV adaptor
//!    parameterization, group formation, preemption, collectives — in plain
//!    `cargo test` with no artifacts or PJRT plugin;
//!  * give the `sched_hotpath` bench a data plane whose cost is negligible,
//!    so allocation/throughput measurements isolate the scheduler itself.
//!
//! The next-token function depends only on (fed token, position), never on
//! the TP degree, rank, or engine id — so the paper's key invariant
//! (DP and TP emit identical greedy tokens, switching is transparent to
//! outputs) holds for the stub exactly as it must for the real kernels,
//! and the stub-driven integration tests can assert it.

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::comm::CommunicatorPool;
use crate::model::{ModelCfg, StaticShapes};

use super::fault::{FaultClock, FaultPlan};
use super::{DecodeSlot, EngineBackend, PrefillChunk};

/// Deterministic pseudo-logits argmax target for a fed (token, position).
/// Stays inside the byte vocab [0, 256) so greedy decoding never emits the
/// EOS id and output lengths are fully controlled by `max_new`.
fn next_token(token: i32, pos: usize) -> usize {
    let mut z = (token as u64)
        .wrapping_mul(0x9e3779b97f4a7c15)
        .wrapping_add((pos as u64).wrapping_mul(0xbf58476d1ce4e5b9));
    z = (z ^ (z >> 30)).wrapping_mul(0x94d049bb133111eb);
    z = z ^ (z >> 27);
    (z % 256) as usize
}

pub struct StubEngine {
    pub id: usize,
    cfg: ModelCfg,
    shapes: StaticShapes,
    comm: Arc<CommunicatorPool>,
    mode_p: usize,
    /// Reused collective buffer: TP steps synchronize through the real
    /// communicator pool so group lockstep (and its failure modes) are
    /// exercised, allocation-free.
    reduce_scratch: Vec<f32>,
    /// Reused staging buffers for the KV-migration scatter (root-side
    /// payload / member-side received slice).
    migrate_send: Vec<f32>,
    migrate_recv: Vec<f32>,
    /// Scripted-fault clock (ISSUE 6); an empty plan is a no-op.
    fault: FaultClock,
}

impl StubEngine {
    pub fn new(
        id: usize,
        cfg: ModelCfg,
        shapes: StaticShapes,
        comm: Arc<CommunicatorPool>,
    ) -> Self {
        Self::with_faults(id, cfg, shapes, comm, FaultPlan::none())
    }

    /// Stub backend with a scripted fault plan.  Every executed command
    /// (SetMode, steps, migration) advances the plan's step clock by one.
    pub fn with_faults(
        id: usize,
        cfg: ModelCfg,
        shapes: StaticShapes,
        comm: Arc<CommunicatorPool>,
        plan: FaultPlan,
    ) -> Self {
        StubEngine {
            id,
            cfg,
            shapes,
            comm,
            mode_p: 1,
            reduce_scratch: vec![0.0; 8],
            migrate_send: Vec::new(),
            migrate_recv: Vec::new(),
            fault: FaultClock::new(plan),
        }
    }

    fn logits_row(&self, token: i32, pos: usize) -> Vec<f32> {
        let mut row = vec![0.0f32; self.cfg.vocab];
        row[next_token(token, pos) % self.cfg.vocab] = 1.0;
        row
    }

    /// Meet the group in a (tiny) all-reduce: same safe-point semantics and
    /// watchdog behavior as the real per-layer collectives.
    fn tp_sync(&mut self, p: usize) -> Result<()> {
        let group = self.comm.group_of(self.id, p)?;
        for x in self.reduce_scratch.iter_mut() {
            *x = 1.0;
        }
        group.all_reduce_sum(self.id, &mut self.reduce_scratch)?;
        Ok(())
    }

    /// Ungated decode body, shared by the DP and TP entry points (the
    /// fault clock ticks once per *command*, not per helper call).
    fn decode_rows(&self, batch: &[DecodeSlot]) -> Result<Vec<Vec<f32>>> {
        ensure!(batch.len() <= self.shapes.b_dec, "batch too large");
        Ok(batch.iter().map(|s| self.logits_row(s.token, s.pos)).collect())
    }

    fn prefill_last(&self, chunk: &PrefillChunk) -> Result<Vec<f32>> {
        let nv = chunk.tokens.len();
        ensure!(nv >= 1 && nv <= self.shapes.c_prefill, "chunk size {nv}");
        ensure!(chunk.slot_ids.len() == nv, "slot ids / tokens mismatch");
        let last = *chunk.tokens.last().unwrap();
        Ok(self.logits_row(last, chunk.start + nv - 1))
    }
}

impl EngineBackend for StubEngine {
    fn set_mode(&mut self, p: usize) -> Result<()> {
        self.fault.tick()?;
        if !self.cfg.supports_tp(p) {
            bail!("model {} does not support TP degree {p}", self.cfg.name);
        }
        self.mode_p = p;
        Ok(())
    }

    fn dp_decode(&mut self, batch: &[DecodeSlot]) -> Result<Vec<Vec<f32>>> {
        self.fault.tick()?;
        self.decode_rows(batch)
    }

    fn dp_prefill(&mut self, chunk: &PrefillChunk) -> Result<Vec<f32>> {
        self.fault.tick()?;
        self.prefill_last(chunk)
    }

    fn tp_decode(&mut self, p: usize, batch: &[DecodeSlot]) -> Result<Vec<Vec<f32>>> {
        self.fault.tick()?;
        ensure!(self.mode_p == p, "engine {} not in TP-{p} mode", self.id);
        self.tp_sync(p)?;
        self.decode_rows(batch)
    }

    fn tp_prefill(&mut self, p: usize, chunk: &PrefillChunk) -> Result<Vec<f32>> {
        self.fault.tick()?;
        ensure!(self.mode_p == p, "engine {} not in TP-{p} mode", self.id);
        self.tp_sync(p)?;
        self.prefill_last(chunk)
    }

    fn migrate_kv(&mut self, p: usize, root: usize, n_elems: usize) -> Result<()> {
        self.fault.tick()?;
        ensure!(
            self.mode_p == p,
            "engine {} not in TP-{p} mode for kv migration",
            self.id
        );
        if p == 1 {
            return Ok(());
        }
        let group = self.comm.group_of(self.id, p)?;
        // The stub holds no real KV bytes (logits are a pure function of the
        // fed token/position), so the payload is synthetic — what this
        // exercises is the real data-plane mechanism: every member meeting
        // the same scatter at the same safe point, watchdog included.
        self.migrate_send.clear();
        if self.id == root {
            self.migrate_send.resize(p * n_elems, 0.0);
            for (i, x) in self.migrate_send.iter_mut().enumerate() {
                *x = (i % 251) as f32;
            }
        }
        group.scatter_into(self.id, root, &self.migrate_send, &mut self.migrate_recv)?;
        ensure!(
            self.migrate_recv.len() == n_elems,
            "engine {}: migration slice {} != {n_elems}",
            self.id,
            self.migrate_recv.len()
        );
        Ok(())
    }

    fn co_step(
        &mut self,
        chunk: &PrefillChunk,
        batch: &[DecodeSlot],
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        // One envelope = one command: tick the fault clock once, then run
        // the ungated helpers (the trait default would tick twice through
        // the gated entry points, breaking the per-command step-clock
        // contract scripted fault plans rely on).
        self.fault.tick()?;
        let last = self.prefill_last(chunk)?;
        let rows = self.decode_rows(batch)?;
        Ok((last, rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_token_is_deterministic_and_byte_ranged() {
        for (tok, pos) in [(0, 0), (255, 17), (42, 9999)] {
            let a = next_token(tok, pos);
            assert_eq!(a, next_token(tok, pos));
            assert!(a < 256);
        }
        // Not constant.
        assert_ne!(next_token(1, 0), next_token(2, 0));
    }
}
