//! Engine workers: one OS thread per DP engine (the paper's per-GPU engine
//! process), driven by the coordinator over bounded channels (the control
//! plane; paper uses Gloo pipes).
//!
//! The execution substrate is abstracted behind [`EngineBackend`]:
//!
//!  * `core::EngineCore` (behind the `pjrt` feature) runs the real compiled
//!    XLA artifacts.  `PjRtClient` is `!Send`, so the core — client, device
//!    buffers, compiled executables — is constructed *inside* the worker
//!    thread and never leaves it.
//!  * `stub::StubEngine` is a deterministic, dependency-free backend with
//!    the same lockstep/collective behavior, used by CI tests and the
//!    scheduler benches where no PJRT plugin exists.
//!
//! Hot-path discipline: commands carry `Arc`-shared batches so the
//! coordinator can recycle its step buffers (`Arc::make_mut` reuses the
//! allocation once the engine's clone is dropped, which the lockstep
//! protocol guarantees by reply time), and each worker owns one pair of
//! *persistent* bounded channels — no per-call channel construction, no
//! per-send queue-node allocation.

#[cfg(feature = "pjrt")]
pub mod core;
pub mod fault;
pub mod stub;

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

pub use fault::FaultPlan;
pub use stub::StubEngine;

/// One decode slot: a request with its adaptor-derived addressing.
#[derive(Clone, Debug, Default)]
pub struct DecodeSlot {
    pub rid: u64,
    pub token: i32,
    pub pos: usize,          // 0-based index of `token` (its kv appends here)
    pub slot_id: u32,        // flat write slot from the adaptor
    pub table_row: Vec<i32>, // padded to n_blocks
}

/// One prefill chunk of a single request.
#[derive(Clone, Debug, Default)]
pub struct PrefillChunk {
    pub rid: u64,
    pub tokens: Vec<i32>,    // <= c_prefill actual tokens
    pub start: usize,        // absolute position of tokens[0]
    pub slot_ids: Vec<u32>,  // one per actual token
    pub table_row: Vec<i32>, // padded to n_blocks
}

/// The engine-side execution contract (Algorithm 1 step ⑥ plus the SetMode
/// collective RPC of step ⑤).  Implementations are constructed on the
/// worker thread and need not be `Send`.
pub trait EngineBackend {
    fn set_mode(&mut self, p: usize) -> Result<()>;
    /// One fused DP decode step; returns one logits row per batch slot.
    fn dp_decode(&mut self, batch: &[DecodeSlot]) -> Result<Vec<Vec<f32>>>;
    /// One fused DP prefill chunk; returns the last actual token's logits.
    fn dp_prefill(&mut self, chunk: &PrefillChunk) -> Result<Vec<f32>>;
    /// One TP decode step for this rank (meets the group in collectives).
    fn tp_decode(&mut self, p: usize, batch: &[DecodeSlot]) -> Result<Vec<Vec<f32>>>;
    fn tp_prefill(&mut self, p: usize, chunk: &PrefillChunk) -> Result<Vec<f32>>;
    /// KV-migration data plane (ISSUE 4): meet the p-wide group in a
    /// scatter that distributes `n_elems` f32 slice elements from `root`'s
    /// re-tagged KV to every other member.  Issued to all members at the
    /// same safe point, like the TP step commands.
    fn migrate_kv(&mut self, p: usize, root: usize, n_elems: usize) -> Result<()>;
    /// Prefill/decode co-issue (ISSUE 9, `--overlap` only): execute one DP
    /// prefill chunk *and* one DP decode batch from a single command
    /// envelope, returning `(last_logits, decode_rows)`.  The default
    /// serializes the two existing entry points — numerically identical to
    /// issuing them as two commands — so backends gain interleaving by
    /// overriding, never by obligation.
    fn co_step(
        &mut self,
        chunk: &PrefillChunk,
        batch: &[DecodeSlot],
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let last = self.dp_prefill(chunk)?;
        let rows = self.dp_decode(batch)?;
        Ok((last, rows))
    }
}

#[derive(Debug)]
pub enum EngineCmd {
    /// Algorithm-1 step 5: atomically configure the execution mode.
    SetMode { p: usize },
    /// One fused DP step (p must be 1).
    DpDecode { batch: Arc<Vec<DecodeSlot>> },
    DpPrefill { chunk: Arc<PrefillChunk> },
    /// One TP shard step; all group members receive this at the same safe
    /// point and meet in the communicator's collectives.
    TpDecode { p: usize, batch: Arc<Vec<DecodeSlot>> },
    TpPrefill { p: usize, chunk: Arc<PrefillChunk> },
    /// Layout-preserving KV migration (ISSUE 4): every member of the p-wide
    /// group receives this at the same safe point; the `root` rank scatters
    /// the other members' shard slices (`n_elems` f32 each) through the
    /// pre-built communicator.
    KvMigrate { p: usize, root: usize, n_elems: usize },
    /// Prefill/decode co-issue (ISSUE 9, `--overlap` only): one DP prefill
    /// chunk and one DP decode batch in a single envelope — one command,
    /// one reply, one fault-clock tick — so the backend can interleave
    /// them.
    CoIssue { chunk: Arc<PrefillChunk>, batch: Arc<Vec<DecodeSlot>> },
    Stop,
}

#[derive(Debug)]
pub enum EngineReply {
    Ok,
    /// Per-slot logits rows (decode).
    Logits(Vec<Vec<f32>>),
    /// Last-token logits (prefill chunk).
    LastLogits(Vec<f32>),
    /// Co-issued prefill + decode (ISSUE 9): the chunk's last-token logits
    /// and the batch's per-slot rows, in one reply.
    CoStep { last: Vec<f32>, rows: Vec<Vec<f32>> },
    Err(String),
}

/// Depth of the per-engine command/reply rings.  The coordinator issues at
/// most one in-flight command per engine (lockstep), so 2 gives slack for
/// the Stop handshake without unbounded buffering.
const CHANNEL_DEPTH: usize = 2;

pub struct EngineHandle {
    pub id: usize,
    /// Incarnation counter (ISSUE 8): 0 for the original spawn, bumped by
    /// the coordinator on every fail-recover respawn.  Stale replies from a
    /// dead incarnation are *structurally* impossible — each spawn owns a
    /// fresh channel pair, and replacing the handle drops the old receiver —
    /// so the generation is identity for journals, thread names, and tests,
    /// not a filtering mechanism.
    pub generation: u32,
    tx: SyncSender<EngineCmd>,
    rx: Receiver<EngineReply>,
    join: Option<JoinHandle<()>>,
}

impl EngineHandle {
    /// Spawn a worker thread around a backend built *on that thread* by
    /// `factory` (PJRT clients are `!Send`).  Blocks until the backend
    /// finished initializing (eager init, so mode switches never compile or
    /// load anything).
    pub fn spawn_with<B, F>(id: usize, factory: F) -> Result<Self>
    where
        B: EngineBackend + 'static,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        Self::spawn_with_gen(id, 0, factory)
    }

    /// [`Self::spawn_with`] for a later incarnation of a revived engine:
    /// generation `g > 0` names the thread `engine-{id}g{g}` so journals and
    /// stack dumps distinguish incarnations; generation 0 keeps the original
    /// `engine-{id}` name byte-identical.
    pub fn spawn_with_gen<B, F>(id: usize, generation: u32, factory: F) -> Result<Self>
    where
        B: EngineBackend + 'static,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let (tx, cmd_rx) = sync_channel::<EngineCmd>(CHANNEL_DEPTH);
        let (reply_tx, rx) = sync_channel::<EngineReply>(CHANNEL_DEPTH);
        let (ready_tx, ready_rx) = sync_channel::<Result<(), String>>(1);
        let name = if generation == 0 {
            format!("engine-{id}")
        } else {
            format!("engine-{id}g{generation}")
        };
        let join = std::thread::Builder::new()
            .name(name)
            .spawn(move || {
                let mut backend = match factory() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                while let Ok(cmd) = cmd_rx.recv() {
                    let resp = match cmd {
                        EngineCmd::SetMode { p } => {
                            backend.set_mode(p).map(|()| EngineReply::Ok)
                        }
                        EngineCmd::DpDecode { batch } => {
                            backend.dp_decode(&batch).map(EngineReply::Logits)
                        }
                        EngineCmd::DpPrefill { chunk } => {
                            backend.dp_prefill(&chunk).map(EngineReply::LastLogits)
                        }
                        EngineCmd::TpDecode { p, batch } => {
                            backend.tp_decode(p, &batch).map(EngineReply::Logits)
                        }
                        EngineCmd::TpPrefill { p, chunk } => {
                            backend.tp_prefill(p, &chunk).map(EngineReply::LastLogits)
                        }
                        EngineCmd::KvMigrate { p, root, n_elems } => {
                            backend.migrate_kv(p, root, n_elems).map(|()| EngineReply::Ok)
                        }
                        EngineCmd::CoIssue { chunk, batch } => backend
                            .co_step(&chunk, &batch)
                            .map(|(last, rows)| EngineReply::CoStep { last, rows }),
                        EngineCmd::Stop => {
                            let _ = reply_tx.send(EngineReply::Ok);
                            break;
                        }
                    };
                    let resp = match resp {
                        Ok(r) => r,
                        // Injected death: exit without replying — the reply
                        // channel disconnects like a crashed process.
                        Err(e) if e.is::<fault::EngineDown>() => break,
                        // Injected reply loss: swallow exactly this reply.
                        Err(e) if e.is::<fault::DropReply>() => continue,
                        Err(e) => EngineReply::Err(format!("{e:#}")),
                    };
                    let _ = reply_tx.send(resp);
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine {id} thread died during init"))?
            .map_err(|e| anyhow::anyhow!("engine {id} init failed: {e}"))?;
        Ok(EngineHandle { id, generation, tx, rx, join: Some(join) })
    }

    /// Spawn a worker over the real PJRT execution core.
    #[cfg(feature = "pjrt")]
    pub fn spawn(
        id: usize,
        manifest: Arc<crate::runtime::Manifest>,
        model: String,
        ws: Arc<crate::model::WeightStore>,
        comm: Arc<crate::comm::CommunicatorPool>,
    ) -> Result<Self> {
        Self::spawn_with(id, move || core::EngineCore::new(id, &manifest, &model, ws, comm))
    }

    /// Spawn a worker over the deterministic stub backend (no PJRT).
    pub fn spawn_stub(
        id: usize,
        cfg: crate::model::ModelCfg,
        shapes: crate::model::StaticShapes,
        comm: Arc<crate::comm::CommunicatorPool>,
    ) -> Result<Self> {
        Self::spawn_with(id, move || Ok(StubEngine::new(id, cfg, shapes, comm)))
    }

    /// Spawn a stub worker carrying a scripted [`FaultPlan`] (ISSUE 6).
    /// An empty plan behaves exactly like [`Self::spawn_stub`].
    pub fn spawn_stub_faulty(
        id: usize,
        cfg: crate::model::ModelCfg,
        shapes: crate::model::StaticShapes,
        comm: Arc<crate::comm::CommunicatorPool>,
        plan: FaultPlan,
    ) -> Result<Self> {
        Self::spawn_with(id, move || Ok(StubEngine::with_faults(id, cfg, shapes, comm, plan)))
    }

    /// Respawn a stub worker as incarnation `generation` of engine `id`
    /// (ISSUE 8 revive).  Fresh backend, fresh channels, fresh fault plan —
    /// the crashed incarnation's state is gone, exactly like an engine
    /// process restart.
    pub fn respawn_stub_faulty(
        id: usize,
        generation: u32,
        cfg: crate::model::ModelCfg,
        shapes: crate::model::StaticShapes,
        comm: Arc<crate::comm::CommunicatorPool>,
        plan: FaultPlan,
    ) -> Result<Self> {
        Self::spawn_with_gen(id, generation, move || {
            Ok(StubEngine::with_faults(id, cfg, shapes, comm, plan))
        })
    }

    /// Fire a command without waiting for its reply.  Used to launch a
    /// whole TP group concurrently so members can meet in the collectives;
    /// pair every `send` with exactly one [`Self::recv`].
    pub fn send(&self, cmd: EngineCmd) {
        // A send failure means the worker died; the paired recv surfaces it.
        let _ = self.tx.send(cmd);
    }

    /// Receive the reply for the oldest in-flight command.
    pub fn recv(&self) -> Result<EngineReply> {
        self.rx.recv().map_err(|_| {
            anyhow::Error::new(crate::error::ServeError::EngineFault {
                engine: self.id,
                kind: crate::error::FaultKind::Disconnected,
            })
        })
    }

    /// Deadline-bounded receive — the lockstep watchdog's primitive.  The
    /// caller owns retry/backoff/escalation policy; this just exposes the
    /// channel's timed wait.
    pub fn recv_timeout(
        &self,
        timeout: Duration,
    ) -> std::result::Result<EngineReply, RecvTimeoutError> {
        self.rx.recv_timeout(timeout)
    }

    /// Synchronous call.
    pub fn call(&self, cmd: EngineCmd) -> Result<EngineReply> {
        self.send(cmd);
        match self.recv()? {
            EngineReply::Err(e) => anyhow::bail!("engine {}: {e}", self.id),
            r => Ok(r),
        }
    }

    pub fn stop(&mut self) {
        let _ = self.call(EngineCmd::Stop);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        if self.join.is_some() {
            self.stop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommunicatorPool;
    use crate::model::{ModelCfg, StaticShapes};
    use std::time::Duration;

    fn cfg() -> ModelCfg {
        ModelCfg {
            name: "stub".into(),
            d_model: 32,
            n_layers: 2,
            n_heads: 8,
            n_kv_heads: 4,
            d_head: 8,
            ffn_hidden: 48,
            n_experts: 0,
            top_k: 0,
            n_blocks: 16,
            block_base: 4,
            max_ctx: 256,
            vocab: 258,
            pool_elems: 16 * 4 * 4 * 8,
        }
    }

    fn shapes() -> StaticShapes {
        StaticShapes { b_dec: 4, c_prefill: 16 }
    }

    #[test]
    fn stub_worker_roundtrip_and_modes() {
        let comm = Arc::new(CommunicatorPool::new(2, &[1, 2], Duration::from_secs(2)));
        let eng = EngineHandle::spawn_stub(0, cfg(), shapes(), comm).unwrap();
        assert!(matches!(eng.call(EngineCmd::SetMode { p: 2 }).unwrap(), EngineReply::Ok));
        assert!(matches!(eng.call(EngineCmd::SetMode { p: 1 }).unwrap(), EngineReply::Ok));
        // Unsupported degree surfaces as an error, not a hang.
        assert!(eng.call(EngineCmd::SetMode { p: 3 }).is_err());
    }

    #[test]
    fn stub_dp_decode_is_deterministic() {
        let comm = Arc::new(CommunicatorPool::new(1, &[1], Duration::from_secs(2)));
        let eng = EngineHandle::spawn_stub(0, cfg(), shapes(), comm).unwrap();
        let slot = DecodeSlot {
            rid: 1,
            token: 42,
            pos: 3,
            slot_id: 12,
            table_row: vec![0; cfg().n_blocks],
        };
        let batch = Arc::new(vec![slot]);
        let a = match eng.call(EngineCmd::DpDecode { batch: batch.clone() }).unwrap() {
            EngineReply::Logits(rows) => rows,
            r => panic!("unexpected {r:?}"),
        };
        let b = match eng.call(EngineCmd::DpDecode { batch }).unwrap() {
            EngineReply::Logits(rows) => rows,
            r => panic!("unexpected {r:?}"),
        };
        assert_eq!(a, b);
        assert_eq!(a[0].len(), cfg().vocab);
    }

    #[test]
    fn stub_tp_pair_meets_in_collective() {
        // Two stub engines in TP-2 mode must both step without deadlock and
        // produce identical logits (replicated compute).
        let comm = Arc::new(CommunicatorPool::new(2, &[1, 2], Duration::from_secs(2)));
        let e0 = EngineHandle::spawn_stub(0, cfg(), shapes(), comm.clone()).unwrap();
        let e1 = EngineHandle::spawn_stub(1, cfg(), shapes(), comm).unwrap();
        e0.call(EngineCmd::SetMode { p: 2 }).unwrap();
        e1.call(EngineCmd::SetMode { p: 2 }).unwrap();
        let batch = Arc::new(vec![DecodeSlot {
            rid: 9,
            token: 7,
            pos: 0,
            slot_id: 4,
            table_row: vec![0; cfg().n_blocks],
        }]);
        e0.send(EngineCmd::TpDecode { p: 2, batch: batch.clone() });
        e1.send(EngineCmd::TpDecode { p: 2, batch });
        let r0 = match e0.recv().unwrap() {
            EngineReply::Logits(rows) => rows,
            r => panic!("unexpected {r:?}"),
        };
        let r1 = match e1.recv().unwrap() {
            EngineReply::Logits(rows) => rows,
            r => panic!("unexpected {r:?}"),
        };
        assert_eq!(r0, r1);
    }

    #[test]
    fn stub_pair_meets_in_kv_migration_scatter() {
        let comm = Arc::new(CommunicatorPool::new(2, &[1, 2], Duration::from_secs(2)));
        let e0 = EngineHandle::spawn_stub(0, cfg(), shapes(), comm.clone()).unwrap();
        let e1 = EngineHandle::spawn_stub(1, cfg(), shapes(), comm).unwrap();
        e0.call(EngineCmd::SetMode { p: 2 }).unwrap();
        e1.call(EngineCmd::SetMode { p: 2 }).unwrap();
        // Both members must be launched concurrently (they meet in the
        // scatter); root mid-command works like the TP step commands.
        e0.send(EngineCmd::KvMigrate { p: 2, root: 1, n_elems: 64 });
        e1.send(EngineCmd::KvMigrate { p: 2, root: 1, n_elems: 64 });
        assert!(matches!(e0.recv().unwrap(), EngineReply::Ok));
        assert!(matches!(e1.recv().unwrap(), EngineReply::Ok));
        // Wrong mode surfaces as an error, not a hang.
        e0.call(EngineCmd::SetMode { p: 1 }).unwrap();
        assert!(e0.call(EngineCmd::KvMigrate { p: 2, root: 0, n_elems: 8 }).is_err());
    }

    #[test]
    fn co_issue_equals_separate_prefill_and_decode() {
        // The envelope is a transport optimization: its outputs must be
        // byte-identical to issuing the same chunk and batch separately.
        let comm = Arc::new(CommunicatorPool::new(1, &[1], Duration::from_secs(2)));
        let eng = EngineHandle::spawn_stub(0, cfg(), shapes(), comm).unwrap();
        let chunk = Arc::new(PrefillChunk {
            rid: 3,
            tokens: vec![5, 6, 7],
            start: 0,
            slot_ids: vec![0, 1, 2],
            table_row: vec![0; cfg().n_blocks],
        });
        let batch = Arc::new(vec![DecodeSlot {
            rid: 1,
            token: 42,
            pos: 3,
            slot_id: 12,
            table_row: vec![0; cfg().n_blocks],
        }]);
        let sep_last = match eng.call(EngineCmd::DpPrefill { chunk: chunk.clone() }).unwrap() {
            EngineReply::LastLogits(l) => l,
            r => panic!("unexpected {r:?}"),
        };
        let sep_rows = match eng.call(EngineCmd::DpDecode { batch: batch.clone() }).unwrap() {
            EngineReply::Logits(rows) => rows,
            r => panic!("unexpected {r:?}"),
        };
        let (co_last, co_rows) = match eng.call(EngineCmd::CoIssue { chunk, batch }).unwrap() {
            EngineReply::CoStep { last, rows } => (last, rows),
            r => panic!("unexpected {r:?}"),
        };
        assert_eq!(co_last, sep_last);
        assert_eq!(co_rows, sep_rows);
    }

    #[test]
    fn co_issue_ticks_the_fault_clock_once() {
        // One envelope = one command for fault-injection purposes: a plan
        // that dies at command 1 survives command 0 even when command 0
        // carries both a prefill and a decode.
        let comm = Arc::new(CommunicatorPool::new(1, &[1], Duration::from_secs(2)));
        let plan = FaultPlan { die_at: Some(1), ..FaultPlan::none() };
        let mut eng = EngineHandle::spawn_stub_faulty(0, cfg(), shapes(), comm, plan).unwrap();
        let chunk = Arc::new(PrefillChunk {
            rid: 3,
            tokens: vec![5],
            start: 0,
            slot_ids: vec![0],
            table_row: vec![0; cfg().n_blocks],
        });
        let batch = Arc::new(vec![DecodeSlot {
            rid: 1,
            token: 2,
            pos: 1,
            slot_id: 4,
            table_row: vec![0; cfg().n_blocks],
        }]);
        assert!(matches!(
            eng.call(EngineCmd::CoIssue { chunk, batch }).unwrap(),
            EngineReply::CoStep { .. }
        ));
        // Command 1 is death: the channel disconnects without a reply.
        eng.send(EngineCmd::SetMode { p: 1 });
        assert!(eng.recv().is_err());
        eng.stop();
    }

    #[test]
    fn fault_death_disconnects_instead_of_replying() {
        let comm = Arc::new(CommunicatorPool::new(1, &[1], Duration::from_secs(2)));
        let plan = FaultPlan { die_at: Some(1), ..FaultPlan::none() };
        let mut eng = EngineHandle::spawn_stub_faulty(0, cfg(), shapes(), comm, plan).unwrap();
        // Step 0 executes normally.
        assert!(matches!(eng.call(EngineCmd::SetMode { p: 1 }).unwrap(), EngineReply::Ok));
        // Step 1 is death: no reply ever arrives; the channel disconnects.
        eng.send(EngineCmd::SetMode { p: 1 });
        let err = eng.recv().unwrap_err();
        assert!(err.downcast_ref::<crate::error::ServeError>().is_some());
        // recv_timeout on a dead engine reports Disconnected, not Timeout.
        assert!(matches!(
            eng.recv_timeout(Duration::from_millis(50)),
            Err(RecvTimeoutError::Disconnected)
        ));
        // The worker already exited; stop() must not hang.
        eng.stop();
    }

    #[test]
    fn respawn_replaces_a_dead_incarnation() {
        let comm = Arc::new(CommunicatorPool::new(1, &[1], Duration::from_secs(2)));
        let plan = FaultPlan { die_at: Some(0), ..FaultPlan::none() };
        let mut eng = EngineHandle::spawn_stub_faulty(0, cfg(), shapes(), comm.clone(), plan).unwrap();
        assert_eq!(eng.generation, 0);
        // First command is death; the channel disconnects.
        eng.send(EngineCmd::SetMode { p: 1 });
        assert!(eng.recv().is_err());
        // Replace the handle: fresh incarnation, fresh channels, healthy plan.
        eng = EngineHandle::respawn_stub_faulty(0, 1, cfg(), shapes(), comm, FaultPlan::none())
            .unwrap();
        assert_eq!(eng.generation, 1);
        assert!(matches!(eng.call(EngineCmd::SetMode { p: 1 }).unwrap(), EngineReply::Ok));
    }

    #[test]
    fn fault_dropped_reply_is_silence_then_recovery() {
        let comm = Arc::new(CommunicatorPool::new(1, &[1], Duration::from_secs(2)));
        let plan = FaultPlan { drop_reply_at: vec![1], ..FaultPlan::none() };
        let eng = EngineHandle::spawn_stub_faulty(0, cfg(), shapes(), comm, plan).unwrap();
        assert!(matches!(eng.call(EngineCmd::SetMode { p: 1 }).unwrap(), EngineReply::Ok));
        // Step 1's reply is dropped: a timed wait observes pure silence...
        eng.send(EngineCmd::SetMode { p: 1 });
        assert!(matches!(
            eng.recv_timeout(Duration::from_millis(100)),
            Err(RecvTimeoutError::Timeout)
        ));
        // ...but the worker survives and serves the next command normally.
        eng.send(EngineCmd::SetMode { p: 1 });
        assert!(matches!(
            eng.recv_timeout(Duration::from_secs(2)).unwrap(),
            EngineReply::Ok
        ));
    }

    #[test]
    fn arc_batch_is_exclusive_after_reply() {
        // The lockstep protocol promise behind the coordinator's
        // zero-allocation reuse: once the reply is in, the engine has
        // dropped its clone and Arc::get_mut succeeds.
        let comm = Arc::new(CommunicatorPool::new(1, &[1], Duration::from_secs(2)));
        let eng = EngineHandle::spawn_stub(0, cfg(), shapes(), comm).unwrap();
        let mut batch = Arc::new(vec![DecodeSlot {
            rid: 1,
            token: 1,
            pos: 0,
            slot_id: 4,
            table_row: vec![0; cfg().n_blocks],
        }]);
        for _ in 0..5 {
            eng.send(EngineCmd::DpDecode { batch: batch.clone() });
            eng.recv().unwrap();
            assert!(
                Arc::get_mut(&mut batch).is_some(),
                "engine retained the batch past its reply"
            );
        }
    }
}
