//! Engine workers: one OS thread per DP engine (the paper's per-GPU engine
//! process), driven by the coordinator over mpsc channels (the control
//! plane; paper uses Gloo pipes).
//!
//! `PjRtClient` is `!Send`, so the `EngineCore` — client, device buffers,
//! compiled executables — is constructed *inside* the worker thread and
//! never leaves it.  The channel protocol mirrors the paper's collective
//! RPCs: `SetMode` ("set_TP_mode"/"reset_TP_mode") and step execution
//! ("execute_model").

pub mod core;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::comm::CommunicatorPool;
use crate::model::WeightStore;
use crate::runtime::Manifest;
pub use core::{DecodeSlot, EngineCore, PrefillChunk};

#[derive(Debug)]
pub enum EngineCmd {
    /// Algorithm-1 step 5: atomically configure the execution mode.
    SetMode { p: usize },
    /// One fused DP step (p must be 1).
    DpDecode { batch: Vec<DecodeSlot> },
    DpPrefill { chunk: PrefillChunk },
    /// One TP shard step; all group members receive this at the same safe
    /// point and meet in the communicator's collectives.
    TpDecode { p: usize, batch: Vec<DecodeSlot> },
    TpPrefill { p: usize, chunk: PrefillChunk },
    Stop,
}

#[derive(Debug)]
pub enum EngineReply {
    Ok,
    /// Per-slot logits rows (decode).
    Logits(Vec<Vec<f32>>),
    /// Last-token logits (prefill chunk).
    LastLogits(Vec<f32>),
    Err(String),
}

pub struct EngineHandle {
    pub id: usize,
    tx: Sender<(EngineCmd, Sender<EngineReply>)>,
    join: Option<JoinHandle<()>>,
}

impl EngineHandle {
    /// Spawn the worker thread; blocks until the engine finished compiling
    /// its artifacts (eager init, so mode switches never compile anything).
    pub fn spawn(
        id: usize,
        manifest: Arc<Manifest>,
        model: String,
        ws: Arc<WeightStore>,
        comm: Arc<CommunicatorPool>,
    ) -> Result<Self> {
        let (tx, rx): (Sender<(EngineCmd, Sender<EngineReply>)>, Receiver<_>) = channel();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let join = std::thread::Builder::new()
            .name(format!("engine-{id}"))
            .spawn(move || {
                let mut core = match EngineCore::new(id, &manifest, &model, ws, comm) {
                    Ok(c) => {
                        let _ = ready_tx.send(Ok(()));
                        c
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                while let Ok((cmd, reply)) = rx.recv() {
                    let resp = match cmd {
                        EngineCmd::SetMode { p } => match core.set_mode(p) {
                            Ok(()) => EngineReply::Ok,
                            Err(e) => EngineReply::Err(format!("{e:#}")),
                        },
                        EngineCmd::DpDecode { batch } => match core.dp_decode(&batch) {
                            Ok(l) => EngineReply::Logits(l),
                            Err(e) => EngineReply::Err(format!("{e:#}")),
                        },
                        EngineCmd::DpPrefill { chunk } => match core.dp_prefill(&chunk) {
                            Ok(l) => EngineReply::LastLogits(l),
                            Err(e) => EngineReply::Err(format!("{e:#}")),
                        },
                        EngineCmd::TpDecode { p, batch } => match core.tp_decode(p, &batch) {
                            Ok(l) => EngineReply::Logits(l),
                            Err(e) => EngineReply::Err(format!("{e:#}")),
                        },
                        EngineCmd::TpPrefill { p, chunk } => match core.tp_prefill(p, &chunk) {
                            Ok(l) => EngineReply::LastLogits(l),
                            Err(e) => EngineReply::Err(format!("{e:#}")),
                        },
                        EngineCmd::Stop => {
                            let _ = reply.send(EngineReply::Ok);
                            break;
                        }
                    };
                    let _ = reply.send(resp);
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine {id} thread died during init"))?
            .map_err(|e| anyhow::anyhow!("engine {id} init failed: {e}"))?;
        Ok(EngineHandle { id, tx, join: Some(join) })
    }

    /// Fire a command without waiting (returns the reply receiver).  Used to
    /// launch a whole TP group concurrently so members can meet in the
    /// collectives.
    pub fn send(&self, cmd: EngineCmd) -> Receiver<EngineReply> {
        let (rtx, rrx) = channel();
        // A send failure means the worker died; the recv below surfaces it.
        let _ = self.tx.send((cmd, rtx));
        rrx
    }

    /// Synchronous call.
    pub fn call(&self, cmd: EngineCmd) -> Result<EngineReply> {
        let rx = self.send(cmd);
        match rx.recv() {
            Ok(EngineReply::Err(e)) => anyhow::bail!("engine {}: {e}", self.id),
            Ok(r) => Ok(r),
            Err(_) => anyhow::bail!("engine {} died", self.id),
        }
    }

    pub fn stop(&mut self) {
        let _ = self.call(EngineCmd::Stop);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        if self.join.is_some() {
            self.stop();
        }
    }
}
