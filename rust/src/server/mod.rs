//! TCP line-JSON serving frontend — the ProcessInputSocket of Algorithm 1
//! exposed over a real socket.
//!
//! Protocol (one JSON object per line):
//!   -> {"id": 1, "prompt": "hello", "max_new": 16,
//!       "priority": "high"?, "tp": 2?}
//!   <- {"id": 1, "text": "...", "tokens": [..], "ttft_ms": 12.3,
//!       "tpot_ms": 4.5}
//!
//! Prompts are byte-level tokenized (vocab = 256 bytes + BOS/EOS), matching
//! the synthetic-weight models.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use anyhow::{Context, Result};

use crate::coordinator::policy::Policy;
use crate::coordinator::strategy::Strategy;
use crate::coordinator::{Cluster, ServeRequest};
use crate::json::Value;
use crate::workload::Priority;

pub fn tokenize(s: &str) -> Vec<i32> {
    s.bytes().map(|b| b as i32).collect()
}

pub fn detokenize(tokens: &[i32]) -> String {
    tokens
        .iter()
        .filter(|&&t| (0..256).contains(&t))
        .map(|&t| t as u8 as char)
        .collect()
}

pub fn parse_request(line: &str, fallback_id: u64) -> Result<ServeRequest> {
    let v = Value::parse(line).map_err(|e| anyhow::anyhow!("{e}"))?;
    let id = v.get("id").and_then(|x| x.as_f64()).map(|x| x as u64).unwrap_or(fallback_id);
    let prompt = tokenize(v.str_field("prompt")?);
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    Ok(ServeRequest {
        id,
        prompt,
        max_new: v.get("max_new").and_then(|x| x.as_usize()).unwrap_or(16),
        priority: match v.get("priority").and_then(|x| x.as_str()) {
            Some("high") => Priority::High,
            _ => Priority::Normal,
        },
        tp_demand: v.get("tp").and_then(|x| x.as_usize()),
        arrival: 0.0,
    })
}

/// Error reply line: `{"id":..,"error":".."}`.  Sent for malformed or
/// rejected requests so the client can correlate the failure by id; the
/// connection stays open and later lines on it are still served.
pub fn error_json(id: u64, err: &str) -> String {
    Value::obj(vec![
        ("id", Value::num(id as f64)),
        ("error", Value::str(err)),
    ])
    .to_string()
}

/// Best-effort id recovery from a request line that failed validation: if
/// the line is valid JSON carrying a numeric `id`, the error reply echoes
/// it; otherwise the connection's next auto-assigned id stands in.
pub fn line_id(line: &str, fallback: u64) -> u64 {
    Value::parse(line)
        .ok()
        .and_then(|v| v.get("id").and_then(|x| x.as_f64()))
        .map(|x| x as u64)
        .unwrap_or(fallback)
}

pub fn response_json(id: u64, tokens: &[i32], ttft_ms: f64, tpot_ms: f64) -> String {
    Value::obj(vec![
        ("id", Value::num(id as f64)),
        ("text", Value::str(detokenize(tokens))),
        (
            "tokens",
            Value::Arr(tokens.iter().map(|&t| Value::num(t as f64)).collect()),
        ),
        ("ttft_ms", Value::num(ttft_ms)),
        ("tpot_ms", Value::num(tpot_ms)),
    ])
    .to_string()
}

/// Serve forever on `addr`.  Each connection may send multiple request
/// lines; responses are written back in completion order.
pub fn serve(
    cluster: &mut Cluster,
    policy: &mut dyn Policy,
    strategy: Strategy,
    addr: &str,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    crate::info!(
        "serving on {addr} (policy={}, strategy={})",
        policy.name(),
        strategy.name()
    );
    let mut next_id = 1u64;
    for stream in listener.incoming() {
        let stream = stream?;
        if let Err(e) = handle_conn(cluster, policy, strategy, stream, &mut next_id) {
            // A typed serving fault (ISSUE 6) means the cell itself can no
            // longer serve — an engine fail-stopped with the watchdog off,
            // or a coordinator channel closed.  Shut the frontend down
            // cleanly instead of accepting connections we cannot honor.
            // Anything else is a per-connection problem (client hung up,
            // bad socket): log and keep serving.
            if e.downcast_ref::<crate::error::ServeError>()
                .map(|se| se.is_fatal())
                .unwrap_or(false)
            {
                crate::info!("fatal serving error, shutting down: {e:#}");
                return Err(e);
            }
            crate::info!("connection error: {e:#}");
        }
    }
    Ok(())
}

fn handle_conn(
    cluster: &mut Cluster,
    policy: &mut dyn Policy,
    strategy: Strategy,
    stream: TcpStream,
    next_id: &mut u64,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        if line.trim().is_empty() {
            continue;
        }
        let req = match parse_request(line.trim(), *next_id) {
            Ok(r) => r,
            Err(e) => {
                writeln!(out, "{}", error_json(line_id(line.trim(), *next_id), &format!("{e:#}")))?;
                continue;
            }
        };
        *next_id = req.id.max(*next_id) + 1;
        let outcome = match cluster.run_trace(vec![req.clone()], policy, strategy) {
            Ok(o) => o,
            Err(e) => {
                // Tell the client its request died before propagating the
                // cluster error (best-effort: the connection may be gone).
                let _ = writeln!(out, "{}", error_json(req.id, "internal serving error"));
                return Err(e);
            }
        };
        let rec = outcome.recorder.get(req.id);
        let (ttft, tpot) = rec
            .map(|r| {
                (
                    r.ttft().unwrap_or(f64::NAN) * 1e3,
                    r.tpot().unwrap_or(f64::NAN) * 1e3,
                )
            })
            .unwrap_or((f64::NAN, f64::NAN));
        match outcome.outputs.get(&req.id) {
            Some(tokens) => writeln!(out, "{}", response_json(req.id, tokens, ttft, tpot))?,
            None => writeln!(out, "{}", error_json(req.id, "rejected (capacity)"))?,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenize_roundtrip() {
        let s = "Hello, FLYING!";
        assert_eq!(detokenize(&tokenize(s)), s);
    }

    #[test]
    fn parse_request_full() {
        let r = parse_request(
            r#"{"id": 7, "prompt": "hi", "max_new": 3, "priority": "high", "tp": 4}"#,
            0,
        )
        .unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, vec![104, 105]);
        assert_eq!(r.max_new, 3);
        assert_eq!(r.priority, Priority::High);
        assert_eq!(r.tp_demand, Some(4));
    }

    #[test]
    fn parse_request_defaults_and_errors() {
        let r = parse_request(r#"{"prompt": "x"}"#, 42).unwrap();
        assert_eq!(r.id, 42);
        assert_eq!(r.max_new, 16);
        assert_eq!(r.priority, Priority::Normal);
        assert!(parse_request(r#"{"prompt": ""}"#, 0).is_err());
        assert!(parse_request("not json", 0).is_err());
    }

    #[test]
    fn response_is_valid_json() {
        let s = response_json(3, &[104, 105], 1.5, 0.5);
        let v = Value::parse(&s).unwrap();
        assert_eq!(v.str_field("text").unwrap(), "hi");
        assert_eq!(v.f64_field("ttft_ms").unwrap(), 1.5);
    }

    #[test]
    fn error_reply_is_valid_json_with_id() {
        // The wire reply for a malformed line must be parseable and carry
        // both the id and the error message — the connection survives, so
        // the client needs the id to correlate.
        let s = error_json(9, "empty prompt");
        let v = Value::parse(&s).unwrap();
        assert_eq!(v.f64_field("id").unwrap(), 9.0);
        assert_eq!(v.str_field("error").unwrap(), "empty prompt");
        // Messages with JSON-hostile characters still serialize cleanly.
        let s = error_json(1, "bad \"quote\"\nline");
        assert!(Value::parse(&s).is_ok());
    }

    #[test]
    fn malformed_line_error_path_recovers_id() {
        // Valid JSON, invalid request (missing prompt): the id is echoed.
        let line = r#"{"id": 31, "max_new": 4}"#;
        assert!(parse_request(line, 7).is_err());
        assert_eq!(line_id(line, 7), 31);
        // Valid JSON, invalid request, no id: fallback id stands in.
        assert_eq!(line_id(r#"{"prompt": ""}"#, 7), 7);
        // Not JSON at all: fallback id.
        assert_eq!(line_id("not json {", 7), 7);
        // Full wire round-trip of the error path.
        let reply = error_json(line_id(line, 7), "missing json field 'prompt'");
        let v = Value::parse(&reply).unwrap();
        assert_eq!(v.f64_field("id").unwrap(), 31.0);
        assert!(v.str_field("error").unwrap().contains("prompt"));
    }
}
