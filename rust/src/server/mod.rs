//! TCP line-JSON serving frontend — the ProcessInputSocket of Algorithm 1
//! exposed over a real socket.
//!
//! Protocol (one JSON object per line):
//!   -> {"id": 1, "prompt": "hello", "max_new": 16,
//!       "priority": "high"?, "tp": 2?}
//!   <- {"id": 1, "text": "...", "tokens": [..], "ttft_ms": 12.3,
//!       "tpot_ms": 4.5}
//!
//! Prompts are byte-level tokenized (vocab = 256 bytes + BOS/EOS), matching
//! the synthetic-weight models.
//!
//! A line consisting of the bare word `metrics` (not JSON) is answered with
//! a Prometheus text exposition (ISSUE 7, [`metrics_text`]) instead of an
//! inference reply; the connection then keeps serving requests.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use anyhow::{Context, Result};

use crate::coordinator::policy::Policy;
use crate::coordinator::strategy::Strategy;
use crate::coordinator::{Cluster, ServeRequest};
use crate::json::Value;
use crate::workload::Priority;

pub fn tokenize(s: &str) -> Vec<i32> {
    s.bytes().map(|b| b as i32).collect()
}

pub fn detokenize(tokens: &[i32]) -> String {
    tokens
        .iter()
        .filter(|&&t| (0..256).contains(&t))
        .map(|&t| t as u8 as char)
        .collect()
}

pub fn parse_request(line: &str, fallback_id: u64) -> Result<ServeRequest> {
    let v = Value::parse(line).map_err(|e| anyhow::anyhow!("{e}"))?;
    let id = v.get("id").and_then(|x| x.as_f64()).map(|x| x as u64).unwrap_or(fallback_id);
    let prompt = tokenize(v.str_field("prompt")?);
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    Ok(ServeRequest {
        id,
        prompt,
        max_new: v.get("max_new").and_then(|x| x.as_usize()).unwrap_or(16),
        priority: match v.get("priority").and_then(|x| x.as_str()) {
            Some("high") => Priority::High,
            _ => Priority::Normal,
        },
        tp_demand: v.get("tp").and_then(|x| x.as_usize()),
        arrival: 0.0,
    })
}

/// Error reply line: `{"id":..,"error":".."}`.  Sent for malformed or
/// rejected requests so the client can correlate the failure by id; the
/// connection stays open and later lines on it are still served.
pub fn error_json(id: u64, err: &str) -> String {
    Value::obj(vec![
        ("id", Value::num(id as f64)),
        ("error", Value::str(err)),
    ])
    .to_string()
}

/// Best-effort id recovery from a request line that failed validation: if
/// the line is valid JSON carrying a numeric `id`, the error reply echoes
/// it; otherwise the connection's next auto-assigned id stands in.
pub fn line_id(line: &str, fallback: u64) -> u64 {
    Value::parse(line)
        .ok()
        .and_then(|v| v.get("id").and_then(|x| x.as_f64()))
        .map(|x| x as u64)
        .unwrap_or(fallback)
}

pub fn response_json(id: u64, tokens: &[i32], ttft_ms: f64, tpot_ms: f64) -> String {
    Value::obj(vec![
        ("id", Value::num(id as f64)),
        ("text", Value::str(detokenize(tokens))),
        (
            "tokens",
            Value::Arr(tokens.iter().map(|&t| Value::num(t as f64)).collect()),
        ),
        ("ttft_ms", Value::num(ttft_ms)),
        ("tpot_ms", Value::num(tpot_ms)),
    ])
    .to_string()
}

/// Frontend counters behind the `metrics` exposition, accumulated across
/// every connection of one `serve` call.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    /// Request lines that parsed and were submitted to the cluster.
    pub requests_total: u64,
    /// Tokens generated across all completed requests.
    pub tokens_total: u64,
    /// Requests the cluster rejected (capacity).
    pub rejected_total: u64,
    /// Malformed request lines answered with an error reply.
    pub bad_lines_total: u64,
    /// Mode switches executed while serving.
    pub switches_total: u64,
}

/// Render the Prometheus text exposition for the `metrics` request: the
/// frontend's serving counters plus the cluster's fault-tolerance stats.
/// Pure — unit tests exercise it without a socket.
pub fn metrics_text(s: &ServerStats, f: &crate::metrics::FaultStats) -> String {
    crate::obs::Exposition::new()
        .counter("flying_requests_total", "Request lines submitted to the cluster.", s.requests_total as f64)
        .counter("flying_tokens_total", "Tokens generated across completed requests.", s.tokens_total as f64)
        .counter("flying_rejected_total", "Requests rejected for capacity.", s.rejected_total as f64)
        .counter("flying_bad_lines_total", "Malformed request lines answered with an error.", s.bad_lines_total as f64)
        .counter("flying_switches_total", "Mode switches executed while serving.", s.switches_total as f64)
        .counter("flying_engine_faults_total", "Engines escalated to permanent fail-stop.", f.engine_faults as f64)
        .counter("flying_reply_timeouts_total", "Watchdog deadlines that exhausted retries.", f.reply_timeouts as f64)
        .counter("flying_stalls_ridden_out_total", "Late replies absorbed within the retry budget.", f.stalls_ridden_out as f64)
        .counter("flying_step_errors_total", "Degraded step errors absorbed by retry.", f.step_errors as f64)
        .counter("flying_requests_recovered_total", "Requests rescued off failed engines.", f.requests_recovered as f64)
        .counter("flying_requests_aborted_total", "Requests aborted after recovery exhaustion.", f.requests_aborted as f64)
        .counter("flying_engine_revives_total", "Failed engines respawned for rejoin.", f.engine_revives as f64)
        .counter("flying_rejoin_probes_total", "Probe steps issued to quarantined engines.", f.rejoin_probes as f64)
        .counter("flying_rejoins_ok_total", "Rejoins that healed capacity.", f.rejoins_ok as f64)
        .counter("flying_rejoins_abandoned_total", "Rejoins abandoned back to permanent fail-stop.", f.rejoins_abandoned as f64)
        .render()
}

/// Serve forever on `addr`.  Each connection may send multiple request
/// lines; responses are written back in completion order.
pub fn serve(
    cluster: &mut Cluster,
    policy: &mut dyn Policy,
    strategy: Strategy,
    addr: &str,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    crate::info!(
        "serving on {addr} (policy={}, strategy={})",
        policy.name(),
        strategy.name()
    );
    let mut next_id = 1u64;
    let mut stats = ServerStats::default();
    for stream in listener.incoming() {
        let stream = stream?;
        if let Err(e) = handle_conn(cluster, policy, strategy, stream, &mut next_id, &mut stats) {
            // A typed serving fault (ISSUE 6) means the cell itself can no
            // longer serve — an engine fail-stopped with the watchdog off,
            // or a coordinator channel closed.  Shut the frontend down
            // cleanly instead of accepting connections we cannot honor.
            // Anything else is a per-connection problem (client hung up,
            // bad socket): log and keep serving.
            if e.downcast_ref::<crate::error::ServeError>()
                .map(|se| se.is_fatal())
                .unwrap_or(false)
            {
                crate::info!("fatal serving error, shutting down: {e:#}");
                return Err(e);
            }
            crate::info!("connection error: {e:#}");
        }
    }
    Ok(())
}

fn handle_conn(
    cluster: &mut Cluster,
    policy: &mut dyn Policy,
    strategy: Strategy,
    stream: TcpStream,
    next_id: &mut u64,
    stats: &mut ServerStats,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        if line.trim().is_empty() {
            continue;
        }
        if line.trim() == "metrics" {
            // Exposition request: answer with the Prometheus text block and
            // keep the connection serving.  Checked before the JSON parse —
            // a bare word would otherwise be a malformed request.
            out.write_all(metrics_text(stats, &cluster.fault_stats()).as_bytes())?;
            continue;
        }
        let req = match parse_request(line.trim(), *next_id) {
            Ok(r) => r,
            Err(e) => {
                stats.bad_lines_total += 1;
                writeln!(out, "{}", error_json(line_id(line.trim(), *next_id), &format!("{e:#}")))?;
                continue;
            }
        };
        *next_id = req.id.max(*next_id) + 1;
        stats.requests_total += 1;
        let outcome = match cluster.run_trace(vec![req.clone()], policy, strategy) {
            Ok(o) => o,
            Err(e) => {
                // Tell the client its request died before propagating the
                // cluster error (best-effort: the connection may be gone).
                let _ = writeln!(out, "{}", error_json(req.id, "internal serving error"));
                return Err(e);
            }
        };
        let rec = outcome.recorder.get(req.id);
        let (ttft, tpot) = rec
            .map(|r| {
                (
                    r.ttft().unwrap_or(f64::NAN) * 1e3,
                    r.tpot().unwrap_or(f64::NAN) * 1e3,
                )
            })
            .unwrap_or((f64::NAN, f64::NAN));
        stats.switches_total += outcome.switches.len() as u64;
        match outcome.outputs.get(&req.id) {
            Some(tokens) => {
                stats.tokens_total += tokens.len() as u64;
                writeln!(out, "{}", response_json(req.id, tokens, ttft, tpot))?
            }
            None => {
                stats.rejected_total += 1;
                writeln!(out, "{}", error_json(req.id, "rejected (capacity)"))?
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenize_roundtrip() {
        let s = "Hello, FLYING!";
        assert_eq!(detokenize(&tokenize(s)), s);
    }

    #[test]
    fn parse_request_full() {
        let r = parse_request(
            r#"{"id": 7, "prompt": "hi", "max_new": 3, "priority": "high", "tp": 4}"#,
            0,
        )
        .unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, vec![104, 105]);
        assert_eq!(r.max_new, 3);
        assert_eq!(r.priority, Priority::High);
        assert_eq!(r.tp_demand, Some(4));
    }

    #[test]
    fn parse_request_defaults_and_errors() {
        let r = parse_request(r#"{"prompt": "x"}"#, 42).unwrap();
        assert_eq!(r.id, 42);
        assert_eq!(r.max_new, 16);
        assert_eq!(r.priority, Priority::Normal);
        assert!(parse_request(r#"{"prompt": ""}"#, 0).is_err());
        assert!(parse_request("not json", 0).is_err());
    }

    #[test]
    fn response_is_valid_json() {
        let s = response_json(3, &[104, 105], 1.5, 0.5);
        let v = Value::parse(&s).unwrap();
        assert_eq!(v.str_field("text").unwrap(), "hi");
        assert_eq!(v.f64_field("ttft_ms").unwrap(), 1.5);
    }

    #[test]
    fn error_reply_is_valid_json_with_id() {
        // The wire reply for a malformed line must be parseable and carry
        // both the id and the error message — the connection survives, so
        // the client needs the id to correlate.
        let s = error_json(9, "empty prompt");
        let v = Value::parse(&s).unwrap();
        assert_eq!(v.f64_field("id").unwrap(), 9.0);
        assert_eq!(v.str_field("error").unwrap(), "empty prompt");
        // Messages with JSON-hostile characters still serialize cleanly.
        let s = error_json(1, "bad \"quote\"\nline");
        assert!(Value::parse(&s).is_ok());
    }

    #[test]
    fn metrics_exposition_renders_all_counters() {
        let stats = ServerStats {
            requests_total: 12,
            tokens_total: 340,
            rejected_total: 2,
            bad_lines_total: 1,
            switches_total: 3,
        };
        let faults = crate::metrics::FaultStats {
            engine_faults: 1,
            reply_timeouts: 2,
            stalls_ridden_out: 4,
            step_errors: 5,
            requests_recovered: 6,
            requests_aborted: 0,
            engine_revives: 7,
            rejoin_probes: 8,
            rejoins_ok: 9,
            rejoins_abandoned: 1,
        };
        let text = metrics_text(&stats, &faults);
        // Prometheus text format: every family gets HELP + TYPE + a sample.
        for (name, val) in [
            ("flying_requests_total", 12),
            ("flying_tokens_total", 340),
            ("flying_rejected_total", 2),
            ("flying_bad_lines_total", 1),
            ("flying_switches_total", 3),
            ("flying_engine_faults_total", 1),
            ("flying_reply_timeouts_total", 2),
            ("flying_stalls_ridden_out_total", 4),
            ("flying_step_errors_total", 5),
            ("flying_requests_recovered_total", 6),
            ("flying_requests_aborted_total", 0),
            ("flying_engine_revives_total", 7),
            ("flying_rejoin_probes_total", 8),
            ("flying_rejoins_ok_total", 9),
            ("flying_rejoins_abandoned_total", 1),
        ] {
            assert!(text.contains(&format!("# TYPE {name} counter")), "{name} TYPE");
            assert!(text.contains(&format!("{name} {val}\n")), "{name} sample");
        }
    }

    #[test]
    fn malformed_line_error_path_recovers_id() {
        // Valid JSON, invalid request (missing prompt): the id is echoed.
        let line = r#"{"id": 31, "max_new": 4}"#;
        assert!(parse_request(line, 7).is_err());
        assert_eq!(line_id(line, 7), 31);
        // Valid JSON, invalid request, no id: fallback id stands in.
        assert_eq!(line_id(r#"{"prompt": ""}"#, 7), 7);
        // Not JSON at all: fallback id.
        assert_eq!(line_id("not json {", 7), 7);
        // Full wire round-trip of the error path.
        let reply = error_json(line_id(line, 7), "missing json field 'prompt'");
        let v = Value::parse(&reply).unwrap();
        assert_eq!(v.f64_field("id").unwrap(), 31.0);
        assert!(v.str_field("error").unwrap().contains("prompt"));
    }
}
