//! Serving metrics (paper §6.1.4): TTFT, TPOT, ILT, queue time, generation
//! throughput, plus the time-series views behind the Fig-8-style plots.
//!
//! One `Recorder` instance collects per-request event timestamps from either
//! the real coordinator or the discrete-event simulator (both report in
//! seconds on their own clock), and derives every metric the paper reports.

use std::collections::BTreeMap;

use crate::util::stats::{Percentiles, TimeSeries};
use crate::workload::Priority;

#[derive(Clone, Debug, Default)]
pub struct ReqRecord {
    pub arrival: f64,
    pub first_sched: Option<f64>, // first time the scheduler placed it
    pub token_times: Vec<f64>,    // emission time of each output token
    pub finished: Option<f64>,
    pub priority: Priority,
    pub prompt_len: usize,
}

impl ReqRecord {
    /// Time To First Token: arrival -> first output token (queuing+prefill).
    pub fn ttft(&self) -> Option<f64> {
        self.token_times.first().map(|t| t - self.arrival)
    }

    /// Queue time: admission -> first scheduling (§6.1.4 iv).
    pub fn queue_time(&self) -> Option<f64> {
        self.first_sched.map(|t| t - self.arrival)
    }

    /// Time Per Output Token: mean inter-token interval after the first.
    pub fn tpot(&self) -> Option<f64> {
        if self.token_times.len() < 2 {
            return None;
        }
        let n = self.token_times.len() - 1;
        Some((self.token_times[n] - self.token_times[0]) / n as f64)
    }

    /// Inter-Token Latency samples (consecutive gaps) — Fig 10 uses ILT
    /// because TPOT folds in queueing/batching effects.
    pub fn ilt_samples(&self) -> impl Iterator<Item = f64> + '_ {
        self.token_times.windows(2).map(|w| w[1] - w[0])
    }
}

/// Fault/recovery counters (ISSUE 6), reported per trace in
/// `ClusterOutcome::fault_stats`.  All zero on a fault-free run — the
/// faults-off differential gates assert exactly that.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Engines escalated to permanent fail-stop.
    pub engine_faults: usize,
    /// Watchdog deadlines that exhausted their retry budget.
    pub reply_timeouts: usize,
    /// Late replies that arrived within the retry budget (stall survived).
    pub stalls_ridden_out: usize,
    /// Error replies absorbed by retrying the step instead of bailing.
    pub step_errors: usize,
    /// Requests rescued off a failed engine and requeued for recompute.
    pub requests_recovered: usize,
    /// Requests rejected because their recovery budget ran out (or no
    /// capacity survived to place them).
    pub requests_aborted: usize,
    /// Failed engines respawned with a fresh backend and channels
    /// (ISSUE 8; counted per incarnation, paired with `engine_revive`).
    pub engine_revives: usize,
    /// Probe steps issued to quarantined engines (paired `rejoin_probe`).
    pub rejoin_probes: usize,
    /// Probes that succeeded — quarantine lifted, capacity healed
    /// (paired `rejoin_ok`).
    pub rejoins_ok: usize,
    /// Engines whose rejoin budget exhausted and re-escalated to
    /// permanent fail-stop (paired `rejoin_abandoned`).
    pub rejoins_abandoned: usize,
}

/// O(1) handle to a request's record, returned by [`Recorder::on_arrival`]
/// / [`Recorder::slot_of`].  Hot loops (the simulator's token emission, the
/// coordinator's step publication) record through slots so the per-token
/// path is an index into a dense slab, not an id-map lookup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecSlot(usize);

#[derive(Default)]
pub struct Recorder {
    /// Dense record storage, in arrival/insertion order.
    entries: Vec<ReqRecord>,
    /// rid -> slab index; also provides rid-ordered deterministic iteration.
    index: BTreeMap<u64, usize>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Slot for `rid`, creating an empty record if absent.
    pub fn slot_of(&mut self, rid: u64) -> RecSlot {
        if let Some(&i) = self.index.get(&rid) {
            return RecSlot(i);
        }
        let i = self.entries.len();
        self.entries.push(ReqRecord::default());
        self.index.insert(rid, i);
        RecSlot(i)
    }

    pub fn on_arrival(&mut self, rid: u64, t: f64, priority: Priority, prompt_len: usize) -> RecSlot {
        let s = self.slot_of(rid);
        let e = &mut self.entries[s.0];
        e.arrival = t;
        e.priority = priority;
        e.prompt_len = prompt_len;
        s
    }

    pub fn on_first_sched(&mut self, rid: u64, t: f64) {
        let s = self.slot_of(rid);
        self.on_first_sched_at(s, t);
    }

    pub fn on_token(&mut self, rid: u64, t: f64) {
        let s = self.slot_of(rid);
        self.on_token_at(s, t);
    }

    pub fn on_finish(&mut self, rid: u64, t: f64) {
        let s = self.slot_of(rid);
        self.on_finish_at(s, t);
    }

    // ---- slot fast paths (no id lookup) ----------------------------------

    pub fn on_first_sched_at(&mut self, s: RecSlot, t: f64) {
        let e = &mut self.entries[s.0];
        if e.first_sched.is_none() {
            e.first_sched = Some(t);
        }
    }

    #[inline]
    pub fn on_token_at(&mut self, s: RecSlot, t: f64) {
        self.entries[s.0].token_times.push(t);
    }

    pub fn on_finish_at(&mut self, s: RecSlot, t: f64) {
        self.entries[s.0].finished = Some(t);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, rid: u64) -> Option<&ReqRecord> {
        self.index.get(&rid).map(|&i| &self.entries[i])
    }

    /// Records in rid order (deterministic across runs).
    pub fn records(&self) -> impl Iterator<Item = (&u64, &ReqRecord)> {
        self.index.iter().map(|(rid, &i)| (rid, &self.entries[i]))
    }

    // ---- summaries -------------------------------------------------------

    fn filtered<'a>(
        &'a self,
        pri: Option<Priority>,
    ) -> impl Iterator<Item = &'a ReqRecord> + 'a {
        self.entries
            .iter()
            .filter(move |r| pri.map_or(true, |p| r.priority == p))
    }

    pub fn summary(&self, pri: Option<Priority>) -> Summary {
        let mut ttft = Percentiles::new();
        let mut tpot = Percentiles::new();
        let mut queue = Percentiles::new();
        let mut ilt = Percentiles::new();
        let mut finished = 0usize;
        for r in self.filtered(pri) {
            if let Some(x) = r.ttft() {
                ttft.add(x);
            }
            if let Some(x) = r.tpot() {
                tpot.add(x);
            }
            if let Some(x) = r.queue_time() {
                queue.add(x);
            }
            for x in r.ilt_samples() {
                ilt.add(x);
            }
            if r.finished.is_some() {
                finished += 1;
            }
        }
        Summary {
            n: self.filtered(pri).count(),
            finished,
            mean_ttft: ttft.mean(),
            p50_ttft: ttft.p50(),
            p90_ttft: ttft.p90(),
            mean_tpot: tpot.mean(),
            p50_tpot: tpot.p50(),
            mean_queue: queue.mean(),
            p90_queue: queue.p90(),
            mean_ilt: ilt.mean(),
            peak_throughput: self.peak_throughput(1.0),
        }
    }

    /// Peak generation throughput: max output tokens/s over fixed windows.
    pub fn peak_throughput(&self, window: f64) -> f64 {
        let mut ts = TimeSeries::new(window);
        for r in self.entries.iter() {
            for &t in &r.token_times {
                ts.add(t, 1.0);
            }
        }
        ts.counts()
            .into_iter()
            .map(|(_, c)| if c.is_nan() { 0.0 } else { c / window })
            .fold(0.0, f64::max)
    }

    /// Requests that finished with TTFT within their (per-request) SLO —
    /// the goodput numerator.  The SLO is a caller-supplied function of the
    /// record so length-proportional targets (long-context requests earn
    /// proportionally longer prefill budgets) are expressible.
    pub fn slo_attained(&self, slo: impl Fn(&ReqRecord) -> f64) -> usize {
        self.entries
            .iter()
            .filter(|r| r.finished.is_some() && r.ttft().is_some_and(|x| x <= slo(r)))
            .count()
    }

    /// Latest recorded timestamp (finish, token, or arrival) — the busy
    /// span's end, used as the goodput denominator.
    pub fn makespan(&self) -> f64 {
        self.entries
            .iter()
            .map(|r| {
                r.finished
                    .or_else(|| r.token_times.last().copied())
                    .unwrap_or(r.arrival)
            })
            .fold(0.0f64, f64::max)
    }

    /// Total mean generation throughput over the busy span.
    pub fn mean_throughput(&self) -> f64 {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        let mut n = 0usize;
        for r in self.entries.iter() {
            for &t in &r.token_times {
                lo = lo.min(t);
                hi = hi.max(t);
                n += 1;
            }
        }
        if n == 0 || hi <= lo {
            return 0.0;
        }
        n as f64 / (hi - lo)
    }

    // ---- time series (Fig 8) ----------------------------------------------

    /// In-flight concurrency sampled at `interval`.
    pub fn concurrency_series(&self, interval: f64) -> Vec<(f64, f64)> {
        let mut events: Vec<(f64, f64)> = Vec::new();
        for r in self.entries.iter() {
            let end = r
                .finished
                .or_else(|| r.token_times.last().copied())
                .unwrap_or(r.arrival);
            events.push((r.arrival, 1.0));
            events.push((end, -1.0));
        }
        events.sort_by(|a, b| a.0.total_cmp(&b.0));
        let t_end = events.last().map(|e| e.0).unwrap_or(0.0);
        let mut out = Vec::new();
        let mut level = 0.0;
        let mut i = 0;
        let mut t = 0.0;
        while t <= t_end {
            while i < events.len() && events[i].0 <= t {
                level += events[i].1;
                i += 1;
            }
            out.push((t, level));
            t += interval;
        }
        out
    }

    /// P90 TTFT bucketed by arrival time.
    pub fn ttft_p90_series(&self, interval: f64) -> Vec<(f64, f64)> {
        let mut ts = TimeSeries::new(interval);
        for r in self.entries.iter() {
            if let Some(x) = r.ttft() {
                ts.add(r.arrival, x);
            }
        }
        ts.p90s()
    }

    /// Mean queue time bucketed by arrival time.
    pub fn queue_series(&self, interval: f64) -> Vec<(f64, f64)> {
        let mut ts = TimeSeries::new(interval);
        for r in self.entries.iter() {
            if let Some(x) = r.queue_time() {
                ts.add(r.arrival, x);
            }
        }
        ts.means()
    }
}

#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub finished: usize,
    pub mean_ttft: f64,
    pub p50_ttft: f64,
    pub p90_ttft: f64,
    pub mean_tpot: f64,
    pub p50_tpot: f64,
    pub mean_queue: f64,
    pub p90_queue: f64,
    pub mean_ilt: f64,
    pub peak_throughput: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec_with_one_request() -> Recorder {
        let mut r = Recorder::new();
        r.on_arrival(1, 10.0, Priority::Normal, 100);
        r.on_first_sched(1, 10.5);
        for i in 0..5 {
            r.on_token(1, 11.0 + i as f64 * 0.1);
        }
        r.on_finish(1, 11.4);
        r
    }

    #[test]
    fn derives_paper_metrics() {
        let r = rec_with_one_request();
        let rec = r.get(1).unwrap();
        assert!((rec.ttft().unwrap() - 1.0).abs() < 1e-9);
        assert!((rec.queue_time().unwrap() - 0.5).abs() < 1e-9);
        assert!((rec.tpot().unwrap() - 0.1).abs() < 1e-9);
        let ilts: Vec<f64> = rec.ilt_samples().collect();
        assert_eq!(ilts.len(), 4);
        assert!(ilts.iter().all(|x| (x - 0.1).abs() < 1e-9));
    }

    #[test]
    fn first_sched_is_sticky() {
        let mut r = Recorder::new();
        r.on_arrival(1, 0.0, Priority::Normal, 1);
        r.on_first_sched(1, 2.0);
        r.on_first_sched(1, 5.0);
        assert_eq!(r.get(1).unwrap().queue_time(), Some(2.0));
    }

    #[test]
    fn summary_counts_and_priorities() {
        let mut r = rec_with_one_request();
        r.on_arrival(2, 0.0, Priority::High, 10);
        r.on_token(2, 0.4);
        let all = r.summary(None);
        assert_eq!(all.n, 2);
        assert_eq!(all.finished, 1);
        let hi = r.summary(Some(Priority::High));
        assert_eq!(hi.n, 1);
        assert!((hi.mean_ttft - 0.4).abs() < 1e-9);
    }

    #[test]
    fn peak_throughput_window() {
        let mut r = Recorder::new();
        r.on_arrival(1, 0.0, Priority::Normal, 1);
        // 10 tokens in [0,1), 2 tokens in [1,2).
        for i in 0..10 {
            r.on_token(1, 0.05 * i as f64);
        }
        r.on_token(1, 1.2);
        r.on_token(1, 1.3);
        assert_eq!(r.peak_throughput(1.0), 10.0);
        assert!(r.mean_throughput() > 0.0);
    }

    #[test]
    fn concurrency_series_tracks_inflight() {
        let mut r = Recorder::new();
        r.on_arrival(1, 0.0, Priority::Normal, 1);
        r.on_finish(1, 2.0);
        r.on_arrival(2, 1.0, Priority::Normal, 1);
        r.on_finish(2, 3.0);
        let s = r.concurrency_series(1.0);
        assert_eq!(s[0].1, 1.0); // t=0: req1
        assert_eq!(s[1].1, 2.0); // t=1: both
        assert_eq!(s[2].1, 1.0); // t=2: req2 only
    }

    // ---- bucket-edge coverage (ISSUE 7 satellite) ------------------------

    #[test]
    fn ttft_p90_series_buckets_by_arrival_floor() {
        // `TimeSeries::add` buckets by floor(t / interval): an arrival
        // exactly on a bucket boundary belongs to the *later* bucket, and
        // untouched buckets in between render as NaN rows at i*interval.
        let mut r = Recorder::new();
        r.on_arrival(1, 0.0, Priority::Normal, 1);
        r.on_token(1, 0.5); // ttft 0.5, bucket 0
        r.on_arrival(2, 1.0, Priority::Normal, 1); // exact edge -> bucket 1
        r.on_token(2, 1.2); // ttft 0.2
        r.on_arrival(3, 2.5, Priority::Normal, 1); // no tokens: no ttft
        r.on_arrival(4, 3.0, Priority::Normal, 1);
        r.on_token(4, 3.3); // ttft 0.3, bucket 3
        let s = r.ttft_p90_series(1.0);
        assert_eq!(s.len(), 4);
        assert_eq!(s[0].0, 0.0);
        assert!((s[0].1 - 0.5).abs() < 1e-9, "single sample p90 = value");
        assert_eq!(s[1].0, 1.0);
        assert!((s[1].1 - 0.2).abs() < 1e-9, "edge arrival lands in bucket 1");
        assert_eq!(s[2].0, 2.0);
        assert!(s[2].1.is_nan(), "tokenless request leaves its bucket empty");
        assert_eq!(s[3].0, 3.0);
        assert!((s[3].1 - 0.3).abs() < 1e-9);
    }

    #[test]
    fn concurrency_series_applies_edge_events_inclusively() {
        // Sampling is inclusive of events at the sample instant
        // (`events[i].0 <= t`): a request finishing exactly at t and one
        // arriving exactly at t cancel out in the same sample.
        let mut r = Recorder::new();
        r.on_arrival(1, 0.0, Priority::Normal, 1);
        r.on_finish(1, 1.0);
        r.on_arrival(2, 1.0, Priority::Normal, 1);
        r.on_finish(2, 2.0);
        let s = r.concurrency_series(1.0);
        // Samples at t = 0, 1, 2 (the grid is end-inclusive).
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], (0.0, 1.0));
        assert_eq!(s[1], (1.0, 1.0), "-1 at t=1 and +1 at t=1 both apply");
        assert_eq!(s[2], (2.0, 0.0));
    }

    #[test]
    fn concurrency_series_grid_starts_at_zero() {
        // The sample grid anchors at t=0 regardless of the first arrival,
        // and a request with no finish/token ends at its own arrival.
        let mut r = Recorder::new();
        r.on_arrival(1, 2.0, Priority::Normal, 1);
        r.on_finish(1, 2.5);
        let s = r.concurrency_series(1.0);
        assert_eq!(s.len(), 3); // t = 0, 1, 2 (2.5 < 3)
        assert_eq!(s[0], (0.0, 0.0));
        assert_eq!(s[1], (1.0, 0.0));
        assert_eq!(s[2], (2.0, 1.0), "arrival at 2.0 seen, finish at 2.5 not yet");
        // Arrival-only record: +1/-1 at the same instant, never observed >0.
        let mut r2 = Recorder::new();
        r2.on_arrival(1, 1.0, Priority::Normal, 1);
        let s2 = r2.concurrency_series(1.0);
        assert_eq!(s2.len(), 2);
        assert_eq!(s2[1], (1.0, 0.0));
    }

    #[test]
    fn slo_attainment_counts_finished_within_budget() {
        let mut r = Recorder::new();
        // Req 1: TTFT 1.0, finished.
        r.on_arrival(1, 10.0, Priority::Normal, 100);
        r.on_token(1, 11.0);
        r.on_finish(1, 11.4);
        // Req 2: TTFT 5.0, finished — misses a 2 s budget.
        r.on_arrival(2, 10.0, Priority::Normal, 100);
        r.on_token(2, 15.0);
        r.on_finish(2, 15.5);
        // Req 3: first token in time but never finished.
        r.on_arrival(3, 10.0, Priority::Normal, 100);
        r.on_token(3, 10.5);
        assert_eq!(r.slo_attained(|_| 2.0), 1);
        assert_eq!(r.slo_attained(|_| 10.0), 2);
        // Length-proportional SLO: long prompts earn bigger budgets.
        r.on_arrival(4, 0.0, Priority::Normal, 10_000);
        r.on_token(4, 6.0);
        r.on_finish(4, 6.1);
        assert_eq!(r.slo_attained(|rec| if rec.prompt_len > 1000 { 8.0 } else { 2.0 }), 2);
        assert_eq!(r.makespan(), 15.5);
    }

    #[test]
    fn empty_recorder_is_sane() {
        let r = Recorder::new();
        let s = r.summary(None);
        assert_eq!(s.n, 0);
        assert!(s.mean_ttft.is_nan());
        assert_eq!(r.peak_throughput(1.0), 0.0);
    }
}
