//! Flight recorder (ISSUE 7): switch-aware structured tracing shared by the
//! real coordinator and the discrete-event simulator.
//!
//! Both execution paths feed one [`Journal`] — a preallocated ring buffer of
//! typed, timestamped [`Event`]s covering the switch lifecycle (drain-begin →
//! per-member settle → promote), KV migration plan/apply, backfill
//! admissions with their predicted horizons, watchdog retries / degradations
//! / fault escalations, and control-plane ticks carrying the telemetry
//! snapshot plus the chosen plan and its rejection reason.
//!
//! The recording discipline mirrors `control::Telemetry`: [`Journal::record`]
//! is O(1) and allocation-free on the hot path (fixed-capacity ring,
//! overwrite-oldest, every event `Copy`), so an armed-but-idle recorder
//! passes the `sched_hotpath` zero-alloc gate.  Draining to JSONL
//! ([`Journal::write_jsonl`], schema in `obs/SCHEMA.md`) happens strictly
//! off the critical path, after the run.
//!
//! On top of the journal:
//!  * [`StallBreakdown`] — decomposes `switch_stall_s` into drain-wait /
//!    settle / migration / backfill-recovered components whose
//!    [`StallBreakdown::total`] must equal the aggregate within 1e-9 (the
//!    bench hard-gates this on `priority_storm` and `switch_churn`);
//!  * [`Journal::mode_timeline`] / [`Journal::utilization`] — per-engine
//!    mode and busy-time timelines derived from the event stream;
//!  * [`summarize_jsonl`] — the `trace` CLI subcommand's parser (every line
//!    must round-trip through `json::parse`, which is the CI smoke gate);
//!  * [`Exposition`] — Prometheus-style text exposition for the socket
//!    server's `metrics` request.

use std::collections::BTreeMap;
use std::io::{self, Write};

use crate::control::{Plan, TickInfo};
use crate::json::Value;

/// Default ring capacity: large enough that a bench-scale run keeps every
/// switch-lifecycle event while the (much denser) exec stream wraps.
/// ~16k entries × ~120 B ≈ 2 MB, allocated once when tracing is armed.
pub const DEFAULT_JOURNAL_CAP: usize = 16_384;

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

/// One journal entry.  Every variant is `Copy` and fixed-size: recording
/// never allocates, and the ring can overwrite in place.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// A DP→TP merge opened its transition window: `members` is the chosen
    /// instances' bitmask, `horizon_s` the predicted settle point.
    DrainBegin {
        group: u32,
        width: u32,
        members: u64,
        horizon_s: f64,
    },
    /// One member settled into the target mode ahead of the stragglers
    /// (incremental settle, backfill mode only).
    MemberSettle { group: u32, members: u64 },
    /// The group promoted: the mode switch executed (`latency_s` is the
    /// span from decision to group-ready).
    Promote {
        group: u32,
        p_from: u32,
        p_to: u32,
        members: u64,
        latency_s: f64,
    },
    /// A TP group dissolved back to DP units.
    Split { group: u32, width: u32, members: u64 },
    /// KV migration planned for a carried request (layout-preserving
    /// re-tag): `elems` is the per-member element count of the scatter.
    MigratePlan { rid: u64, tokens: u64, elems: u64 },
    /// KV migration applied: the request's cache crossed the layout change
    /// live, `cost_s` charged to the merge horizon.
    MigrateApply { rid: u64, tokens: u64, cost_s: f64 },
    /// A request admitted onto a draining engine under the backfill horizon
    /// predicate: predicted completion `fit_s` against window `horizon_s`.
    BackfillAdmit {
        rid: u64,
        engine: u32,
        fit_s: f64,
        horizon_s: f64,
    },
    /// One engine/group executed a step: `members` is its instance bitmask,
    /// `busy_s` the step duration (feeds the utilization timeline).
    Exec {
        members: u64,
        busy_s: f64,
        batch: u32,
        prefill: bool,
    },
    /// One control-plane tick: telemetry snapshot, forecaster state, the
    /// desired plan and whether adoption was held by the cooldown.
    CtrlTick { info: TickInfo },
    /// A late reply arrived within the watchdog's retry budget.
    WatchdogRetry { engine: u32, attempt: u32 },
    /// A reply deadline exhausted its retry budget (escalates to fault).
    WatchdogTimeout { engine: u32 },
    /// An engine was escalated to permanent fail-stop.
    EngineFault { engine: u32 },
    /// Graceful degradation ran for a failed engine; `requeued` requests
    /// were rescued off it.
    EngineDegraded { engine: u32, requeued: u32 },
    /// A rescued request re-entered the waiting rings (`retry` so far).
    RequestRecovered { rid: u64, retry: u32 },
    /// A request was aborted (recovery budget exhausted, or no surviving
    /// capacity could ever host it).
    RequestAborted { rid: u64 },
    /// A degraded step error was absorbed (streak below the fail-stop
    /// escalation budget).
    StepError { engine: u32, streak: u32 },
    /// A failed engine was respawned (ISSUE 8): fresh backend, fresh
    /// channels, generation-bumped identity.  Quarantined until probed.
    EngineRevive { engine: u32 },
    /// A probe step was issued to a quarantined (respawned) engine;
    /// `attempt` is the engine's cumulative rejoin-attempt count.
    RejoinProbe { engine: u32, attempt: u32 },
    /// The probe succeeded: quarantine lifted, the engine is back in
    /// unit/idle candidacy and the capacity healed.
    RejoinOk { engine: u32 },
    /// The rejoin-attempt budget exhausted: the engine re-escalated to
    /// permanent fail-stop (crash-loop anti-livelock, same rule as the
    /// step-error streak).
    RejoinAbandoned { engine: u32 },
    /// Double-buffered pipeline (ISSUE 9, `--overlap` only): a decode batch
    /// of `batch` slots was issued to `engine` from arena `slot` (0/1).
    SlotIssue { engine: u32, slot: u32, batch: u32 },
    /// The back arena's prebuilt batch was judged at issue time: `reused`
    /// is the bounded-staleness verdict (stamp matched the live scheduler
    /// state → arenas swapped; else discarded and rebuilt).
    SlotRetire { engine: u32, slot: u32, reused: bool },
    /// An asynchronous KV-migration transfer went in flight (ISSUE 9): the
    /// scatter runs concurrently with other engines' decode steps until the
    /// next safe point.  `window_s` is the predicted overlap window (the
    /// simulator fills it; the real path emits 0.0 — wall-clock convention
    /// as `drain_begin`).
    AsyncMigrateBegin { rid: u64, tokens: u64, window_s: f64 },
    /// The in-flight transfer completed at a safe point; `overlapped_s` is
    /// the wall the transfer hid behind concurrent compute (the journal-
    /// verified overlap window).
    AsyncMigrateEnd { rid: u64, overlapped_s: f64 },
    /// Prefix-cache admission hit (ISSUE 10, `--prefix-cache` only): the
    /// request adopted `tokens` cached prompt tokens by reference — that
    /// prefill never runs.
    PrefixHit { rid: u64, tokens: u64 },
    /// A finished request forked the prefix tree copy-on-write: `blocks`
    /// novel blocks were cached past the shared chain's divergence point.
    PrefixFork { rid: u64, blocks: u32 },
    /// `blocks` cache-only (refcount-1 leaf) blocks were LRU-evicted back
    /// to the pool to satisfy allocation demand.
    PrefixEvict { blocks: u32 },
}

impl Event {
    /// Stable kind tag, shared by the JSONL schema and the summaries.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::DrainBegin { .. } => "drain_begin",
            Event::MemberSettle { .. } => "member_settle",
            Event::Promote { .. } => "promote",
            Event::Split { .. } => "split",
            Event::MigratePlan { .. } => "migrate_plan",
            Event::MigrateApply { .. } => "migrate_apply",
            Event::BackfillAdmit { .. } => "backfill_admit",
            Event::Exec { .. } => "exec",
            Event::CtrlTick { .. } => "ctrl_tick",
            Event::WatchdogRetry { .. } => "watchdog_retry",
            Event::WatchdogTimeout { .. } => "watchdog_timeout",
            Event::EngineFault { .. } => "engine_fault",
            Event::EngineDegraded { .. } => "engine_degraded",
            Event::RequestRecovered { .. } => "request_recovered",
            Event::RequestAborted { .. } => "request_aborted",
            Event::StepError { .. } => "step_error",
            Event::EngineRevive { .. } => "engine_revive",
            Event::RejoinProbe { .. } => "rejoin_probe",
            Event::RejoinOk { .. } => "rejoin_ok",
            Event::RejoinAbandoned { .. } => "rejoin_abandoned",
            Event::SlotIssue { .. } => "slot_issue",
            Event::SlotRetire { .. } => "slot_retire",
            Event::AsyncMigrateBegin { .. } => "async_migrate_begin",
            Event::AsyncMigrateEnd { .. } => "async_migrate_end",
            Event::PrefixHit { .. } => "prefix_hit",
            Event::PrefixFork { .. } => "prefix_fork",
            Event::PrefixEvict { .. } => "prefix_evict",
        }
    }
}

fn plan_fields(plan: Plan) -> (&'static str, usize) {
    match plan {
        Plan::Hold => ("hold", 0),
        Plan::ScaleOut => ("scale-out", 0),
        Plan::ScaleUp { width } => ("scale-up", width),
    }
}

/// One event as a JSON value (`{"t":..,"ev":"..",..}` — see `SCHEMA.md`).
pub fn event_value(t: f64, ev: &Event) -> Value {
    let mut pairs: Vec<(&str, Value)> = vec![
        ("t", Value::num(t)),
        ("ev", Value::str(ev.kind())),
    ];
    match *ev {
        Event::DrainBegin { group, width, members, horizon_s } => {
            pairs.push(("group", Value::num(group as f64)));
            pairs.push(("width", Value::num(width as f64)));
            pairs.push(("members", Value::num(members as f64)));
            pairs.push(("horizon_s", Value::num(horizon_s)));
        }
        Event::MemberSettle { group, members } => {
            pairs.push(("group", Value::num(group as f64)));
            pairs.push(("members", Value::num(members as f64)));
        }
        Event::Promote { group, p_from, p_to, members, latency_s } => {
            pairs.push(("group", Value::num(group as f64)));
            pairs.push(("p_from", Value::num(p_from as f64)));
            pairs.push(("p_to", Value::num(p_to as f64)));
            pairs.push(("members", Value::num(members as f64)));
            pairs.push(("latency_s", Value::num(latency_s)));
        }
        Event::Split { group, width, members } => {
            pairs.push(("group", Value::num(group as f64)));
            pairs.push(("width", Value::num(width as f64)));
            pairs.push(("members", Value::num(members as f64)));
        }
        Event::MigratePlan { rid, tokens, elems } => {
            pairs.push(("rid", Value::num(rid as f64)));
            pairs.push(("tokens", Value::num(tokens as f64)));
            pairs.push(("elems", Value::num(elems as f64)));
        }
        Event::MigrateApply { rid, tokens, cost_s } => {
            pairs.push(("rid", Value::num(rid as f64)));
            pairs.push(("tokens", Value::num(tokens as f64)));
            pairs.push(("cost_s", Value::num(cost_s)));
        }
        Event::BackfillAdmit { rid, engine, fit_s, horizon_s } => {
            pairs.push(("rid", Value::num(rid as f64)));
            pairs.push(("engine", Value::num(engine as f64)));
            pairs.push(("fit_s", Value::num(fit_s)));
            pairs.push(("horizon_s", Value::num(horizon_s)));
        }
        Event::Exec { members, busy_s, batch, prefill } => {
            pairs.push(("members", Value::num(members as f64)));
            pairs.push(("busy_s", Value::num(busy_s)));
            pairs.push(("batch", Value::num(batch as f64)));
            pairs.push(("prefill", Value::Bool(prefill)));
        }
        Event::CtrlTick { info } => {
            let (want, want_w) = plan_fields(info.desired);
            let (got, got_w) = plan_fields(info.adopted);
            pairs.push(("arrival_rate", Value::num(info.arrival_rate)));
            pairs.push(("rate_fast", Value::num(info.rate_fast)));
            pairs.push(("rate_slow", Value::num(info.rate_slow)));
            pairs.push(("forecast_rate", Value::num(info.forecast_rate)));
            pairs.push(("burst", Value::Bool(info.burst)));
            pairs.push(("queue_len", Value::num(info.queue_len as f64)));
            pairs.push(("kv_frac", Value::num(info.kv_frac)));
            pairs.push(("idle_units", Value::num(info.idle_units as f64)));
            pairs.push(("n_units", Value::num(info.n_units as f64)));
            pairs.push(("desired", Value::str(want)));
            pairs.push(("desired_width", Value::num(want_w as f64)));
            pairs.push(("adopted", Value::str(got)));
            pairs.push(("adopted_width", Value::num(got_w as f64)));
            pairs.push((
                "rejected_reason",
                if info.held_by_cooldown {
                    Value::str("cooldown")
                } else {
                    Value::Null
                },
            ));
        }
        Event::WatchdogRetry { engine, attempt } => {
            pairs.push(("engine", Value::num(engine as f64)));
            pairs.push(("attempt", Value::num(attempt as f64)));
        }
        Event::WatchdogTimeout { engine } => {
            pairs.push(("engine", Value::num(engine as f64)));
        }
        Event::EngineFault { engine } => {
            pairs.push(("engine", Value::num(engine as f64)));
        }
        Event::EngineDegraded { engine, requeued } => {
            pairs.push(("engine", Value::num(engine as f64)));
            pairs.push(("requeued", Value::num(requeued as f64)));
        }
        Event::RequestRecovered { rid, retry } => {
            pairs.push(("rid", Value::num(rid as f64)));
            pairs.push(("retry", Value::num(retry as f64)));
        }
        Event::RequestAborted { rid } => {
            pairs.push(("rid", Value::num(rid as f64)));
        }
        Event::StepError { engine, streak } => {
            pairs.push(("engine", Value::num(engine as f64)));
            pairs.push(("streak", Value::num(streak as f64)));
        }
        Event::EngineRevive { engine } => {
            pairs.push(("engine", Value::num(engine as f64)));
        }
        Event::RejoinProbe { engine, attempt } => {
            pairs.push(("engine", Value::num(engine as f64)));
            pairs.push(("attempt", Value::num(attempt as f64)));
        }
        Event::RejoinOk { engine } => {
            pairs.push(("engine", Value::num(engine as f64)));
        }
        Event::RejoinAbandoned { engine } => {
            pairs.push(("engine", Value::num(engine as f64)));
        }
        Event::SlotIssue { engine, slot, batch } => {
            pairs.push(("engine", Value::num(engine as f64)));
            pairs.push(("slot", Value::num(slot as f64)));
            pairs.push(("batch", Value::num(batch as f64)));
        }
        Event::SlotRetire { engine, slot, reused } => {
            pairs.push(("engine", Value::num(engine as f64)));
            pairs.push(("slot", Value::num(slot as f64)));
            pairs.push(("reused", Value::Bool(reused)));
        }
        Event::AsyncMigrateBegin { rid, tokens, window_s } => {
            pairs.push(("rid", Value::num(rid as f64)));
            pairs.push(("tokens", Value::num(tokens as f64)));
            pairs.push(("window_s", Value::num(window_s)));
        }
        Event::AsyncMigrateEnd { rid, overlapped_s } => {
            pairs.push(("rid", Value::num(rid as f64)));
            pairs.push(("overlapped_s", Value::num(overlapped_s)));
        }
        Event::PrefixHit { rid, tokens } => {
            pairs.push(("rid", Value::num(rid as f64)));
            pairs.push(("tokens", Value::num(tokens as f64)));
        }
        Event::PrefixFork { rid, blocks } => {
            pairs.push(("rid", Value::num(rid as f64)));
            pairs.push(("blocks", Value::num(blocks as f64)));
        }
        Event::PrefixEvict { blocks } => {
            pairs.push(("blocks", Value::num(blocks as f64)));
        }
    }
    Value::obj(pairs)
}

// ---------------------------------------------------------------------------
// Stall attribution
// ---------------------------------------------------------------------------

/// Decomposition of `switch_stall_s` into where transition time goes.  Each
/// component is accumulated at the exact site the aggregate is touched, so
/// the identity
///
/// ```text
/// switch_stall_s = drain_wait_s + settle_s + migration_s
///                - backfill_recovered_s - pipeline_overlap_s
/// ```
///
/// holds to floating-point rounding (the bench hard-gates 1e-9 on
/// `priority_storm` and `switch_churn`).  Accumulation is unconditional —
/// a handful of f64 adds per switch — so the breakdown is available even
/// with the journal off.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StallBreakdown {
    /// Chosen members idle from their own free point to the slowest
    /// straggler's drain point.
    pub drain_wait_s: f64,
    /// The live-switch latency itself, per member.
    pub settle_s: f64,
    /// KV-transfer wait charged to the merge horizon (`switch_migrate`
    /// carries; 0 with the flag off).
    pub migration_s: f64,
    /// Work backfill shells executed inside transition windows (credited
    /// back against the aggregate; 0 with `switch_backfill` off).
    pub backfill_recovered_s: f64,
    /// Migration-transfer wall hidden behind concurrent compute by the
    /// pipelined path (ISSUE 9; credited back against the aggregate like
    /// `backfill_recovered_s`; 0 with `--overlap` off).
    pub pipeline_overlap_s: f64,
}

impl StallBreakdown {
    /// The aggregate the components must reconstruct.
    pub fn total(&self) -> f64 {
        self.drain_wait_s + self.settle_s + self.migration_s
            - self.backfill_recovered_s
            - self.pipeline_overlap_s
    }

    pub fn to_value(&self) -> Value {
        Value::obj(vec![
            ("drain_wait_s", Value::num(self.drain_wait_s)),
            ("settle_s", Value::num(self.settle_s)),
            ("migration_s", Value::num(self.migration_s)),
            ("backfill_recovered_s", Value::num(self.backfill_recovered_s)),
            ("pipeline_overlap_s", Value::num(self.pipeline_overlap_s)),
            ("total_s", Value::num(self.total())),
        ])
    }
}

// ---------------------------------------------------------------------------
// Journal
// ---------------------------------------------------------------------------

/// Fixed-capacity, overwrite-oldest event ring.  A disabled journal
/// ([`Journal::off`]) records nothing and holds no storage, so call sites
/// can thread `&mut Journal` unconditionally.
#[derive(Debug)]
pub struct Journal {
    buf: Vec<(f64, Event)>,
    cap: usize,
    /// Oldest entry once the ring has wrapped (0 until then).
    head: usize,
    /// Entries overwritten after the ring filled.
    dropped: u64,
    enabled: bool,
}

impl Journal {
    /// An armed journal with storage for `cap` events, allocated up front
    /// (the hot path never grows it).
    pub fn new(cap: usize) -> Self {
        Journal {
            buf: Vec::with_capacity(cap),
            cap,
            head: 0,
            dropped: 0,
            enabled: cap > 0,
        }
    }

    /// A disabled journal: `record` is a branch and a return.
    pub fn off() -> Self {
        Journal {
            buf: Vec::new(),
            cap: 0,
            head: 0,
            dropped: 0,
            enabled: false,
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Events overwritten since the last clear (ring exhaustion indicator).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.dropped = 0;
    }

    /// Record one event.  O(1), allocation-free: within capacity this is a
    /// push into preallocated storage; once full it overwrites the oldest
    /// entry in place.
    #[inline]
    pub fn record(&mut self, t: f64, ev: Event) {
        if !self.enabled {
            return;
        }
        if self.buf.len() < self.cap {
            self.buf.push((t, ev));
        } else {
            self.buf[self.head] = (t, ev);
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Entries oldest-first.
    pub fn iter(&self) -> impl Iterator<Item = &(f64, Event)> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }

    /// Event counts by kind (cheap journal-level summary).
    pub fn counts(&self) -> BTreeMap<&'static str, usize> {
        let mut m = BTreeMap::new();
        for (_, ev) in self.iter() {
            *m.entry(ev.kind()).or_insert(0) += 1;
        }
        m
    }

    /// Drain to JSONL: one `{"t":..,"ev":..}` object per line, oldest
    /// first, preceded by `meta` lines (`{"meta": ...}`) if given.  Runs
    /// strictly off the critical path.
    pub fn write_jsonl<W: Write>(&self, w: &mut W, meta: Option<&Value>) -> io::Result<()> {
        if let Some(m) = meta {
            writeln!(w, "{}", Value::obj(vec![("meta", m.clone())]))?;
        }
        for (t, ev) in self.iter() {
            writeln!(w, "{}", event_value(*t, ev))?;
        }
        Ok(())
    }

    // ---- timelines (derived, off the hot path) ---------------------------

    /// Per-engine mode timeline: `(t, width)` transitions for each of
    /// `n_engines` unit instances, derived from the switch-lifecycle
    /// events.  Width 0 marks a fail-stopped engine; a later `rejoin_ok`
    /// returns it to width 1 (the fault→heal bracket is the outage
    /// window).  Engines start (and may stay) implicitly at width 1 — the
    /// timeline records changes.
    pub fn mode_timeline(&self, n_engines: usize) -> Vec<Vec<(f64, u32)>> {
        let mut out: Vec<Vec<(f64, u32)>> = vec![Vec::new(); n_engines];
        let mut group_width: BTreeMap<u32, u32> = BTreeMap::new();
        let mut mark = |out: &mut Vec<Vec<(f64, u32)>>, bits: u64, t: f64, w: u32| {
            let mut b = bits;
            while b != 0 {
                let e = b.trailing_zeros() as usize;
                b &= b - 1;
                if e < n_engines {
                    out[e].push((t, w));
                }
            }
        };
        for &(t, ev) in self.iter() {
            match ev {
                Event::DrainBegin { group, width, .. } => {
                    group_width.insert(group, width);
                }
                Event::MemberSettle { group, members } => {
                    let w = group_width.get(&group).copied().unwrap_or(1);
                    mark(&mut out, members, t, w);
                }
                Event::Promote { group, p_to, members, .. } => {
                    group_width.insert(group, p_to);
                    mark(&mut out, members, t, p_to);
                }
                Event::Split { group, members, .. } => {
                    group_width.remove(&group);
                    mark(&mut out, members, t, 1);
                }
                Event::EngineFault { engine } => {
                    if (engine as usize) < n_engines {
                        out[engine as usize].push((t, 0));
                    }
                }
                // A healed engine rejoins at unit width (the probe step
                // re-established DP mode); width 0 ... rejoin_ok brackets
                // the outage window in the timeline.
                Event::RejoinOk { engine } => {
                    if (engine as usize) < n_engines {
                        out[engine as usize].push((t, 1));
                    }
                }
                _ => {}
            }
        }
        out
    }

    /// Per-engine busy seconds bucketed by `bucket_s`, from `Exec` events
    /// (a group step charges each member instance its full duration).
    pub fn utilization(&self, n_engines: usize, bucket_s: f64) -> Vec<Vec<f64>> {
        assert!(bucket_s > 0.0);
        let mut out: Vec<Vec<f64>> = vec![Vec::new(); n_engines];
        for &(t, ev) in self.iter() {
            if let Event::Exec { members, busy_s, .. } = ev {
                let idx = (t / bucket_s).floor().max(0.0) as usize;
                let mut b = members;
                while b != 0 {
                    let e = b.trailing_zeros() as usize;
                    b &= b - 1;
                    if e < n_engines {
                        if out[e].len() <= idx {
                            out[e].resize(idx + 1, 0.0);
                        }
                        out[e][idx] += busy_s;
                    }
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Trace-file summary (`trace` CLI subcommand, CI smoke parser)
// ---------------------------------------------------------------------------

/// Aggregate view of a JSONL journal file.  Built through `json::parse` on
/// every line, so summarizing doubles as the round-trip validity check.
#[derive(Debug, Default)]
pub struct TraceSummary {
    pub lines: usize,
    pub meta_lines: usize,
    pub events: usize,
    pub t_min: f64,
    pub t_max: f64,
    pub by_kind: BTreeMap<String, usize>,
    pub promote_latency_sum_s: f64,
    pub promotes: usize,
    pub stall_reclaimed_s: f64,
}

impl TraceSummary {
    pub fn mean_promote_latency_s(&self) -> f64 {
        if self.promotes == 0 {
            0.0
        } else {
            self.promote_latency_sum_s / self.promotes as f64
        }
    }
}

impl std::fmt::Display for TraceSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "journal: {} events ({} lines, {} meta) over [{:.3}s, {:.3}s]",
            self.events, self.lines, self.meta_lines, self.t_min, self.t_max
        )?;
        for (kind, n) in &self.by_kind {
            writeln!(f, "  {kind:18} {n}")?;
        }
        if self.promotes > 0 {
            writeln!(
                f,
                "  mean promote latency: {:.4}s over {} promotions",
                self.mean_promote_latency_s(),
                self.promotes
            )?;
        }
        Ok(())
    }
}

/// Parse a JSONL journal dump and summarize it.  Every non-empty line must
/// be valid JSON (an event object with `t`/`ev`, or a `{"meta":..}` line) —
/// anything else is an error, which is exactly what the CI trace-smoke step
/// asserts.
pub fn summarize_jsonl(text: &str) -> anyhow::Result<TraceSummary> {
    let mut s = TraceSummary {
        t_min: f64::INFINITY,
        t_max: f64::NEG_INFINITY,
        ..TraceSummary::default()
    };
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Value::parse(line.trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", i + 1))?;
        s.lines += 1;
        if v.get("meta").is_some() {
            s.meta_lines += 1;
            continue;
        }
        let t = v.f64_field("t").map_err(|e| anyhow::anyhow!("line {}: {e}", i + 1))?;
        let kind = v
            .str_field("ev")
            .map_err(|e| anyhow::anyhow!("line {}: {e}", i + 1))?;
        s.events += 1;
        s.t_min = s.t_min.min(t);
        s.t_max = s.t_max.max(t);
        *s.by_kind.entry(kind.to_string()).or_insert(0) += 1;
        if kind == "promote" {
            s.promotes += 1;
            s.promote_latency_sum_s += v.get("latency_s").and_then(|x| x.as_f64()).unwrap_or(0.0);
        }
    }
    if s.events == 0 {
        s.t_min = 0.0;
        s.t_max = 0.0;
    }
    Ok(s)
}

// ---------------------------------------------------------------------------
// Prometheus-style exposition
// ---------------------------------------------------------------------------

/// Minimal Prometheus text-format builder (counters and gauges, no labels)
/// behind the socket server's `metrics` request.
#[derive(Default)]
pub struct Exposition {
    out: String,
}

impl Exposition {
    pub fn new() -> Self {
        Exposition::default()
    }

    fn push(&mut self, name: &str, mtype: &str, help: &str, value: f64) {
        use std::fmt::Write as _;
        let _ = writeln!(self.out, "# HELP {name} {help}");
        let _ = writeln!(self.out, "# TYPE {name} {mtype}");
        if value.is_finite() && value.fract() == 0.0 && value.abs() < 1e15 {
            let _ = writeln!(self.out, "{name} {}", value as i64);
        } else {
            let _ = writeln!(self.out, "{name} {value}");
        }
    }

    pub fn counter(mut self, name: &str, help: &str, value: f64) -> Self {
        self.push(name, "counter", help, value);
        self
    }

    pub fn gauge(mut self, name: &str, help: &str, value: f64) -> Self {
        self.push(name, "gauge", help, value);
        self
    }

    pub fn render(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(engine: u32) -> Event {
        Event::EngineFault { engine }
    }

    #[test]
    fn ring_overwrites_oldest_in_order() {
        let mut j = Journal::new(3);
        for i in 0..5 {
            j.record(i as f64, ev(i));
        }
        assert_eq!(j.len(), 3);
        assert_eq!(j.dropped(), 2);
        let ts: Vec<f64> = j.iter().map(|&(t, _)| t).collect();
        assert_eq!(ts, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn disabled_journal_records_nothing() {
        let mut j = Journal::off();
        j.record(1.0, ev(0));
        assert!(j.is_empty());
        assert!(!j.is_enabled());
        assert_eq!(j.dropped(), 0);
    }

    #[test]
    fn record_within_capacity_never_reallocates() {
        let mut j = Journal::new(64);
        let ptr = j.buf.as_ptr();
        for i in 0..200 {
            j.record(i as f64, ev(0));
        }
        assert_eq!(j.buf.as_ptr(), ptr, "ring storage must never move");
    }

    #[test]
    fn jsonl_roundtrips_through_parser() {
        let mut j = Journal::new(16);
        j.record(
            0.5,
            Event::DrainBegin { group: 7, width: 4, members: 0b1111, horizon_s: 1.25 },
        );
        j.record(
            1.25,
            Event::Promote { group: 7, p_from: 1, p_to: 4, members: 0b1111, latency_s: 0.75 },
        );
        j.record(2.0, Event::RequestAborted { rid: 42 });
        let mut buf = Vec::new();
        j.write_jsonl(&mut buf, Some(&Value::str("unit-test"))).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let s = summarize_jsonl(&text).unwrap();
        assert_eq!(s.events, 3);
        assert_eq!(s.meta_lines, 1);
        assert_eq!(s.by_kind["promote"], 1);
        assert!((s.mean_promote_latency_s() - 0.75).abs() < 1e-12);
        assert!((s.t_min - 0.5).abs() < 1e-12 && (s.t_max - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summarize_rejects_non_json_lines() {
        assert!(summarize_jsonl("{\"t\":1,\"ev\":\"split\"}\nnot json\n").is_err());
    }

    #[test]
    fn stall_breakdown_identity() {
        let b = StallBreakdown {
            drain_wait_s: 3.0,
            settle_s: 0.5,
            migration_s: 0.25,
            backfill_recovered_s: 1.0,
            pipeline_overlap_s: 0.125,
        };
        assert!((b.total() - 2.625).abs() < 1e-12);
        let v = b.to_value();
        assert!((v.f64_field("total_s").unwrap() - 2.625).abs() < 1e-12);
        assert!((v.f64_field("pipeline_overlap_s").unwrap() - 0.125).abs() < 1e-12);
    }

    #[test]
    fn overlap_events_roundtrip_through_jsonl() {
        let mut j = Journal::new(16);
        j.record(0.1, Event::SlotIssue { engine: 1, slot: 0, batch: 8 });
        j.record(0.2, Event::SlotRetire { engine: 1, slot: 1, reused: true });
        j.record(0.3, Event::AsyncMigrateBegin { rid: 7, tokens: 512, window_s: 0.02 });
        j.record(0.4, Event::AsyncMigrateEnd { rid: 7, overlapped_s: 0.015 });
        let mut buf = Vec::new();
        j.write_jsonl(&mut buf, None).unwrap();
        let s = summarize_jsonl(&String::from_utf8(buf).unwrap()).unwrap();
        assert_eq!(s.events, 4);
        assert_eq!(s.by_kind["slot_issue"], 1);
        assert_eq!(s.by_kind["slot_retire"], 1);
        assert_eq!(s.by_kind["async_migrate_begin"], 1);
        assert_eq!(s.by_kind["async_migrate_end"], 1);
    }

    #[test]
    fn prefix_events_roundtrip_through_jsonl() {
        let mut j = Journal::new(16);
        j.record(0.1, Event::PrefixHit { rid: 11, tokens: 96 });
        j.record(0.2, Event::PrefixFork { rid: 11, blocks: 2 });
        j.record(0.3, Event::PrefixEvict { blocks: 3 });
        let mut buf = Vec::new();
        j.write_jsonl(&mut buf, None).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("\"ev\":\"prefix_hit\"") && text.contains("\"tokens\":96"));
        let s = summarize_jsonl(&text).unwrap();
        assert_eq!(s.events, 3);
        assert_eq!(s.by_kind["prefix_hit"], 1);
        assert_eq!(s.by_kind["prefix_fork"], 1);
        assert_eq!(s.by_kind["prefix_evict"], 1);
    }

    #[test]
    fn mode_timeline_tracks_lifecycle() {
        let mut j = Journal::new(16);
        j.record(
            0.0,
            Event::DrainBegin { group: 9, width: 2, members: 0b11, horizon_s: 1.0 },
        );
        j.record(0.4, Event::MemberSettle { group: 9, members: 0b01 });
        j.record(
            1.0,
            Event::Promote { group: 9, p_from: 1, p_to: 2, members: 0b11, latency_s: 1.0 },
        );
        j.record(3.0, Event::Split { group: 9, width: 2, members: 0b11 });
        j.record(4.0, Event::EngineFault { engine: 1 });
        let tl = j.mode_timeline(2);
        assert_eq!(tl[0], vec![(0.4, 2), (1.0, 2), (3.0, 1)]);
        assert_eq!(tl[1], vec![(1.0, 2), (3.0, 1), (4.0, 0)]);
    }

    #[test]
    fn mode_timeline_brackets_outage_with_rejoin() {
        let mut j = Journal::new(16);
        j.record(1.0, Event::EngineFault { engine: 0 });
        j.record(1.5, Event::EngineRevive { engine: 0 });
        j.record(1.6, Event::RejoinProbe { engine: 0, attempt: 1 });
        j.record(2.0, Event::RejoinOk { engine: 0 });
        let tl = j.mode_timeline(1);
        assert_eq!(tl[0], vec![(1.0, 0), (2.0, 1)]);
    }

    #[test]
    fn rejoin_events_roundtrip_through_jsonl() {
        let mut j = Journal::new(16);
        j.record(0.1, Event::EngineRevive { engine: 2 });
        j.record(0.2, Event::RejoinProbe { engine: 2, attempt: 1 });
        j.record(0.3, Event::RejoinOk { engine: 2 });
        j.record(0.4, Event::RejoinAbandoned { engine: 3 });
        let mut buf = Vec::new();
        j.write_jsonl(&mut buf, None).unwrap();
        let s = summarize_jsonl(&String::from_utf8(buf).unwrap()).unwrap();
        assert_eq!(s.events, 4);
        assert_eq!(s.by_kind["engine_revive"], 1);
        assert_eq!(s.by_kind["rejoin_probe"], 1);
        assert_eq!(s.by_kind["rejoin_ok"], 1);
        assert_eq!(s.by_kind["rejoin_abandoned"], 1);
    }

    #[test]
    fn utilization_buckets_group_steps_per_member() {
        let mut j = Journal::new(16);
        j.record(0.2, Event::Exec { members: 0b11, busy_s: 0.5, batch: 4, prefill: false });
        j.record(1.7, Event::Exec { members: 0b01, busy_s: 0.25, batch: 1, prefill: true });
        let u = j.utilization(2, 1.0);
        assert!((u[0][0] - 0.5).abs() < 1e-12);
        assert!((u[0][1] - 0.25).abs() < 1e-12);
        assert!((u[1][0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn exposition_renders_prometheus_text() {
        let text = Exposition::new()
            .counter("flying_requests_total", "Requests admitted.", 42.0)
            .gauge("flying_kv_frac", "KV utilization.", 0.5)
            .render();
        assert!(text.contains("# TYPE flying_requests_total counter"));
        assert!(text.contains("flying_requests_total 42\n"));
        assert!(text.contains("flying_kv_frac 0.5\n"));
    }
}
