//! Analytic H200 cost model for the discrete-event simulator.
//!
//! One CPU core cannot exhibit parallel speedups, so the paper's
//! end-to-end latency/throughput results (Figs 8–10, Tables 1–2) are
//! regenerated on a simulated 8×H200 node driven by the *same policy code*
//! as the real path.  The model is first-principles (roofline: compute vs
//! HBM vs NVLink) with two calibrated constants:
//!
//! * `overhead_gb_per_gpu` — non-KV memory overhead (activations, CUDA
//!   graphs, fragmentation).  28.7 GB/GPU reproduces the paper's Table-2
//!   max-context column to within a few percent at every TP degree
//!   (264K / 959K / 2.3M for Llama-70B at 2/4/8 GPUs).
//! * cold-start: `cold_base_s + s_per_gb * weight_gb_per_gpu`, fit to the
//!   paper's 292/212/147 s column.
//!
//! All model arithmetic is bf16 (2 bytes/param, 2 bytes/KV element), which
//! is what the Table-2 numbers imply.

/// 8× NVIDIA H200 node (paper §6.1.1).
#[derive(Clone, Copy, Debug)]
pub struct HwSpec {
    pub n_gpus: usize,
    pub hbm_gb: f64,
    pub hbm_bw: f64,    // bytes/s per GPU
    pub nvlink_bw: f64, // bytes/s per GPU (bidirectional)
    pub flops_bf16: f64,
    pub mfu_prefill: f64,
    pub mfu_decode: f64,
    pub kernel_launch_s: f64, // per collective/kernel fixed cost
    pub overhead_gb_per_gpu: f64,
    pub cold_base_s: f64,
    pub cold_s_per_gb: f64,
}

impl Default for HwSpec {
    fn default() -> Self {
        HwSpec {
            n_gpus: 8,
            hbm_gb: 141.0,
            hbm_bw: 4.8e12,
            nvlink_bw: 900e9,
            flops_bf16: 989e12,
            mfu_prefill: 0.55,
            mfu_decode: 0.35,
            kernel_launch_s: 25e-6,
            overhead_gb_per_gpu: 28.7,
            cold_base_s: 110.0,
            cold_s_per_gb: 2.55,
        }
    }
}

/// Paper-scale model description.
#[derive(Clone, Debug)]
pub struct PaperModel {
    pub name: &'static str,
    pub params_b: f64,        // total parameters, billions
    pub active_params_b: f64, // activated per token (MoE < total)
    pub n_layers: usize,
    pub d_model: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    /// Minimum GPUs for one serving instance (the paper's base DP engine):
    /// Llama-70B bf16 needs 2 GPUs; the others fit on 1.
    pub min_gpus: usize,
    pub max_model_ctx: usize,
    /// Weight bytes per parameter (bf16 = 2; GPT-OSS ships MXFP4 ≈ 1).
    pub bytes_per_param: f64,
    /// KV-cache bytes per element (bf16 = 2 for the paper-scale models;
    /// the testbed-calibrated fit uses 4 — its pools are f32).
    pub kv_bytes_per_elem: f64,
}

impl PaperModel {
    pub fn llama70b() -> Self {
        PaperModel {
            name: "Llama-3-70B",
            params_b: 70.0,
            active_params_b: 70.0,
            n_layers: 80,
            d_model: 8192,
            n_kv_heads: 8,
            d_head: 128,
            min_gpus: 2,
            max_model_ctx: 8192,
            bytes_per_param: 2.0,
            kv_bytes_per_elem: 2.0,
        }
    }

    pub fn gptoss120b() -> Self {
        PaperModel {
            name: "GPT-OSS-120B",
            params_b: 117.0,
            active_params_b: 5.1,
            n_layers: 36,
            d_model: 2880,
            n_kv_heads: 8,
            d_head: 64,
            min_gpus: 2,
            max_model_ctx: 131072,
            bytes_per_param: 1.0, // MXFP4 checkpoint
            kv_bytes_per_elem: 2.0,
        }
    }

    pub fn nemotron8b() -> Self {
        PaperModel {
            name: "Nemotron-8B",
            params_b: 8.0,
            active_params_b: 8.0,
            n_layers: 32,
            d_model: 4096,
            n_kv_heads: 8,
            d_head: 128,
            min_gpus: 1,
            max_model_ctx: 4_000_000,
            bytes_per_param: 2.0,
            kv_bytes_per_elem: 2.0,
        }
    }

    pub fn weight_bytes(&self) -> f64 {
        self.params_b * 1e9 * self.bytes_per_param
    }

    /// KV bytes per token (all layers, k+v, at this model's element width).
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.n_layers as f64
            * self.n_kv_heads as f64
            * self.d_head as f64
            * self.kv_bytes_per_elem
    }
}

/// Cost model for a group of `g` GPUs serving one instance.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub hw: HwSpec,
    pub model: PaperModel,
}

impl CostModel {
    pub fn new(hw: HwSpec, model: PaperModel) -> Self {
        CostModel { hw, model }
    }

    /// Max KV tokens a g-GPU instance can hold (Table-2 capacity model).
    pub fn kv_capacity_tokens(&self, g: usize) -> usize {
        let total = g as f64 * self.hw.hbm_gb * 1e9;
        let overhead = g as f64 * self.hw.overhead_gb_per_gpu * 1e9;
        let avail = total - self.model.weight_bytes() - overhead;
        (avail.max(0.0) / self.model.kv_bytes_per_token()) as usize
    }

    /// All-reduce time for `bytes` across g GPUs (ring, 2(g-1)/g passes).
    fn allreduce_s(&self, bytes: f64, g: usize) -> f64 {
        if g <= 1 {
            return 0.0;
        }
        self.hw.kernel_launch_s + 2.0 * (g - 1) as f64 / g as f64 * bytes / self.hw.nvlink_bw
    }

    /// Prefill `t` tokens on a g-GPU instance (compute-bound; 2 all-reduces
    /// per layer when g > 1).
    pub fn prefill_s(&self, t: usize, g: usize) -> f64 {
        let flops = 2.0 * self.model.active_params_b * 1e9 * t as f64;
        let compute = flops / (g as f64 * self.hw.flops_bf16 * self.hw.mfu_prefill);
        let act_bytes = t as f64 * self.model.d_model as f64 * 2.0;
        let comm = 2.0 * self.model.n_layers as f64 * self.allreduce_s(act_bytes, g);
        compute + comm + self.hw.kernel_launch_s * self.model.n_layers as f64
    }

    /// One decode step for a batch of `b` requests at mean context `ctx`
    /// (memory-bound: weight + KV reads; 2 all-reduces per layer).
    pub fn decode_step_s(&self, b: usize, ctx: usize, g: usize) -> f64 {
        // MoE batched decode touches ~min(total, active*b) parameters: with
        // realistic batches most experts are hit every step, so the read
        // approaches the full model (the classic MoE serving effect).
        let touched_bytes = (self.model.active_params_b * b as f64)
            .min(self.model.params_b)
            * 1e9
            * self.model.bytes_per_param;
        let weight_read = touched_bytes / (g as f64 * self.hw.hbm_bw);
        let kv_read = b as f64 * ctx as f64 * self.model.kv_bytes_per_token() / (g as f64 * self.hw.hbm_bw);
        let flops = 2.0 * self.model.active_params_b * 1e9 * b as f64;
        let compute = flops / (g as f64 * self.hw.flops_bf16 * self.hw.mfu_decode);
        let act_bytes = b as f64 * self.model.d_model as f64 * 2.0;
        let comm = 2.0 * self.model.n_layers as f64 * self.allreduce_s(act_bytes, g);
        weight_read.max(kv_read).max(compute) + comm + self.hw.kernel_launch_s * self.model.n_layers as f64
    }

    /// Cold restart of an instance at g GPUs (weight reload + NCCL init) —
    /// what a *static* system pays to change parallelism (Table 2).
    pub fn cold_start_s(&self, g: usize) -> f64 {
        let per_gpu_gb = self.model.weight_bytes() / 1e9 / g as f64;
        self.hw.cold_base_s + self.hw.cold_s_per_gb * per_gpu_gb
    }

    /// Request rate (req/s) that saturates the full-node TP configuration's
    /// decode capacity for the §6.1.3 length mix.  Used to translate the
    /// paper's absolute arrival rates (which sit just around Llama-70B's TP
    /// saturation on their testbed) into equivalent utilization on this
    /// cost model for each model.
    pub fn tp_saturation_rps(&self, mean_prompt: usize, mean_output: usize) -> f64 {
        let b = 48;
        let step = self.decode_step_s(b, mean_prompt + mean_output / 2, self.hw.n_gpus);
        (b as f64 / step) / mean_output as f64
    }

    /// FLYING SERVING's live switch: metadata + pre-built communicator
    /// activation (measured at ~15 ms on the paper's testbed; our real-path
    /// thread cluster measures the same mechanism in microseconds — the
    /// simulator uses the paper's H200 number).
    pub fn live_switch_s(&self) -> f64 {
        0.015
    }

    /// Layout-preserving KV migration of `tokens` cached tokens into a
    /// g-GPU layout (ISSUE 4): the home rank re-tags its own shard in place
    /// (zero copy — Eqs. 2–3 make the bytes layout-invariant), so only the
    /// other `g-1` ranks' slices cross NVLink.  One scatter launch plus
    /// bytes over link bandwidth.
    pub fn migrate_t(&self, tokens: usize, g: usize) -> f64 {
        if g <= 1 || tokens == 0 {
            return 0.0;
        }
        let bytes =
            tokens as f64 * self.model.kv_bytes_per_token() * (g - 1) as f64 / g as f64;
        self.hw.kernel_launch_s + bytes / self.hw.nvlink_bw
    }

    /// The migrate-vs-recompute decision (shared verbatim by the simulator
    /// event core and the real coordinator, so the two paths stay
    /// byte-comparable): carry the KV when moving its bytes beats
    /// re-prefilling it on the target layout.  Shift Parallelism's
    /// observation (arXiv:2509.16495) — KV bytes over NVLink are orders of
    /// magnitude cheaper than prefill FLOPs — makes this true at every
    /// realistic context length; the rule only flips on a link slow enough
    /// to invert the ratio.
    pub fn migrate_wins(&self, tokens: usize, g: usize) -> bool {
        self.migrate_t(tokens, g) < self.prefill_s(tokens, g)
    }

    /// Absolute finish time of a request executed **alone** on a g-GPU
    /// instance starting at `start`: chunked prefill (chunks of
    /// `chunk_tokens`), then one decode step per remaining output token,
    /// every step floored at `heartbeat_s` — step for step the sequence the
    /// event-driven simulator runs for a solo request, accumulated in the
    /// same order so the timestamps match to the bit.
    ///
    /// This is the admission predicate for drain-horizon backfill
    /// (`SimConfig::switch_backfill`): in the simulator the cost model IS
    /// the execution model, so "predicted to complete inside the drain
    /// horizon" is exact, never optimistic.  `budget` short-circuits the
    /// walk: once the accumulated time passes it the exact value no longer
    /// matters and the current (lower-bound) estimate is returned.
    #[allow(clippy::too_many_arguments)]
    pub fn solo_completion_t(
        &self,
        start: f64,
        prompt: usize,
        output: usize,
        g: usize,
        chunk_tokens: usize,
        heartbeat_s: f64,
        budget: f64,
    ) -> f64 {
        let mut t = start;
        let mut remaining = prompt;
        while remaining > 0 {
            let chunk = remaining.min(chunk_tokens);
            t += self.prefill_s(chunk, g).max(heartbeat_s);
            remaining -= chunk;
            if t > budget {
                return t;
            }
        }
        // The final prefill chunk emits token 1; each decode step one more.
        for e in 1..output {
            t += self.decode_step_s(1, prompt + e, g).max(heartbeat_s);
            if t > budget {
                return t;
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn llama() -> CostModel {
        CostModel::new(HwSpec::default(), PaperModel::llama70b())
    }

    #[test]
    fn table2_max_context_reproduced() {
        let cm = llama();
        // Paper Table 2: 264K (2 GPUs), 959K (4), 2.3M (8).
        let k264 = cm.kv_capacity_tokens(2) as f64;
        let k959 = cm.kv_capacity_tokens(4) as f64;
        let k2300 = cm.kv_capacity_tokens(8) as f64;
        assert!((k264 / 264_000.0 - 1.0).abs() < 0.10, "2gpu={k264}");
        assert!((k959 / 959_000.0 - 1.0).abs() < 0.10, "4gpu={k959}");
        assert!((k2300 / 2_300_000.0 - 1.0).abs() < 0.10, "8gpu={k2300}");
    }

    #[test]
    fn table2_cold_start_shape() {
        let cm = llama();
        // Paper: 292 s (2 GPUs), 212 s (4), 147 s (8): monotone decreasing,
        // right magnitude.
        let c2 = cm.cold_start_s(2);
        let c4 = cm.cold_start_s(4);
        let c8 = cm.cold_start_s(8);
        assert!(c2 > c4 && c4 > c8);
        assert!((c2 / 292.0 - 1.0).abs() < 0.15, "c2={c2}");
        assert!((c8 / 147.0 - 1.0).abs() < 0.25, "c8={c8}");
        // Live switch is ~4 orders of magnitude faster.
        assert!(c2 / cm.live_switch_s() > 1e4);
    }

    #[test]
    fn tp_reduces_latency_dp_never_slower_total() {
        let cm = llama();
        // Per-request prefill latency shrinks with more GPUs.
        let p2 = cm.prefill_s(2000, 2);
        let p8 = cm.prefill_s(2000, 8);
        assert!(p8 < p2, "prefill {p2} -> {p8}");
        // Decode step too (weight read dominates).
        let d2 = cm.decode_step_s(8, 1000, 2);
        let d8 = cm.decode_step_s(8, 1000, 8);
        assert!(d8 < d2);
        // But aggregate decode throughput favors DP: 4 instances of 2 GPUs
        // each running batch 8 beat one 8-GPU instance at batch 8.
        let dp_rate = 4.0 * 8.0 / d2;
        let tp_rate = 8.0 / d8;
        assert!(dp_rate > 1.5 * tp_rate, "dp={dp_rate} tp={tp_rate}");
    }

    #[test]
    fn moe_decode_cheaper_than_dense_at_same_size() {
        let hw = HwSpec::default();
        let dense = CostModel::new(hw, PaperModel::llama70b());
        let moe = CostModel::new(hw, PaperModel::gptoss120b());
        // Active params dominate decode: the 120B MoE steps faster than the
        // dense 70B.
        assert!(moe.decode_step_s(8, 1000, 2) < dense.decode_step_s(8, 1000, 2));
    }

    #[test]
    fn migration_beats_recompute_at_paper_scale() {
        let cm = llama();
        for tokens in [512usize, 8_192, 300_000] {
            for g in [2usize, 4, 8] {
                assert!(
                    cm.migrate_wins(tokens, g),
                    "migrate_t={} prefill_s={} at tokens={tokens} g={g}",
                    cm.migrate_t(tokens, g),
                    cm.prefill_s(tokens, g)
                );
                // The gap is what makes re-prefill the wrong default: at
                // long context it is orders of magnitude.
                if tokens >= 8_192 {
                    assert!(cm.prefill_s(tokens, g) > 10.0 * cm.migrate_t(tokens, g));
                }
            }
        }
        // Degenerate cases cost nothing.
        assert_eq!(cm.migrate_t(0, 4), 0.0);
        assert_eq!(cm.migrate_t(1000, 1), 0.0);
    }

    #[test]
    fn migration_decision_flips_when_kv_outweighs_compute() {
        // The rule is a genuine comparison, not a constant: a model whose
        // per-token KV footprint dwarfs its per-token FLOPs (tiny active
        // parameters, very wide KV) makes re-prefill the cheaper carry.
        let heavy_kv = PaperModel {
            name: "kv-heavy-toy",
            params_b: 0.1,
            active_params_b: 0.1,
            n_layers: 100,
            d_model: 512,
            n_kv_heads: 64,
            d_head: 256,
            min_gpus: 1,
            max_model_ctx: 1_000_000,
            bytes_per_param: 2.0,
            kv_bytes_per_elem: 2.0,
        };
        let cm = CostModel::new(HwSpec::default(), heavy_kv);
        assert!(
            !cm.migrate_wins(8_192, 2),
            "migrate_t={} prefill_s={}",
            cm.migrate_t(8_192, 2),
            cm.prefill_s(8_192, 2)
        );
    }

    #[test]
    fn nemotron_million_token_fits_merged_only() {
        let cm = CostModel::new(HwSpec::default(), PaperModel::nemotron8b());
        // 1M-token context: must NOT fit one GPU, must fit the full node.
        assert!(cm.kv_capacity_tokens(1) < 1_000_000);
        assert!(cm.kv_capacity_tokens(8) > 1_000_000);
    }
}
