//! Reference simulator: the original loop-based implementation, preserved
//! verbatim in behavior as the differential-testing oracle for the
//! event-driven core in `cluster.rs`.
//!
//! Per scheduling iteration it re-sorts the whole ready queue and linearly
//! re-scans every virtual engine, so a trace of n requests costs O(n²) under
//! sustained backlog — which is exactly why the production `simulate` was
//! rewritten.  Keep this implementation boring and obviously correct; the
//! property tests in `tests/sim_equivalence.rs` assert the rewritten core
//! produces identical completion/rejection sets and switch counts.
//!
//! Two deliberate fixes over the seed (mirrored in the event core so the
//! implementations stay outcome-equivalent):
//!  * arrival comparisons use `f64::total_cmp` (no NaN panic), and
//!  * the "queue non-empty, nothing running, nothing arriving" spin is
//!    detected and resolved by deterministically rejecting the stuck
//!    requests instead of advancing the clock forever.

use std::collections::BTreeMap;

use crate::coordinator::policy::{ModeDecision, Policy, Snapshot};
use crate::metrics::Recorder;
use crate::workload::Request;

use super::cluster::{SimConfig, SimOutcome, SimSystem};
use super::costmodel::CostModel;

#[derive(Clone, Debug, PartialEq)]
enum RPhase {
    Queued,
    Prefill,
    Decode,
    Done,
}

#[derive(Clone, Debug)]
struct SimReq {
    req: Request,
    phase: RPhase,
    prefilled: usize,
    emitted: usize,
    paused: bool,
}

#[derive(Clone, Debug)]
struct VEng {
    m: usize,
    free_at: f64,
    active: Vec<u64>,
    transient: bool,
}

/// Reference (seed) implementation of [`super::cluster::simulate`].
pub fn simulate_reference(
    system: SimSystem,
    cm: &CostModel,
    trace: &[Request],
    cfg: &SimConfig,
) -> SimOutcome {
    let n_inst = cm.hw.n_gpus / cm.model.min_gpus;
    let gpus_per_inst = cm.model.min_gpus;

    let mut vengs: Vec<VEng> = match system {
        SimSystem::StaticDp | SimSystem::Flying | SimSystem::FlyingSequential => (0..n_inst)
            .map(|_| VEng { m: 1, free_at: 0.0, active: vec![], transient: false })
            .collect(),
        SimSystem::StaticTp(m) => {
            let m = m.min(n_inst).max(1);
            (0..n_inst / m)
                .map(|_| VEng { m, free_at: 0.0, active: vec![], transient: false })
                .collect()
        }
        SimSystem::Shift => vec![VEng { m: n_inst, free_at: 0.0, active: vec![], transient: false }],
    };

    let mut reqs: BTreeMap<u64, SimReq> = BTreeMap::new();
    let mut queue: Vec<u64> = Vec::new();
    let mut rec = Recorder::new();
    let mut rejected = Vec::new();
    let mut n_switches = 0usize;
    let mut policy = crate::coordinator::policy::FlyingPolicy::default();

    let mut arrivals: Vec<&Request> = trace.iter().collect();
    arrivals.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    let mut next_arr = 0usize;
    let mut t = 0.0f64;
    let mut progressed = true;

    let dp_cap = cm.kv_capacity_tokens(gpus_per_inst);

    loop {
        // ---- advance the clock to the next actionable moment ------------
        let work_t = vengs
            .iter()
            .filter(|v| !v.active.is_empty())
            .map(|v| v.free_at)
            .fold(f64::INFINITY, f64::min);
        let arr_t = arrivals.get(next_arr).map(|r| r.arrival).unwrap_or(f64::INFINITY);
        let next_t = work_t.min(arr_t);
        if next_t.is_infinite() {
            if queue.is_empty() {
                break;
            }
            if !progressed {
                // Stall: queue non-empty, nothing running, nothing arriving,
                // and a full scheduling iteration changed nothing.  Reject
                // the stuck requests deterministically instead of spinning.
                for rid in std::mem::take(&mut queue) {
                    reqs.get_mut(&rid).unwrap().phase = RPhase::Done;
                    rejected.push(rid);
                    rec.on_finish(rid, t);
                }
                break;
            }
            // One more heartbeat-quantum iteration: a split/assignment may
            // still make progress (e.g. a drained transient group under
            // queue pressure).
            t += cfg.heartbeat_s;
        } else {
            t = t.max(next_t);
        }
        progressed = false;

        // ---- admissions ---------------------------------------------------
        while next_arr < arrivals.len() && arrivals[next_arr].arrival <= t {
            let r = arrivals[next_arr];
            rec.on_arrival(r.id, r.arrival, r.priority, r.prompt_len);
            reqs.insert(
                r.id,
                SimReq {
                    req: r.clone(),
                    phase: RPhase::Queued,
                    prefilled: 0,
                    emitted: 0,
                    paused: false,
                },
            );
            queue.push(r.id);
            next_arr += 1;
            progressed = true;
        }

        // ---- assignment (the policy layer, shared with the real path) ----
        queue.sort_by(|a, b| {
            let (ra, rb) = (&reqs[a].req, &reqs[b].req);
            rb.priority
                .cmp(&ra.priority)
                .then(ra.arrival.total_cmp(&rb.arrival))
        });
        let mut still_queued = Vec::new();
        let drained = std::mem::take(&mut queue);
        let backlog_total = drained.len();
        for (qi, rid) in drained.into_iter().enumerate() {
            let total = reqs[&rid].req.prompt_len + reqs[&rid].req.output_len;
            let decision = match system {
                SimSystem::StaticDp => {
                    if total > dp_cap {
                        ModeDecision::Reject
                    } else {
                        ModeDecision::Dp
                    }
                }
                SimSystem::StaticTp(m) => {
                    if total > cm.kv_capacity_tokens(m.min(n_inst) * gpus_per_inst) {
                        ModeDecision::Reject
                    } else {
                        ModeDecision::Tp(m)
                    }
                }
                SimSystem::Shift => ModeDecision::Tp(n_inst),
                SimSystem::Flying | SimSystem::FlyingSequential => {
                    let idle: usize = vengs
                        .iter()
                        .filter(|v| v.active.is_empty())
                        .map(|v| v.m)
                        .sum();
                    let (kv_used, kv_cap) = vengs.iter().fold((0usize, 0usize), |(u, c), v| {
                        let used: usize = v
                            .active
                            .iter()
                            .map(|r| reqs[r].req.prompt_len + reqs[r].emitted)
                            .sum();
                        (u + used, c + cm.kv_capacity_tokens(v.m * gpus_per_inst))
                    });
                    let snap = Snapshot {
                        now: t,
                        queue_len: still_queued.len() + (backlog_total - qi - 1),
                        idle_engines: idle,
                        n_engines: n_inst,
                        dp_capacity_tokens: dp_cap,
                        max_tp: n_inst,
                        kv_frac: if kv_cap == 0 {
                            0.0
                        } else {
                            kv_used as f64 / kv_cap as f64
                        },
                    };
                    policy.decide(
                        reqs[&rid].req.prompt_len,
                        reqs[&rid].req.output_len,
                        reqs[&rid].req.priority,
                        reqs[&rid].req.tp_demand,
                        &snap,
                    )
                }
            };
            match decision {
                ModeDecision::Reject => {
                    reqs.get_mut(&rid).unwrap().phase = RPhase::Done;
                    rejected.push(rid);
                    rec.on_finish(rid, t);
                    progressed = true;
                }
                ModeDecision::Dp => {
                    let pick = vengs
                        .iter_mut()
                        .filter(|v| v.m == 1 || matches!(system, SimSystem::StaticDp))
                        .filter(|v| v.active.len() < cfg.max_batch)
                        .filter(|v| kv_room(v, &reqs, cm, gpus_per_inst) >= total)
                        .min_by_key(|v| v.active.len());
                    match pick {
                        Some(v) => {
                            v.active.push(rid);
                            let r = reqs.get_mut(&rid).unwrap();
                            r.phase = RPhase::Prefill;
                            rec.on_first_sched(rid, t);
                            progressed = true;
                        }
                        None => {
                            let backlog_now = still_queued.len() + (backlog_total - qi - 1);
                            let joined = matches!(
                                system,
                                SimSystem::Flying | SimSystem::FlyingSequential
                            ) && backlog_now == 0
                                && vengs
                                    .iter_mut()
                                    .find(|v| {
                                        v.transient
                                            && v.active.iter().filter(|r| !reqs[r].paused).count() < 8
                                            && kv_room(v, &reqs, cm, gpus_per_inst) >= total
                                    })
                                    .map(|v| {
                                        v.active.push(rid);
                                        true
                                    })
                                    .unwrap_or(false);
                            if joined {
                                let r = reqs.get_mut(&rid).unwrap();
                                r.phase = RPhase::Prefill;
                                rec.on_first_sched(rid, t);
                                progressed = true;
                            } else {
                                still_queued.push(rid);
                            }
                        }
                    }
                }
                ModeDecision::Tp(want_m) => {
                    let want_m = want_m.min(n_inst).max(1);
                    match bind_tp_ref(
                        system, &mut vengs, &mut reqs, rid, want_m, t, cm, cfg, &mut n_switches,
                        gpus_per_inst,
                    ) {
                        Some(bind_t) => {
                            rec.on_first_sched(rid, bind_t);
                            progressed = true;
                        }
                        None => still_queued.push(rid),
                    }
                }
            }
        }
        queue = still_queued;

        // ---- execute one step on every free veng with work ---------------
        for v in vengs.iter_mut() {
            if v.free_at > t || v.active.is_empty() {
                continue;
            }
            let g = v.m * gpus_per_inst;
            let pre = v.active.iter().copied().find(|r| {
                let q = &reqs[r];
                q.phase == RPhase::Prefill && !q.paused
            });
            if let Some(rid) = pre {
                let q = reqs.get_mut(&rid).unwrap();
                let chunk = (q.req.prompt_len - q.prefilled).min(cfg.chunk_tokens);
                let dur = cm.prefill_s(chunk, g).max(cfg.heartbeat_s);
                v.free_at = t + dur;
                q.prefilled += chunk;
                if q.prefilled >= q.req.prompt_len {
                    q.phase = RPhase::Decode;
                    q.emitted = 1;
                    rec.on_token(rid, t + dur);
                    if q.emitted >= q.req.output_len {
                        q.phase = RPhase::Done;
                        rec.on_finish(rid, t + dur);
                    }
                }
                let riders: Vec<u64> = v
                    .active
                    .iter()
                    .copied()
                    .filter(|r| *r != rid && reqs[r].phase == RPhase::Decode && !reqs[r].paused)
                    .take(cfg.max_batch)
                    .collect();
                for r in riders {
                    let q = reqs.get_mut(&r).unwrap();
                    q.emitted += 1;
                    rec.on_token(r, t + dur);
                    if q.emitted >= q.req.output_len {
                        q.phase = RPhase::Done;
                        rec.on_finish(r, t + dur);
                    }
                }
                progressed = true;
            } else {
                let batch_cap = if matches!(system, SimSystem::Shift) {
                    cfg.max_batch * v.m
                } else {
                    cfg.max_batch
                };
                let batch: Vec<u64> = v
                    .active
                    .iter()
                    .copied()
                    .filter(|r| reqs[r].phase == RPhase::Decode && !reqs[r].paused)
                    .take(batch_cap)
                    .collect();
                if batch.is_empty() {
                    continue;
                }
                let mean_ctx = (batch
                    .iter()
                    .map(|r| reqs[r].req.prompt_len + reqs[r].emitted)
                    .sum::<usize>()
                    / batch.len())
                .max(1);
                let dur = match system {
                    SimSystem::Shift if batch.len() > 2 * n_inst => {
                        let per = batch.len().div_ceil(n_inst);
                        cm.decode_step_s(per, mean_ctx, gpus_per_inst) / 0.85
                    }
                    _ => cm.decode_step_s(batch.len(), mean_ctx, g),
                }
                .max(cfg.heartbeat_s);
                v.free_at = t + dur;
                for rid in batch {
                    let q = reqs.get_mut(&rid).unwrap();
                    q.emitted += 1;
                    rec.on_token(rid, t + dur);
                    if q.emitted >= q.req.output_len {
                        q.phase = RPhase::Done;
                        rec.on_finish(rid, t + dur);
                    }
                }
                progressed = true;
            }
            v.active.retain(|r| reqs[r].phase != RPhase::Done);
        }

        // ---- split transient TP groups whose work drained -----------------
        let mut new_vengs = Vec::with_capacity(vengs.len());
        for v in vengs.drain(..) {
            let tp_work_left = v
                .active
                .iter()
                .any(|r| !reqs[r].paused && reqs[r].phase != RPhase::Done);
            let has_paused = v.active.iter().any(|r| reqs[r].paused);
            if v.transient && !tp_work_left && (!queue.is_empty() || has_paused) {
                let paused: Vec<u64> = v.active.clone();
                for i in 0..v.m {
                    let mut unit = VEng { m: 1, free_at: v.free_at, active: vec![], transient: false };
                    for (j, rid) in paused.iter().enumerate() {
                        if j % v.m == i {
                            reqs.get_mut(rid).unwrap().paused = false;
                            unit.active.push(*rid);
                        }
                    }
                    new_vengs.push(unit);
                }
                n_switches += 1;
                progressed = true;
            } else {
                new_vengs.push(v);
            }
        }
        vengs = new_vengs;
    }

    // The reference models neither transition windows nor KV migration; its
    // stall/carry metrics are reported as 0 and deliberately excluded from
    // `outcomes_equivalent`.
    SimOutcome {
        recorder: rec,
        rejected,
        n_switches,
        switch_stall_s: 0.0,
        recompute_tokens_avoided: 0,
        prefill_tokens_avoided: 0,
        stall: Default::default(),
        journal: None,
    }
}

fn kv_room(
    v: &VEng,
    reqs: &BTreeMap<u64, SimReq>,
    cm: &CostModel,
    gpus_per_inst: usize,
) -> usize {
    let cap = cm.kv_capacity_tokens(v.m * gpus_per_inst);
    let used: usize = v
        .active
        .iter()
        .map(|r| reqs[r].req.prompt_len + reqs[r].emitted)
        .sum();
    cap.saturating_sub(used)
}

/// Merge contiguous unit vengs into a transient TP group for `rid`.
#[allow(clippy::too_many_arguments)]
fn bind_tp_ref(
    system: SimSystem,
    vengs: &mut Vec<VEng>,
    reqs: &mut BTreeMap<u64, SimReq>,
    rid: u64,
    want_m: usize,
    t: f64,
    cm: &CostModel,
    _cfg: &SimConfig,
    n_switches: &mut usize,
    gpus_per_inst: usize,
) -> Option<f64> {
    let total = reqs[&rid].req.prompt_len + reqs[&rid].req.output_len;
    let batch_cap = |v: &VEng| {
        if matches!(system, SimSystem::Shift) {
            _cfg.max_batch * v.m
        } else {
            _cfg.max_batch
        }
    };
    if let Some(v) = vengs.iter_mut().find(|v| {
        v.m == want_m
            && v.active.len() < batch_cap(v)
            && kv_room(v, reqs, cm, gpus_per_inst) >= total
    }) {
        if matches!(system, SimSystem::StaticTp(_) | SimSystem::Shift) || v.transient || v.m == 1 {
            v.active.push(rid);
            reqs.get_mut(&rid).unwrap().phase = RPhase::Prefill;
            return Some(t);
        }
    }
    if !matches!(system, SimSystem::Flying | SimSystem::FlyingSequential) {
        return None;
    }

    let mut unit_idx: Vec<usize> = (0..vengs.len()).filter(|&i| vengs[i].m == 1).collect();
    if unit_idx.len() < want_m {
        return None;
    }
    unit_idx.sort_by_key(|&i| vengs[i].active.len());
    let chosen: Vec<usize> = unit_idx.into_iter().take(want_m).collect();

    let busy = chosen.iter().any(|&i| !vengs[i].active.is_empty());
    if busy && system == SimSystem::FlyingSequential {
        return None;
    }

    let mut merged = VEng {
        m: want_m,
        free_at: chosen
            .iter()
            .map(|&i| vengs[i].free_at)
            .fold(t, f64::max)
            + cm.live_switch_s(),
        active: vec![],
        transient: true,
    };
    for &i in &chosen {
        for r in &vengs[i].active {
            reqs.get_mut(r).unwrap().paused = true;
            merged.active.push(*r);
        }
    }
    merged.active.push(rid);
    reqs.get_mut(&rid).unwrap().phase = RPhase::Prefill;
    let bind_t = merged.free_at;
    let mut chosen_sorted = chosen;
    chosen_sorted.sort_unstable_by(|a, b| b.cmp(a));
    for i in chosen_sorted {
        vengs.remove(i);
    }
    vengs.push(merged);
    *n_switches += 1;
    Some(bind_t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::costmodel::{HwSpec, PaperModel};
    use crate::workload::{generate, WorkloadCfg};

    fn cm() -> CostModel {
        CostModel::new(HwSpec::default(), PaperModel::llama70b())
    }

    #[test]
    fn reference_completes_small_trace() {
        let trace = generate(&WorkloadCfg::paper_full(11, 120));
        for sys in [SimSystem::StaticDp, SimSystem::Flying] {
            let o = simulate_reference(sys, &cm(), &trace, &SimConfig::default());
            let s = o.recorder.summary(None);
            assert_eq!(s.finished + o.rejected.len(), 120, "{}", sys.label());
        }
    }

    #[test]
    fn reference_stall_rejects_instead_of_spinning() {
        // max_batch = 0 makes every DP admission impossible: the seed code
        // would heartbeat forever; the fixed reference rejects.
        let trace = generate(&WorkloadCfg::paper_full(3, 5));
        let cfg = SimConfig { max_batch: 0, ..SimConfig::default() };
        let o = simulate_reference(SimSystem::StaticDp, &cm(), &trace, &cfg);
        assert_eq!(o.rejected.len(), 5);
    }
}
