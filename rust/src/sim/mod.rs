//! Discrete-event cluster simulation (see DESIGN.md §Substitutions).
//!
//! The paper's end-to-end numbers come from an 8×H200 node; a single CPU
//! core cannot exhibit parallel speedups, so Figs 8–10 and Tables 1–2 are
//! regenerated here on an analytically-modeled node (costmodel.rs,
//! calibrated against the paper's own Table-2 capacity/cold-start columns)
//! driven by the same `Policy` code as the real thread cluster.

pub mod cluster;
pub mod costmodel;
pub mod reference;

pub use cluster::{
    outcomes_equivalent, simulate, simulate_adaptive, SimConfig, SimOutcome, SimSystem,
};
pub use costmodel::{CostModel, HwSpec, PaperModel};
pub use reference::simulate_reference;
