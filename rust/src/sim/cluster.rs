//! Discrete-event cluster simulator: an 8×H200 node serving paper-scale
//! models under the four systems of §6 (static DP, static TP,
//! Shift-Parallelism, FLYING SERVING), driven by the *same* `Policy`
//! implementations as the real thread cluster.
//!
//! Virtual engines ("vengs") partition the node's serving instances; FLYING
//! merges contiguous unit vengs into TP groups and splits them back, paying
//! the paper's 15 ms live-switch cost, while static systems keep a fixed
//! partition (and pay a cold restart if they must change it).  Every event
//! lands in a `metrics::Recorder`, so the benches read the simulator with
//! the same summaries/time-series as the real path.

use std::collections::BTreeMap;

use crate::coordinator::policy::{ModeDecision, Policy, Snapshot};
use crate::metrics::Recorder;
use crate::workload::Request;

use super::costmodel::CostModel;

#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Chunked-prefill chunk size (tokens).
    pub chunk_tokens: usize,
    /// Max decode batch per virtual engine.
    pub max_batch: usize,
    /// Scheduling-iteration quantum lower bound (control-plane heartbeat).
    pub heartbeat_s: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            chunk_tokens: 2048,
            max_batch: 48,
            heartbeat_s: 0.004,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SimSystem {
    /// One instance per min-GPU slice, never merged.
    StaticDp,
    /// Fixed merge of `m` instances per group.
    StaticTp(usize),
    /// Shift-Parallelism (arXiv:2509.16495): one cluster-wide group that
    /// flips between latency-optimal TP and throughput-oriented SP.
    Shift,
    /// FLYING SERVING with hard preempt.
    Flying,
    /// FLYING SERVING with sequential (non-preemptive) switching — the
    /// ablation of §5.2.
    FlyingSequential,
}

impl SimSystem {
    pub fn label(&self) -> &'static str {
        match self {
            SimSystem::StaticDp => "static-dp",
            SimSystem::StaticTp(_) => "static-tp",
            SimSystem::Shift => "shift-parallelism",
            SimSystem::Flying => "flying",
            SimSystem::FlyingSequential => "flying-sequential",
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
enum RPhase {
    Queued,
    Prefill,
    Decode,
    Done,
}

#[derive(Clone, Debug)]
struct SimReq {
    req: Request,
    phase: RPhase,
    prefilled: usize,
    emitted: usize,
    paused: bool,
}

#[derive(Clone, Debug)]
struct VEng {
    /// Serving instances merged into this virtual engine (1 = plain DP).
    m: usize,
    free_at: f64,
    active: Vec<u64>,
    /// Set for a merged veng that must split back when its TP work drains.
    transient: bool,
}

pub struct SimOutcome {
    pub recorder: Recorder,
    pub rejected: Vec<u64>,
    pub n_switches: usize,
}

pub fn simulate(
    system: SimSystem,
    cm: &CostModel,
    trace: &[Request],
    cfg: &SimConfig,
) -> SimOutcome {
    let n_inst = cm.hw.n_gpus / cm.model.min_gpus;
    let gpus_per_inst = cm.model.min_gpus;

    let mut vengs: Vec<VEng> = match system {
        SimSystem::StaticDp | SimSystem::Flying | SimSystem::FlyingSequential => (0..n_inst)
            .map(|_| VEng { m: 1, free_at: 0.0, active: vec![], transient: false })
            .collect(),
        SimSystem::StaticTp(m) => {
            let m = m.min(n_inst).max(1);
            (0..n_inst / m)
                .map(|_| VEng { m, free_at: 0.0, active: vec![], transient: false })
                .collect()
        }
        SimSystem::Shift => vec![VEng { m: n_inst, free_at: 0.0, active: vec![], transient: false }],
    };

    let mut reqs: BTreeMap<u64, SimReq> = BTreeMap::new();
    let mut queue: Vec<u64> = Vec::new();
    let mut rec = Recorder::new();
    let mut rejected = Vec::new();
    let mut n_switches = 0usize;
    let mut policy = crate::coordinator::policy::FlyingPolicy::default();

    let mut arrivals: Vec<&Request> = trace.iter().collect();
    arrivals.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    let mut next_arr = 0usize;
    let mut t = 0.0f64;

    let dp_cap = cm.kv_capacity_tokens(gpus_per_inst);

    loop {
        // ---- advance the clock to the next actionable moment ------------
        let work_t = vengs
            .iter()
            .filter(|v| !v.active.is_empty())
            .map(|v| v.free_at)
            .fold(f64::INFINITY, f64::min);
        let arr_t = arrivals.get(next_arr).map(|r| r.arrival).unwrap_or(f64::INFINITY);
        let next_t = work_t.min(arr_t);
        if next_t.is_infinite() {
            if queue.is_empty() {
                break;
            }
            // Queue non-empty but nothing running: engines are idle, step
            // time forward by a heartbeat so assignment can proceed.
            t += cfg.heartbeat_s;
        } else {
            t = t.max(next_t);
        }

        // ---- admissions ---------------------------------------------------
        while next_arr < arrivals.len() && arrivals[next_arr].arrival <= t {
            let r = arrivals[next_arr];
            rec.on_arrival(r.id, r.arrival, r.priority, r.prompt_len);
            reqs.insert(
                r.id,
                SimReq {
                    req: r.clone(),
                    phase: RPhase::Queued,
                    prefilled: 0,
                    emitted: 0,
                    paused: false,
                },
            );
            queue.push(r.id);
            next_arr += 1;
        }

        // ---- assignment (the policy layer, shared with the real path) ----
        queue.sort_by(|a, b| {
            let (ra, rb) = (&reqs[a].req, &reqs[b].req);
            rb.priority
                .cmp(&ra.priority)
                .then(ra.arrival.partial_cmp(&rb.arrival).unwrap())
        });
        let mut still_queued = Vec::new();
        let drained = std::mem::take(&mut queue);
        let backlog_total = drained.len();
        for (qi, rid) in drained.into_iter().enumerate() {
            let total = reqs[&rid].req.prompt_len + reqs[&rid].req.output_len;
            let decision = match system {
                SimSystem::StaticDp => {
                    if total > dp_cap {
                        ModeDecision::Reject
                    } else {
                        ModeDecision::Dp
                    }
                }
                SimSystem::StaticTp(m) => {
                    if total > cm.kv_capacity_tokens(m.min(n_inst) * gpus_per_inst) {
                        ModeDecision::Reject
                    } else {
                        ModeDecision::Tp(m)
                    }
                }
                SimSystem::Shift => ModeDecision::Tp(n_inst),
                SimSystem::Flying | SimSystem::FlyingSequential => {
                    // Idle capacity in *unit-instance* terms so the snapshot
                    // semantics match the real (fixed-engine) coordinator.
                    let idle: usize = vengs
                        .iter()
                        .filter(|v| v.active.is_empty())
                        .map(|v| v.m)
                        .sum();
                    let snap = Snapshot {
                        queue_len: still_queued.len() + (backlog_total - qi - 1),
                        idle_engines: idle,
                        n_engines: n_inst,
                        dp_capacity_tokens: dp_cap,
                        max_tp: n_inst,
                    };
                    policy.decide(
                        reqs[&rid].req.prompt_len,
                        reqs[&rid].req.output_len,
                        reqs[&rid].req.priority,
                        reqs[&rid].req.tp_demand,
                        &snap,
                    )
                }
            };
            match decision {
                ModeDecision::Reject => {
                    reqs.get_mut(&rid).unwrap().phase = RPhase::Done;
                    rejected.push(rid);
                    rec.on_finish(rid, t);
                }
                ModeDecision::Dp => {
                    // Least-loaded unit veng with KV room and batch room
                    // (vLLM max_num_seqs-style admission).
                    let pick = vengs
                        .iter_mut()
                        .filter(|v| v.m == 1 || matches!(system, SimSystem::StaticDp))
                        .filter(|v| v.active.len() < cfg.max_batch)
                        .filter(|v| kv_room(v, &reqs, cm, gpus_per_inst) >= total)
                        .min_by_key(|v| v.active.len());
                    match pick {
                        Some(v) => {
                            v.active.push(rid);
                            let r = reqs.get_mut(&rid).unwrap();
                            r.phase = RPhase::Prefill;
                            rec.on_first_sched(rid, t);
                        }
                        None => {
                            // FLYING at low load: if every engine is merged
                            // into a live TP group and there is NO backlog,
                            // the request simply executes on the group (the
                            // paper's "opportunistically TP" regime).  The
                            // group's batch stays latency-sized (<= 8) so a
                            // burst onset only has to drain a small batch
                            // before the split releases the DP engines.
                            let backlog_now = still_queued.len() + (backlog_total - qi - 1);
                            let joined = matches!(
                                system,
                                SimSystem::Flying | SimSystem::FlyingSequential
                            ) && backlog_now == 0
                                && vengs
                                    .iter_mut()
                                    .find(|v| {
                                        v.transient
                                            && v.active.iter().filter(|r| !reqs[r].paused).count() < 8
                                            && kv_room(v, &reqs, cm, gpus_per_inst) >= total
                                    })
                                    .map(|v| {
                                        v.active.push(rid);
                                        true
                                    })
                                    .unwrap_or(false);
                            if joined {
                                let r = reqs.get_mut(&rid).unwrap();
                                r.phase = RPhase::Prefill;
                                rec.on_first_sched(rid, t);
                            } else {
                                still_queued.push(rid);
                            }
                        }
                    }
                }
                ModeDecision::Tp(want_m) => {
                    let want_m = want_m.min(n_inst).max(1);
                    match bind_tp_sim(
                        system, &mut vengs, &mut reqs, rid, want_m, t, cm, cfg, &mut n_switches,
                        gpus_per_inst,
                    ) {
                        Some(bind_t) => rec.on_first_sched(rid, bind_t),
                        None => still_queued.push(rid),
                    }
                }
            }
        }
        queue = still_queued;

        // ---- execute one step on every free veng with work ---------------
        for v in vengs.iter_mut() {
            if v.free_at > t || v.active.is_empty() {
                continue;
            }
            let g = v.m * gpus_per_inst;
            // Prefill-first (chunked); else a decode batch.
            let pre = v.active.iter().copied().find(|r| {
                let q = &reqs[r];
                q.phase == RPhase::Prefill && !q.paused
            });
            if let Some(rid) = pre {
                let q = reqs.get_mut(&rid).unwrap();
                let chunk = (q.req.prompt_len - q.prefilled).min(cfg.chunk_tokens);
                let dur = cm.prefill_s(chunk, g).max(cfg.heartbeat_s);
                v.free_at = t + dur;
                q.prefilled += chunk;
                if q.prefilled >= q.req.prompt_len {
                    q.phase = RPhase::Decode;
                    q.emitted = 1; // first token produced by final chunk
                    rec.on_token(rid, t + dur);
                    if q.emitted >= q.req.output_len {
                        q.phase = RPhase::Done;
                        rec.on_finish(rid, t + dur);
                    }
                }
                // Chunked prefill piggybacks decodes (Sarathi/vLLM, which
                // the paper preserves): in-flight decode requests advance
                // one token within the same round.
                let riders: Vec<u64> = v
                    .active
                    .iter()
                    .copied()
                    .filter(|r| *r != rid && reqs[r].phase == RPhase::Decode && !reqs[r].paused)
                    .take(cfg.max_batch)
                    .collect();
                for r in riders {
                    let q = reqs.get_mut(&r).unwrap();
                    q.emitted += 1;
                    rec.on_token(r, t + dur);
                    if q.emitted >= q.req.output_len {
                        q.phase = RPhase::Done;
                        rec.on_finish(r, t + dur);
                    }
                }
            } else {
                // SP (Shift) executes token-parallel across all instances,
                // so its effective batch is cluster-wide.
                let batch_cap = if matches!(system, SimSystem::Shift) {
                    cfg.max_batch * v.m
                } else {
                    cfg.max_batch
                };
                let batch: Vec<u64> = v
                    .active
                    .iter()
                    .copied()
                    .filter(|r| reqs[r].phase == RPhase::Decode && !reqs[r].paused)
                    .take(batch_cap)
                    .collect();
                if batch.is_empty() {
                    continue;
                }
                let mean_ctx = (batch
                    .iter()
                    .map(|r| reqs[r].req.prompt_len + reqs[r].emitted)
                    .sum::<usize>()
                    / batch.len())
                .max(1);
                let dur = match system {
                    // SP mode: token-parallel across instances — near-DP
                    // aggregate throughput at an efficiency discount.
                    SimSystem::Shift if batch.len() > 2 * n_inst => {
                        let per = batch.len().div_ceil(n_inst);
                        cm.decode_step_s(per, mean_ctx, gpus_per_inst) / 0.85
                    }
                    _ => cm.decode_step_s(batch.len(), mean_ctx, g),
                }
                .max(cfg.heartbeat_s);
                v.free_at = t + dur;
                for rid in batch {
                    let q = reqs.get_mut(&rid).unwrap();
                    q.emitted += 1;
                    rec.on_token(rid, t + dur);
                    if q.emitted >= q.req.output_len {
                        q.phase = RPhase::Done;
                        rec.on_finish(rid, t + dur);
                    }
                }
            }
            // Retire finished requests.
            v.active.retain(|r| reqs[r].phase != RPhase::Done);
        }

        // ---- split transient TP groups whose work drained -----------------
        let mut split_any = false;
        let mut new_vengs = Vec::with_capacity(vengs.len());
        for v in vengs.drain(..) {
            let tp_work_left = v
                .active
                .iter()
                .any(|r| !reqs[r].paused && reqs[r].phase != RPhase::Done);
            let has_paused = v.active.iter().any(|r| reqs[r].paused);
            // Split only under pressure: queued DP work or hard-preempted
            // requests waiting to resume.  An idle merged group is kept so
            // low-load traffic stays in the TP regime (Use Case 1).
            if v.transient && !tp_work_left && (!queue.is_empty() || has_paused) {
                // Resume paused DP requests on the split unit vengs.
                let paused: Vec<u64> = v.active.clone();
                for i in 0..v.m {
                    let mut unit = VEng { m: 1, free_at: v.free_at, active: vec![], transient: false };
                    // Round-robin the resumed requests over the units.
                    for (j, rid) in paused.iter().enumerate() {
                        if j % v.m == i {
                            reqs.get_mut(rid).unwrap().paused = false;
                            unit.active.push(*rid);
                        }
                    }
                    new_vengs.push(unit);
                }
                n_switches += 1;
                split_any = true;
            } else {
                new_vengs.push(v);
            }
        }
        vengs = new_vengs;
        let _ = split_any;
    }

    SimOutcome { recorder: rec, rejected, n_switches }
}

fn kv_room(
    v: &VEng,
    reqs: &BTreeMap<u64, SimReq>,
    cm: &CostModel,
    gpus_per_inst: usize,
) -> usize {
    let cap = cm.kv_capacity_tokens(v.m * gpus_per_inst);
    let used: usize = v
        .active
        .iter()
        .map(|r| reqs[r].req.prompt_len + reqs[r].emitted)
        .sum();
    cap.saturating_sub(used)
}

/// Merge contiguous unit vengs into a transient TP group for `rid`.
/// Returns the bind time (incl. live-switch latency) or None if no group is
/// currently formable.
#[allow(clippy::too_many_arguments)]
fn bind_tp_sim(
    system: SimSystem,
    vengs: &mut Vec<VEng>,
    reqs: &mut BTreeMap<u64, SimReq>,
    rid: u64,
    want_m: usize,
    t: f64,
    cm: &CostModel,
    _cfg: &SimConfig,
    n_switches: &mut usize,
    gpus_per_inst: usize,
) -> Option<f64> {
    // An existing group of the right width with KV + batch room?
    let total = reqs[&rid].req.prompt_len + reqs[&rid].req.output_len;
    let batch_cap = |v: &VEng| {
        if matches!(system, SimSystem::Shift) {
            _cfg.max_batch * v.m
        } else {
            _cfg.max_batch
        }
    };
    if let Some(v) = vengs.iter_mut().find(|v| {
        v.m == want_m
            && v.active.len() < batch_cap(v)
            && kv_room(v, reqs, cm, gpus_per_inst) >= total
    }) {
        // Static TP / Shift: groups are permanent; Flying: join transient.
        if matches!(system, SimSystem::StaticTp(_) | SimSystem::Shift) || v.transient || v.m == 1 {
            v.active.push(rid);
            reqs.get_mut(&rid).unwrap().phase = RPhase::Prefill;
            return Some(t);
        }
    }
    if !matches!(system, SimSystem::Flying | SimSystem::FlyingSequential) {
        return None;
    }

    // Collect want_m unit vengs to merge (prefer idle ones).
    let mut unit_idx: Vec<usize> = (0..vengs.len()).filter(|&i| vengs[i].m == 1).collect();
    if unit_idx.len() < want_m {
        return None;
    }
    unit_idx.sort_by_key(|&i| vengs[i].active.len());
    let chosen: Vec<usize> = unit_idx.into_iter().take(want_m).collect();

    let busy = chosen.iter().any(|&i| !vengs[i].active.is_empty());
    if busy && system == SimSystem::FlyingSequential {
        // Sequential switching: wait for the stragglers (Fig 7a) — the
        // request stays queued and the chosen engines drain naturally.
        return None;
    }

    // Hard preempt (Fig 7c): pause members' DP requests in place.
    let mut merged = VEng {
        m: want_m,
        free_at: chosen
            .iter()
            .map(|&i| vengs[i].free_at)
            .fold(t, f64::max)
            + cm.live_switch_s(),
        active: vec![],
        transient: true,
    };
    for &i in &chosen {
        for r in &vengs[i].active {
            reqs.get_mut(r).unwrap().paused = true;
            merged.active.push(*r);
        }
    }
    merged.active.push(rid);
    reqs.get_mut(&rid).unwrap().phase = RPhase::Prefill;
    let bind_t = merged.free_at;
    // Remove chosen (descending to keep indices valid), insert merged.
    let mut chosen_sorted = chosen;
    chosen_sorted.sort_unstable_by(|a, b| b.cmp(a));
    for i in chosen_sorted {
        vengs.remove(i);
    }
    vengs.push(merged);
    *n_switches += 1;
    Some(bind_t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::costmodel::{HwSpec, PaperModel};
    use crate::workload::{generate, WorkloadCfg};

    fn cm() -> CostModel {
        CostModel::new(HwSpec::default(), PaperModel::llama70b())
    }

    fn bursty(n: usize) -> Vec<Request> {
        generate(&WorkloadCfg::paper_full(7, n))
    }

    fn run(system: SimSystem, n: usize) -> SimOutcome {
        simulate(system, &cm(), &bursty(n), &SimConfig::default())
    }

    #[test]
    fn all_systems_complete_the_trace() {
        for sys in [
            SimSystem::StaticDp,
            SimSystem::StaticTp(4),
            SimSystem::Shift,
            SimSystem::Flying,
            SimSystem::FlyingSequential,
        ] {
            let o = run(sys, 300);
            let s = o.recorder.summary(None);
            assert_eq!(s.finished + o.rejected.len(), 300, "{}", sys.label());
            assert!(s.mean_ttft > 0.0, "{}", sys.label());
        }
    }

    #[test]
    fn paper_shape_dp_beats_tp_on_burst_ttft() {
        // Under bursty load, static TP queues badly; DP and FLYING drain.
        let dp = run(SimSystem::StaticDp, 600).recorder.summary(None);
        let tp = run(SimSystem::StaticTp(4), 600).recorder.summary(None);
        let fly = run(SimSystem::Flying, 600).recorder.summary(None);
        assert!(
            tp.p90_ttft > 1.5 * dp.p90_ttft,
            "tp {} vs dp {}",
            tp.p90_ttft,
            dp.p90_ttft
        );
        assert!(
            fly.p90_ttft < 0.75 * tp.p90_ttft,
            "fly {} vs tp {}",
            fly.p90_ttft,
            tp.p90_ttft
        );
    }

    #[test]
    fn paper_shape_throughput_flying_near_dp() {
        let dp = run(SimSystem::StaticDp, 600).recorder.summary(None);
        let tp = run(SimSystem::StaticTp(4), 600).recorder.summary(None);
        let fly = run(SimSystem::Flying, 600).recorder.summary(None);
        // Fig 9: FLYING retains ~95% of DP peak throughput and beats TP
        // by >1.5x.
        assert!(fly.peak_throughput > 0.8 * dp.peak_throughput);
        assert!(fly.peak_throughput > 1.3 * tp.peak_throughput);
    }

    #[test]
    fn flying_switches_happen() {
        let o = run(SimSystem::Flying, 300);
        assert!(o.n_switches > 0);
    }

    #[test]
    fn deterministic() {
        let a = run(SimSystem::Flying, 200).recorder.summary(None);
        let b = run(SimSystem::Flying, 200).recorder.summary(None);
        assert_eq!(a.mean_ttft, b.mean_ttft);
        assert_eq!(a.peak_throughput, b.peak_throughput);
    }
}
