//! Discrete-event cluster simulator: an 8×H200 node serving paper-scale
//! models under the four systems of §6 (static DP, static TP,
//! Shift-Parallelism, FLYING SERVING), driven by the *same* `Policy`
//! implementations as the real thread cluster.
//!
//! Virtual engines ("vengs") partition the node's serving instances; FLYING
//! merges contiguous unit vengs into TP groups and splits them back, paying
//! the paper's 15 ms live-switch cost, while static systems keep a fixed
//! partition (and pay a cold restart if they must change it).  Every event
//! lands in a `metrics::Recorder`, so the benches read the simulator with
//! the same summaries/time-series as the real path.
//!
//! # The event-driven core
//!
//! This is the O(n log n) rewrite of the loop-based reference kept in
//! `sim::reference` (same decisions, same outcomes — asserted by the
//! differential property tests in `tests/sim_equivalence.rs`):
//!
//!  * **Typed event heap.**  A `BinaryHeap` of (arrival, engine-free,
//!    switch-settle) events replaces the per-iteration min-scan; stale
//!    events are invalidated lazily by per-veng stamps.
//!  * **The scheduling kernel (ISSUE 5).**  Waiting rings, the admission-
//!    walk skeleton, dirty tracking, the engine bitmask index, and every
//!    decision predicate (constraint tiers, least-loaded pick, backfill
//!    horizon, migrate gate) live in `crate::sched` — the same kernel the
//!    real coordinator drives, so decisions cannot fork between the two
//!    paths.  This file is the *driver*: it feeds the kernel `SchedEvent`s
//!    and stamps its placements onto the event heap.  One FIFO ring per
//!    priority level replaces the full (priority, arrival) re-sort each
//!    iteration; the walk runs only after an event that can change an
//!    admission decision (arrival, completion, merge/split) — between
//!    those, decode steps only shrink capacity and never flip a decision,
//!    so skipped walks are provably identical to the reference's no-op
//!    walks.  (The sim deliberately does not emit `ControlPlan` dirtying:
//!    re-walking on plan adoption was never the PR-1/2 behavior the
//!    differential harness pins.)
//!  * **Dense request slab + incremental KV accounting.**  Requests live in
//!    a `Vec` indexed by admission order (no id-map lookups on the hot
//!    path), and each veng tracks Σ(prompt+emitted) incrementally instead
//!    of recomputing it per admission probe.
//!  * **Explicit stall handling.**  The reference's heartbeat spin ("queue
//!    non-empty, nothing running, nothing arriving") is detected and
//!    resolved by deterministically rejecting the stuck requests.
//!
//! Steady-state scratch (rings, batch buffers, split buffers) is allocated
//! once and recycled, so the event loop itself is allocation-free apart
//! from heap growth during warmup.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::control::ControlRuntime;
use crate::coordinator::policy::{ModeDecision, Policy, Snapshot};
use crate::metrics::{RecSlot, Recorder};
use crate::sched::{lifecycle, EngineIndex, Kernel, Placement, SchedEvent};
use crate::workload::{Priority, Request};

use super::costmodel::CostModel;

#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Chunked-prefill chunk size (tokens).
    pub chunk_tokens: usize,
    /// Max decode batch per virtual engine.
    pub max_batch: usize,
    /// Scheduling-iteration quantum lower bound (control-plane heartbeat).
    pub heartbeat_s: f64,
    /// Drain backfill + incremental settle (ISSUE 3).  Off (default): a
    /// merge idles every chosen member from its free point until the
    /// slowest straggler's step completes plus the live-switch latency —
    /// byte-identical to `sim::reference`.  On: chosen members become
    /// *backfill shells* that keep executing through the transition window
    /// (resident decode steps that fit before the settle point, plus
    /// bounded new elastic work admitted by the kernel's horizon
    /// predicate) and fold into the forming TP group per-member at their
    /// settle stamp.  A shell whose original residents have drained may
    /// hold *several* concurrent backfills (ISSUE 5): each admission is
    /// charged behind the shell's running work bound — a decode batch
    /// never takes longer than the sum of its members' solo steps, so the
    /// bound is a sound over-approximation and no backfill can cross the
    /// settle stamp.  Outcomes may legitimately differ from the reference
    /// when on; `SimOutcome::switch_stall_s` measures the reclaimed idle
    /// capacity either way.
    pub switch_backfill: bool,
    /// Layout-preserving KV migration (ISSUE 4).  Off (default): a DP→TP
    /// merge hard-pauses every resident until the group splits — byte-
    /// identical to `sim::reference`.  On: each decode-phase resident is
    /// judged by the shared `CostModel::migrate_wins` rule (KV bytes over
    /// the link vs re-prefill FLOPs — the identical rule the real
    /// coordinator applies); winners are *carried live* into the forming
    /// group (their KV migrated into the TP layout, `migrate_t` charged to
    /// the merge horizon) and keep decoding through the window, and are
    /// gathered back to unit engines when the group splits.
    /// `SimOutcome::recompute_tokens_avoided` counts the tokens carried.
    pub switch_migrate: bool,
    /// Flight recorder (ISSUE 7).  Off (default): no journal is allocated
    /// and every `record` call is a branch-and-return — byte-identical
    /// outcomes and metrics.  On: switch lifecycle, migration, backfill
    /// admission, exec, and control-tick events land in a fixed-capacity
    /// ring (`obs::DEFAULT_JOURNAL_CAP`), surfaced as
    /// `SimOutcome::journal`.  Recording is O(1)/allocation-free either
    /// way; only decisions already made are observed, never steered.
    pub trace: bool,
    /// Step-pipeline overlap (ISSUE 9).  Off (default): byte-identical to
    /// the pre-overlap event core on every scenario.  On: mirrors the real
    /// coordinator's asynchronous migration collectives — the carried
    /// residents' `migrate_t` charge runs concurrently with the drain
    /// window instead of serially after it, so only the part that spills
    /// past the horizon stalls the merge.  The full charge still lands in
    /// `StallBreakdown::migration_s`; the concurrent part is credited back
    /// through `pipeline_overlap_s`, keeping the stall-attribution identity
    /// exact.
    pub overlap: bool,
    /// Cross-request prefix cache (ISSUE 10).  Off (default): admissions
    /// never consult request families — byte-identical to `sim::reference`
    /// on every scenario.  On: when a DP admission lands on a unit whose
    /// cache already holds an earlier same-family request's prefix, the
    /// shared tokens are adopted by reference (`sched::prefix_hit`, the
    /// identical predicate the real coordinator applies at token
    /// granularity) and skipped from prefill;
    /// `SimOutcome::prefill_tokens_avoided` counts them.  KV accounting
    /// stays conservative (full prompt charged) and eviction is not
    /// modeled — the simulator measures the prefill-compute win only.
    pub prefix_cache: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            chunk_tokens: 2048,
            max_batch: 48,
            heartbeat_s: 0.004,
            switch_backfill: false,
            switch_migrate: false,
            trace: false,
            overlap: false,
            prefix_cache: false,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SimSystem {
    /// One instance per min-GPU slice, never merged.
    StaticDp,
    /// Fixed merge of `m` instances per group.
    StaticTp(usize),
    /// Shift-Parallelism (arXiv:2509.16495): one cluster-wide group that
    /// flips between latency-optimal TP and throughput-oriented SP.
    Shift,
    /// FLYING SERVING with hard preempt.
    Flying,
    /// FLYING SERVING with sequential (non-preemptive) switching — the
    /// ablation of §5.2.
    FlyingSequential,
}

impl SimSystem {
    pub fn label(&self) -> &'static str {
        match self {
            SimSystem::StaticDp => "static-dp",
            SimSystem::StaticTp(_) => "static-tp",
            SimSystem::Shift => "shift-parallelism",
            SimSystem::Flying => "flying",
            SimSystem::FlyingSequential => "flying-sequential",
        }
    }
}

pub struct SimOutcome {
    pub recorder: Recorder,
    pub rejected: Vec<u64>,
    pub n_switches: usize,
    /// Switch-stall engine-seconds: idle instance-time spent inside
    /// merge-transition windows (from each chosen member's free point to
    /// the group's settle point), plus KV-migration transfer time charged
    /// to the horizon when `switch_migrate` carries residents, minus the
    /// work backfill shells executed inside those windows.  With
    /// `switch_backfill` off nothing is credited back, so off-vs-on on the
    /// same trace measures exactly the capacity the drain barrier wastes.
    /// `stall` decomposes this aggregate.  (The loop reference does not
    /// track this; `outcomes_equivalent` ignores it.)
    pub switch_stall_s: f64,
    /// Tokens of cached KV carried live across a DP→TP layout flip by
    /// migration (`switch_migrate`), counted once per carried request at
    /// merge/fold time — tokens a recompute-based carry would have
    /// re-prefilled, the same once-per-promotion semantics as
    /// `ClusterOutcome::recompute_tokens_avoided` on the real path.  The
    /// split-time inverse gather is not re-counted.  Always 0 with the flag
    /// off (and in the loop reference); `outcomes_equivalent` ignores it.
    pub recompute_tokens_avoided: usize,
    /// Prompt tokens adopted from the prefix cache at admission
    /// (`prefix_cache`) — tokens that were never prefilled because an
    /// earlier same-family request already resident on the unit cached
    /// them.  Mirrors `ClusterOutcome::prefill_tokens_avoided` on the real
    /// path.  Always 0 with the flag off (and in the loop reference);
    /// `outcomes_equivalent` ignores it.
    pub prefill_tokens_avoided: usize,
    /// Stall attribution (ISSUE 7): where `switch_stall_s` goes.  Each
    /// component accumulates at the exact site the aggregate is touched, so
    /// `stall.total()` reconstructs `switch_stall_s` to FP rounding (the
    /// bench hard-gates 1e-9).  Always populated — four f64 adds per
    /// switch, no flag.  (The loop reference leaves it zeroed;
    /// `outcomes_equivalent` ignores it.)
    pub stall: crate::obs::StallBreakdown,
    /// Flight-recorder journal when `SimConfig::trace` is on, else `None`.
    pub journal: Option<crate::obs::Journal>,
}

/// Outcome equivalence between two simulator runs: identical completion
/// sets, identical rejection sets, identical switch counts.  This is the
/// contract the event-driven core maintains against `sim::reference` —
/// shared by `tests/sim_equivalence.rs` and `benches/sched_hotpath.rs` so
/// the definition cannot drift.  (Timing-derived metrics are deliberately
/// excluded: stall/idle resolution may shift timestamps by a heartbeat
/// quantum without changing any scheduling decision.)
pub fn outcomes_equivalent(a: &SimOutcome, b: &SimOutcome) -> Result<(), String> {
    let finished = |o: &SimOutcome| -> Vec<u64> {
        o.recorder
            .records()
            .filter(|(_, r)| r.finished.is_some())
            .map(|(&id, _)| id)
            .collect()
    };
    if finished(a) != finished(b) {
        return Err("completion sets diverge".into());
    }
    let mut rej_a = a.rejected.clone();
    let mut rej_b = b.rejected.clone();
    rej_a.sort_unstable();
    rej_b.sort_unstable();
    if rej_a != rej_b {
        return Err("rejection sets diverge".into());
    }
    if a.n_switches != b.n_switches {
        return Err(format!(
            "switch counts diverge ({} vs {})",
            a.n_switches, b.n_switches
        ));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Internal state
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq)]
enum RPhase {
    Queued,
    Prefill,
    Decode,
    Done,
}

/// One admitted request, stored in a dense slab indexed by admission order.
struct SimReq {
    id: u64,
    arrival: f64,
    prompt_len: usize,
    output_len: usize,
    tp_demand: Option<usize>,
    phase: RPhase,
    prefilled: usize,
    emitted: usize,
    paused: bool,
    /// Carried live into a TP group by KV migration (`switch_migrate`):
    /// keeps decoding through the merge window and is gathered back to a
    /// unit engine at split time.  Never set with the flag off.
    migrated: bool,
    /// Admitted onto a backfill shell under the horizon predicate
    /// (`switch_backfill` only).  A shell may host several concurrent
    /// backfills, but never a backfill alongside an original resident.
    backfill: bool,
    /// Shared-prefix family tag from the trace (`prefix_cache` only
    /// consults it; pure metadata otherwise).
    family: Option<(u64, usize)>,
    rec: RecSlot,
}

fn kv_tokens(r: &SimReq) -> usize {
    r.prompt_len + r.emitted
}

/// A virtual engine: `m` merged serving instances.
struct VEng {
    m: usize,
    free_at: f64,
    active: Vec<u32>,
    /// Set for a merged veng that must split back when its TP work drains.
    transient: bool,
    /// Stable identity for heap events (indices shift on merge/split).
    handle: u32,
    /// Bumped whenever pending events for this veng become meaningless
    /// (step rescheduled, veng went idle, veng destroyed).
    stamp: u32,
    /// Σ kv_tokens over `active`, maintained incrementally.
    kv_used: usize,
    /// Backfill shell (`switch_backfill` only): this unit instance is
    /// committed to a forming TP group and keeps serving until `settle_at`,
    /// when its remaining residents pause into `merge_into` and the shell
    /// disappears.  `f64::INFINITY` = not a shell.
    settle_at: f64,
    /// Handle of the forming group this shell folds into at `settle_at`.
    merge_into: u32,
    /// KV tokens pre-pledged into the forming group at merge time (the
    /// residents' footprint snapshot), reconciled against their actual
    /// footprint at settle so mid-window joins to the group cannot
    /// over-commit its KV.
    pledged_kv: usize,
    /// Instance-bit ownership for the kernel's `EngineIndex`: a veng of
    /// width `m` carries the `m` bits of the serving instances merged into
    /// it.  Bits travel with the instances — merges union them, shells keep
    /// them (marked draining) until the fold hands them to the forming
    /// group, splits deal them back one per unit — so the index's
    /// `idle_count` is exactly the old Σ-m-over-idle-vengs fold, O(1).
    unit_bits: u64,
    /// Batched-shell backfill bound (`switch_backfill`): a running upper
    /// bound on when every backfill admitted to this shell completes.  The
    /// next admission starts no earlier than this, which makes concurrent
    /// backfills a sound over-approximation (a decode batch never takes
    /// longer than the sum of its members' solo steps), so a shell can hold
    /// several backfills without ever crossing its settle stamp.
    bf_bound: f64,
}

impl VEng {
    fn is_shell(&self) -> bool {
        self.settle_at.is_finite()
    }
}

#[derive(Clone, Copy, Debug)]
enum EvKind {
    /// The trace request at sorted position `seq` becomes visible.
    Arrival { seq: u32 },
    /// A veng's in-flight step completes.
    EngineFree { veng: u32, stamp: u32 },
    /// A freshly-merged TP group finishes its live switch.
    SwitchSettle { veng: u32, stamp: u32 },
}

#[derive(Clone, Copy, Debug)]
struct Event {
    t: f64,
    kind: EvKind,
}

impl Event {
    /// Deterministic tie-break rank at equal times.
    fn rank(&self) -> (u8, u32) {
        match self.kind {
            EvKind::Arrival { seq } => (0, seq),
            EvKind::SwitchSettle { veng, .. } => (1, veng),
            EvKind::EngineFree { veng, .. } => (2, veng),
        }
    }
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    // Reversed so the std max-heap pops the earliest event first.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .t
            .total_cmp(&self.t)
            .then_with(|| other.rank().cmp(&self.rank()))
    }
}

// ---------------------------------------------------------------------------
// The simulator
// ---------------------------------------------------------------------------

pub fn simulate(
    system: SimSystem,
    cm: &CostModel,
    trace: &[Request],
    cfg: &SimConfig,
) -> SimOutcome {
    simulate_inner(system, cm, trace, cfg, None)
}

/// FLYING SERVING under an adaptive reconfiguration control plane: the
/// event core's Flying machinery with per-request decisions steered by the
/// [`ControlRuntime`]'s current plan instead of the bare `FlyingPolicy`.
///
/// Telemetry taps feed the runtime the true event stream (arrivals with
/// their length mix, first-token TTFTs, decode-step latencies), and control
/// ticks fire on the simulation clock — the identical runtime drives the
/// real coordinator through `control::AdaptivePolicy`, so a controller's
/// decisions are byte-identical across both paths given the same events.
///
/// With `StaticController::hold()` the plan never leaves `Plan::Hold`, every
/// decision falls through to `FlyingPolicy`, and the outcome matches
/// `simulate(SimSystem::Flying, ..)` exactly (asserted by the differential
/// tests).
pub fn simulate_adaptive(
    cm: &CostModel,
    trace: &[Request],
    cfg: &SimConfig,
    rt: &mut ControlRuntime,
) -> SimOutcome {
    simulate_inner(SimSystem::Flying, cm, trace, cfg, Some(rt))
}

fn simulate_inner(
    system: SimSystem,
    cm: &CostModel,
    trace: &[Request],
    cfg: &SimConfig,
    mut ctrl: Option<&mut ControlRuntime>,
) -> SimOutcome {
    assert!(
        trace.iter().all(|r| r.arrival.is_finite()),
        "simulate: trace contains non-finite arrival times (validate with workload::validate)"
    );

    let n_inst = cm.hw.n_gpus / cm.model.min_gpus;
    let gpus_per_inst = cm.model.min_gpus;
    // KV capacity per group width, precomputed once (pure function of m).
    let cap_by_m: Vec<usize> = (0..=n_inst)
        .map(|m| if m == 0 { 0 } else { cm.kv_capacity_tokens(m * gpus_per_inst) })
        .collect();
    let dp_cap = cap_by_m[1];
    let live_switch_s = cm.live_switch_s();

    assert!(n_inst <= 64, "EngineIndex bitmasks support at most 64 serving instances");
    let new_veng = |m: usize, handle: u32| VEng {
        m,
        free_at: 0.0,
        active: vec![],
        transient: false,
        handle,
        stamp: 0,
        kv_used: 0,
        settle_at: f64::INFINITY,
        merge_into: u32::MAX,
        pledged_kv: 0,
        unit_bits: 0,
        bf_bound: f64::NEG_INFINITY,
    };
    let mut vengs: Vec<VEng> = match system {
        SimSystem::StaticDp | SimSystem::Flying | SimSystem::FlyingSequential => {
            (0..n_inst).map(|i| new_veng(1, i as u32)).collect()
        }
        SimSystem::StaticTp(m) => {
            let m = m.min(n_inst).max(1);
            (0..n_inst / m).map(|i| new_veng(m, i as u32)).collect()
        }
        SimSystem::Shift => vec![new_veng(n_inst, 0)],
    };
    let mut next_handle = vengs.len() as u32;
    let mut handle_pos: Vec<usize> = (0..vengs.len()).collect();

    // The scheduling kernel: waiting rings + engine index + dirty tracking.
    // Assign each veng its instance bits and seed the index (everything
    // starts unit-or-group, idle).
    let mut kernel: Kernel<u32> = Kernel::new();
    {
        let mut next_bit = 0usize;
        for v in vengs.iter_mut() {
            let mut bits = 0u64;
            for _ in 0..v.m {
                bits |= 1u64 << next_bit;
                next_bit += 1;
            }
            v.unit_bits = bits;
            kernel.index.set_unit(bits, v.m == 1);
            kernel.index.set_idle(bits, true);
        }
    }

    // Arrival order (stable by arrival time, ties by trace position — the
    // same order the reference's stable sort produces).
    let mut order: Vec<u32> = (0..trace.len() as u32).collect();
    order.sort_by(|&a, &b| trace[a as usize].arrival.total_cmp(&trace[b as usize].arrival));

    let mut reqs: Vec<SimReq> = Vec::with_capacity(trace.len());
    let mut rec = Recorder::new();
    let mut rejected: Vec<u64> = Vec::new();
    let mut n_switches = 0usize;
    let mut switch_stall_s = 0.0f64;
    let mut recompute_avoided = 0usize;
    let mut prefill_avoided = 0usize;
    let mut stall = crate::obs::StallBreakdown::default();
    // Prefix-cache registry (ISSUE 10): per unit-instance bit, the families
    // already resident there as (family_id, longest bound prefix_len).  Keyed
    // by the instance bit (not the veng handle) so cache identity survives
    // merge/split churn the way physical blocks do on the real path.  Only
    // consulted when `cfg.prefix_cache` is armed.
    let mut families_by_bit: Vec<Vec<(u64, usize)>> = vec![Vec::new(); n_inst];
    let prefix = cfg.prefix_cache;
    let mut journal = if cfg.trace {
        crate::obs::Journal::new(crate::obs::DEFAULT_JOURNAL_CAP)
    } else {
        crate::obs::Journal::off()
    };
    let backfill = cfg.switch_backfill;
    let migrate = cfg.switch_migrate;
    let mut policy = crate::coordinator::policy::FlyingPolicy::default();

    let mut heap: BinaryHeap<Event> = BinaryHeap::with_capacity(4 * vengs.len() + 8);
    let mut next_arr = 0usize;
    if let Some(&first) = order.first() {
        heap.push(Event {
            t: trace[first as usize].arrival,
            kind: EvKind::Arrival { seq: 0 },
        });
    }

    let mut t = 0.0f64;

    // Reusable scratch (allocated once, recycled every round).
    let mut batch: Vec<u32> = Vec::new();
    let mut unit_scratch: Vec<usize> = Vec::new();
    let mut split_buf: Vec<VEng> = Vec::new();

    'outer: loop {
        // ---- advance the clock to the next valid event --------------------
        let mut next_t = f64::INFINITY;
        while let Some(e) = heap.peek() {
            let stale = match e.kind {
                EvKind::Arrival { seq } => (seq as usize) < next_arr,
                EvKind::EngineFree { veng, stamp } | EvKind::SwitchSettle { veng, stamp } => {
                    let pos = handle_pos[veng as usize];
                    !(pos < vengs.len()
                        && vengs[pos].handle == veng
                        && vengs[pos].stamp == stamp)
                }
            };
            if stale {
                heap.pop();
                continue;
            }
            next_t = e.t;
            break;
        }
        if next_t.is_infinite() {
            if kernel.rings.is_empty() {
                break 'outer;
            }
            if !kernel.walk_pending() {
                // Stall (the reference's heartbeat spin): queue non-empty,
                // nothing running, nothing arriving, and the last scheduling
                // pass changed nothing.  Reject deterministically.
                while let Some(ri) = kernel.rings.pop_any() {
                    let q = &mut reqs[ri as usize];
                    q.phase = RPhase::Done;
                    rejected.push(q.id);
                    rec.on_finish_at(q.rec, t);
                }
                break 'outer;
            }
            // Walk pending: fall through and run one more scheduling pass at
            // the current time (a split/merge may still unblock the queue).
        } else {
            t = t.max(next_t);
            // Consume every event at or before t; the same-time cascade
            // below services all of them in one pass.
            while let Some(e) = heap.peek() {
                if e.t > t {
                    break;
                }
                heap.pop();
            }
        }

        // ---- same-time cascade: admit → assign → execute → split ----------
        // Repeats while some veng still has work runnable at `t` (the
        // reference re-iterates its outer loop at the same time in that
        // case, e.g. after a split resumed paused requests).
        let mut rounds = 0usize;
        loop {
            rounds += 1;
            assert!(rounds < 100_000, "simulate: same-time livelock at t={t}");

            // ---- incremental settle: fold due backfill shells -------------
            // Each shell's remaining residents hard-pause into the forming
            // group it merged toward (the per-member half of the switch);
            // the shell itself disappears.  Residents that completed during
            // the transition window simply never pause — the backfill win.
            if backfill && vengs.iter().any(|v| v.settle_at <= t) {
                for si in 0..vengs.len() {
                    if vengs[si].settle_at > t {
                        continue;
                    }
                    let target = handle_pos[vengs[si].merge_into as usize];
                    debug_assert!(
                        target < vengs.len()
                            && vengs[target].handle == vengs[si].merge_into,
                        "shell settle: forming group vanished"
                    );
                    let moved = std::mem::take(&mut vengs[si].active);
                    vengs[si].kv_used = 0;
                    // The shell's instance bits join the forming group: no
                    // longer unit, no longer draining (idle stays cleared —
                    // the group is executing its TP work).
                    let shell_bits = vengs[si].unit_bits;
                    journal.record(
                        t,
                        crate::obs::Event::MemberSettle {
                            group: vengs[si].merge_into,
                            members: shell_bits,
                        },
                    );
                    vengs[si].unit_bits = 0;
                    kernel.index.set_draining(shell_bits, false);
                    kernel.index.set_unit(shell_bits, false);
                    vengs[target].unit_bits |= shell_bits;
                    // Reconcile the merge-time pledge against the residents'
                    // actual footprint now (some finished, others grew).
                    vengs[target].kv_used -= vengs[si].pledged_kv;
                    let g_new = vengs[target].m * gpus_per_inst;
                    for &r in moved.iter() {
                        let q = &mut reqs[r as usize];
                        q.backfill = false;
                        if lifecycle::carry_wins(
                            cm,
                            migrate,
                            q.phase == RPhase::Decode,
                            kv_tokens(q),
                            g_new,
                        ) {
                            // Carried live: the resident's KV migrates into
                            // the TP layout and it keeps decoding inside the
                            // group (the shell already absorbed the
                            // transition window, so no extra charge here).
                            q.migrated = true;
                            recompute_avoided += kv_tokens(q);
                            journal.record(
                                t,
                                crate::obs::Event::MigrateApply {
                                    rid: q.id,
                                    tokens: kv_tokens(q) as u64,
                                    cost_s: 0.0,
                                },
                            );
                        } else {
                            q.paused = true;
                        }
                        vengs[target].kv_used += kv_tokens(q);
                        vengs[target].active.push(r);
                    }
                }
                vengs.retain(|v| !(v.settle_at <= t));
                for (idx, v) in vengs.iter().enumerate() {
                    handle_pos[v.handle as usize] = idx;
                }
                kernel.on_event(SchedEvent::Settle);
            }

            // ---- admissions ----------------------------------------------
            let mut consumed_arrival = false;
            while next_arr < order.len() && trace[order[next_arr] as usize].arrival <= t {
                let r = &trace[order[next_arr] as usize];
                let slot = rec.on_arrival(r.id, r.arrival, r.priority, r.prompt_len);
                if let Some(rt) = ctrl.as_mut() {
                    rt.note_arrival(
                        r.arrival,
                        r.prompt_len,
                        r.output_len,
                        r.priority == Priority::High,
                    );
                }
                reqs.push(SimReq {
                    id: r.id,
                    arrival: r.arrival,
                    prompt_len: r.prompt_len,
                    output_len: r.output_len,
                    tp_demand: r.tp_demand,
                    phase: RPhase::Queued,
                    prefilled: 0,
                    emitted: 0,
                    paused: false,
                    migrated: false,
                    backfill: false,
                    family: r.prefix_family,
                    rec: slot,
                });
                kernel.on_event(SchedEvent::Arrival {
                    h: (reqs.len() - 1) as u32,
                    priority: r.priority,
                });
                next_arr += 1;
                consumed_arrival = true;
            }
            if consumed_arrival && next_arr < order.len() {
                heap.push(Event {
                    t: trace[order[next_arr] as usize].arrival,
                    kind: EvKind::Arrival { seq: next_arr as u32 },
                });
            }

            // ---- control tick (adaptive runs only) -----------------------
            // Fires on the simulation clock at the runtime's tick interval;
            // the `due` guard keeps non-tick iterations O(1).
            if let Some(rt) = ctrl.as_mut() {
                if rt.due(t) {
                    // Shells are committed capacity (their instances are
                    // already represented by the forming group's width), so
                    // they never count as idle or contribute pool capacity —
                    // encoded in the index maintenance (shell bits drop out
                    // of the idle mask at conversion), making this O(1).
                    let idle = kernel.index.idle_count();
                    debug_assert_eq!(
                        idle,
                        vengs
                            .iter()
                            .filter(|v| v.active.is_empty() && !v.is_shell())
                            .map(|v| v.m)
                            .sum::<usize>(),
                        "EngineIndex idle bits drifted from veng state"
                    );
                    let (kv_used, kv_cap) = vengs
                        .iter()
                        .filter(|v| !v.is_shell())
                        .fold((0usize, 0usize), |(u, c), v| (u + v.kv_used, c + cap_by_m[v.m]));
                    let kv_frac =
                        if kv_cap == 0 { 0.0 } else { kv_used as f64 / kv_cap as f64 };
                    rt.tick(t, kernel.rings.len(), kv_frac, idle, n_inst);
                    if let Some(info) = rt.last_tick() {
                        journal.record(t, crate::obs::Event::CtrlTick { info });
                    }
                }
            }

            // ---- assignment (the kernel walk; decision layer shared with
            // the real path) ------------------------------------------------
            if kernel.should_walk() {
                // KV pressure for the per-request snapshots, computed once
                // per walk: no sim-side decide path reads kv_frac (the
                // control plane consumes KV pressure at tick time, above),
                // so a value that goes slightly stale as the walk binds
                // requests is fine — and the O(n_engines) fold stays off
                // the per-request path PR 1 optimized.
                let (kv_used, kv_cap) = vengs
                    .iter()
                    .filter(|v| !v.is_shell())
                    .fold((0usize, 0usize), |(u, c), v| (u + v.kv_used, c + cap_by_m[v.m]));
                let walk_kv_frac = if kv_cap == 0 { 0.0 } else { kv_used as f64 / kv_cap as f64 };
                let mut walk = kernel.begin_walk();
                while let Some((ri, pri_high)) = walk.next() {
                    let placement = {
                        let riu = ri as usize;
                        let total = reqs[riu].prompt_len + reqs[riu].output_len;
                        let backlog_now = walk.backlog_now();
                        let decision = match system {
                            SimSystem::StaticDp => {
                                if total > dp_cap {
                                    ModeDecision::Reject
                                } else {
                                    ModeDecision::Dp
                                }
                            }
                            SimSystem::StaticTp(m) => {
                                if total > cap_by_m[m.min(n_inst)] {
                                    ModeDecision::Reject
                                } else {
                                    ModeDecision::Tp(m)
                                }
                            }
                            SimSystem::Shift => ModeDecision::Tp(n_inst),
                            SimSystem::Flying | SimSystem::FlyingSequential => {
                                // Idle capacity in *unit-instance* terms so
                                // the snapshot semantics match the real
                                // (fixed-engine) coordinator.  Shells are
                                // committed to a forming group, never idle —
                                // both facts are encoded in the kernel's
                                // index bits, so the query is O(1).
                                let idle = kernel.index.idle_count();
                                debug_assert_eq!(
                                    idle,
                                    vengs
                                        .iter()
                                        .filter(|v| v.active.is_empty() && !v.is_shell())
                                        .map(|v| v.m)
                                        .sum::<usize>(),
                                    "EngineIndex idle bits drifted from veng state"
                                );
                                let snap = Snapshot {
                                    now: t,
                                    queue_len: backlog_now,
                                    idle_engines: idle,
                                    n_engines: n_inst,
                                    dp_capacity_tokens: dp_cap,
                                    max_tp: n_inst,
                                    kv_frac: walk_kv_frac,
                                };
                                let (plen, olen, demand) = (
                                    reqs[riu].prompt_len,
                                    reqs[riu].output_len,
                                    reqs[riu].tp_demand,
                                );
                                let pri =
                                    if pri_high { Priority::High } else { Priority::Normal };
                                match ctrl.as_mut() {
                                    Some(rt) => rt.decide(plen, olen, pri, demand, &snap),
                                    None => policy.decide(plen, olen, pri, demand, &snap),
                                }
                            }
                        };
                        match decision {
                            ModeDecision::Reject => {
                                let q = &mut reqs[riu];
                                q.phase = RPhase::Done;
                                rejected.push(q.id);
                                rec.on_finish_at(q.rec, t);
                                Placement::Reject
                            }
                            ModeDecision::Dp => {
                                // Least-loaded unit veng with KV room and
                                // batch room (the kernel's first-among-
                                // equals tie-break).
                                let mut pick = crate::sched::LeastLoaded::new();
                                // Predicted shell completion of the picked
                                // candidate (None for non-shell picks),
                                // carried out of the filter loop so the
                                // admission below never re-runs the
                                // solo-completion walk.
                                let mut picked_fin: Option<f64> = None;
                                for (vi, v) in vengs.iter().enumerate() {
                                    if !(v.m == 1 || matches!(system, SimSystem::StaticDp)) {
                                        continue;
                                    }
                                    if v.active.len() >= cfg.max_batch {
                                        continue;
                                    }
                                    if cap_by_m[v.m].saturating_sub(v.kv_used) < total {
                                        continue;
                                    }
                                    let mut shell_fin: Option<f64> = None;
                                    if v.is_shell() {
                                        // Drain backfill: a shell admits only
                                        // backfill work (never alongside an
                                        // original resident), and only when
                                        // the kernel's horizon predicate —
                                        // exact here, since the cost model IS
                                        // the execution model — lands the
                                        // request inside the settle stamp.
                                        // Concurrent backfills start behind
                                        // the shell's running work bound
                                        // (`bf_bound`), the batched-shell
                                        // over-approximation.
                                        if v.active.iter().any(|&r| !reqs[r as usize].backfill) {
                                            continue;
                                        }
                                        let q = &reqs[riu];
                                        let start = t.max(v.free_at).max(v.bf_bound);
                                        shell_fin = crate::sched::backfill_fit(
                                            cm,
                                            start,
                                            q.prompt_len,
                                            q.output_len,
                                            gpus_per_inst,
                                            cfg.chunk_tokens,
                                            cfg.heartbeat_s,
                                            false,
                                            v.settle_at,
                                        );
                                        if shell_fin.is_none() {
                                            continue;
                                        }
                                    }
                                    let prev = pick.pick();
                                    pick.offer(vi, v.active.len());
                                    if pick.pick() != prev {
                                        picked_fin = shell_fin;
                                    }
                                }
                                match pick.pick() {
                                    Some(vi) => {
                                        let was_shell = vengs[vi].is_shell();
                                        if let Some(fin) = picked_fin {
                                            // Fold this admission into the
                                            // shell's work bound so the next
                                            // concurrent backfill is charged
                                            // behind it.
                                            debug_assert!(was_shell);
                                            vengs[vi].bf_bound = fin;
                                            reqs[riu].backfill = true;
                                            journal.record(
                                                t,
                                                crate::obs::Event::BackfillAdmit {
                                                    rid: reqs[riu].id,
                                                    engine: vengs[vi].handle,
                                                    fit_s: fin,
                                                    horizon_s: vengs[vi].settle_at,
                                                },
                                            );
                                        }
                                        let used = kv_tokens(&reqs[riu]);
                                        let v = &mut vengs[vi];
                                        v.active.push(ri);
                                        v.kv_used += used;
                                        kernel.index.set_idle(v.unit_bits, false);
                                        if v.free_at > t {
                                            v.stamp += 1;
                                            heap.push(Event {
                                                t: v.free_at,
                                                kind: EvKind::EngineFree {
                                                    veng: v.handle,
                                                    stamp: v.stamp,
                                                },
                                            });
                                        }
                                        if prefix {
                                            // Prefix-cache admission (ISSUE
                                            // 10): adopt the family's shared
                                            // tokens when an earlier member
                                            // already seeded this unit's
                                            // cache; the hit is computed by
                                            // the shared kernel predicate at
                                            // token granularity (bt = 1).
                                            let bit = vengs[vi]
                                                .unit_bits
                                                .trailing_zeros()
                                                as usize;
                                            if let Some((fid, plen)) = reqs[riu].family {
                                                let fams = &mut families_by_bit[bit];
                                                if let Some(&(_, seen)) =
                                                    fams.iter().find(|e| e.0 == fid)
                                                {
                                                    let hit = crate::sched::prefix_hit(
                                                        seen.min(plen),
                                                        reqs[riu].prompt_len,
                                                        1,
                                                    );
                                                    if hit > 0 {
                                                        reqs[riu].prefilled = hit;
                                                        prefill_avoided += hit;
                                                        journal.record(
                                                            t,
                                                            crate::obs::Event::PrefixHit {
                                                                rid: reqs[riu].id,
                                                                tokens: hit as u64,
                                                            },
                                                        );
                                                    }
                                                }
                                                match fams.iter_mut().find(|e| e.0 == fid) {
                                                    Some(e) => e.1 = e.1.max(plen),
                                                    None => fams.push((fid, plen)),
                                                }
                                            }
                                        }
                                        let q = &mut reqs[riu];
                                        q.phase = RPhase::Prefill;
                                        rec.on_first_sched_at(q.rec, t);
                                        Placement::Dp { unit: vi as u32, backfill: was_shell }
                                    }
                                    None => {
                                        // FLYING at low load: if every engine
                                        // is merged into a live TP group and
                                        // there is NO backlog, the request
                                        // joins the group (the paper's
                                        // "opportunistically TP" regime).
                                        let mut joined: Option<usize> = None;
                                        if matches!(
                                            system,
                                            SimSystem::Flying | SimSystem::FlyingSequential
                                        ) && backlog_now == 0
                                        {
                                            for (vi, v) in vengs.iter_mut().enumerate() {
                                                if v.transient
                                                    && v.active
                                                        .iter()
                                                        .filter(|&&r| !reqs[r as usize].paused)
                                                        .count()
                                                        < 8
                                                    && cap_by_m[v.m].saturating_sub(v.kv_used)
                                                        >= total
                                                {
                                                    let used = kv_tokens(&reqs[riu]);
                                                    v.active.push(ri);
                                                    v.kv_used += used;
                                                    kernel.index.set_idle(v.unit_bits, false);
                                                    if v.free_at > t {
                                                        v.stamp += 1;
                                                        heap.push(Event {
                                                            t: v.free_at,
                                                            kind: EvKind::EngineFree {
                                                                veng: v.handle,
                                                                stamp: v.stamp,
                                                            },
                                                        });
                                                    }
                                                    joined = Some(vi);
                                                    break;
                                                }
                                            }
                                        }
                                        match joined {
                                            Some(vi) => {
                                                let q = &mut reqs[riu];
                                                q.phase = RPhase::Prefill;
                                                rec.on_first_sched_at(q.rec, t);
                                                Placement::Dp {
                                                    unit: vi as u32,
                                                    backfill: false,
                                                }
                                            }
                                            None => Placement::Defer,
                                        }
                                    }
                                }
                            }
                            ModeDecision::Tp(want_m) => {
                                let want_m = want_m.min(n_inst).max(1);
                                match bind_tp_sim(
                                    system,
                                    &mut vengs,
                                    &mut handle_pos,
                                    &mut next_handle,
                                    &mut reqs,
                                    &mut heap,
                                    &mut unit_scratch,
                                    &mut kernel.index,
                                    ri,
                                    want_m,
                                    t,
                                    live_switch_s,
                                    &cap_by_m,
                                    cfg,
                                    &mut n_switches,
                                    backfill,
                                    &mut switch_stall_s,
                                    cm,
                                    migrate,
                                    &mut recompute_avoided,
                                    &mut stall,
                                    &mut journal,
                                ) {
                                    Some(bind_t) => {
                                        rec.on_first_sched_at(reqs[riu].rec, bind_t);
                                        Placement::Tp { width: want_m as u32 }
                                    }
                                    None => Placement::Defer,
                                }
                            }
                        }
                    };
                    walk.settle(ri, pri_high, reqs[ri as usize].id, placement);
                }
                kernel.end_walk(walk);
            }

            // ---- execute one step on every ready veng with work -----------
            for vi in 0..vengs.len() {
                if vengs[vi].free_at > t || vengs[vi].active.is_empty() {
                    continue;
                }
                let g = vengs[vi].m * gpus_per_inst;
                // Prefill-first (chunked); else a decode batch.
                let mut pre: Option<u32> = None;
                for &r in &vengs[vi].active {
                    let q = &reqs[r as usize];
                    if q.phase == RPhase::Prefill && !q.paused {
                        pre = Some(r);
                        break;
                    }
                }
                if let Some(rid) = pre {
                    let (chunk, dur) = {
                        let q = &reqs[rid as usize];
                        let chunk = (q.prompt_len - q.prefilled).min(cfg.chunk_tokens);
                        (chunk, cm.prefill_s(chunk, g).max(cfg.heartbeat_s))
                    };
                    let done_t = t + dur;
                    if vengs[vi].is_shell() {
                        if done_t > vengs[vi].settle_at {
                            // The step would cross the settle point: park
                            // until the shell folds into its group (the
                            // remaining window is unreclaimed stall).
                            vengs[vi].free_at = vengs[vi].settle_at;
                            continue;
                        }
                        // Work executed inside the transition window is
                        // reclaimed stall.
                        switch_stall_s -= dur;
                        stall.backfill_recovered_s += dur;
                    }
                    vengs[vi].free_at = done_t;
                    let q = &mut reqs[rid as usize];
                    q.prefilled += chunk;
                    if q.prefilled >= q.prompt_len {
                        q.phase = RPhase::Decode;
                        q.emitted = 1; // first token produced by final chunk
                        vengs[vi].kv_used += 1;
                        rec.on_token_at(q.rec, done_t);
                        if let Some(rt) = ctrl.as_mut() {
                            rt.note_first_token(done_t, done_t - q.arrival);
                        }
                        if q.emitted >= q.output_len {
                            q.phase = RPhase::Done;
                            rec.on_finish_at(q.rec, done_t);
                        }
                    }
                    // Chunked prefill piggybacks decodes (Sarathi/vLLM,
                    // which the paper preserves): in-flight decode requests
                    // advance one token within the same round.
                    batch.clear();
                    for &r in &vengs[vi].active {
                        if r == rid {
                            continue;
                        }
                        let q = &reqs[r as usize];
                        if q.phase == RPhase::Decode && !q.paused {
                            if batch.len() == cfg.max_batch {
                                break;
                            }
                            batch.push(r);
                        }
                    }
                    for &r in batch.iter() {
                        let q = &mut reqs[r as usize];
                        q.emitted += 1;
                        rec.on_token_at(q.rec, done_t);
                        if q.emitted >= q.output_len {
                            q.phase = RPhase::Done;
                            rec.on_finish_at(q.rec, done_t);
                        }
                    }
                    vengs[vi].kv_used += batch.len();
                    journal.record(
                        t,
                        crate::obs::Event::Exec {
                            members: vengs[vi].unit_bits,
                            busy_s: dur,
                            batch: (batch.len() + 1) as u32,
                            prefill: true,
                        },
                    );
                } else {
                    // SP (Shift) executes token-parallel across all
                    // instances, so its effective batch is cluster-wide.
                    let batch_cap = if matches!(system, SimSystem::Shift) {
                        cfg.max_batch * vengs[vi].m
                    } else {
                        cfg.max_batch
                    };
                    batch.clear();
                    let mut ctx_sum = 0usize;
                    for &r in &vengs[vi].active {
                        let q = &reqs[r as usize];
                        if q.phase == RPhase::Decode && !q.paused {
                            if batch.len() == batch_cap {
                                break;
                            }
                            ctx_sum += kv_tokens(q);
                            batch.push(r);
                        }
                    }
                    if batch.is_empty() {
                        continue;
                    }
                    let mean_ctx = (ctx_sum / batch.len()).max(1);
                    let dur = match system {
                        // SP mode: token-parallel across instances — near-DP
                        // aggregate throughput at an efficiency discount.
                        SimSystem::Shift if batch.len() > 2 * n_inst => {
                            let per = batch.len().div_ceil(n_inst);
                            cm.decode_step_s(per, mean_ctx, gpus_per_inst) / 0.85
                        }
                        _ => cm.decode_step_s(batch.len(), mean_ctx, g),
                    }
                    .max(cfg.heartbeat_s);
                    let done_t = t + dur;
                    if vengs[vi].is_shell() {
                        if done_t > vengs[vi].settle_at {
                            // Step would cross the settle point: park until
                            // the shell folds into its forming group.
                            vengs[vi].free_at = vengs[vi].settle_at;
                            continue;
                        }
                        switch_stall_s -= dur;
                        stall.backfill_recovered_s += dur;
                    }
                    vengs[vi].free_at = done_t;
                    if let Some(rt) = ctrl.as_mut() {
                        // Each batched request advances one token this step:
                        // the step duration IS the inter-token latency sample.
                        rt.note_step(done_t, dur);
                    }
                    for &r in batch.iter() {
                        let q = &mut reqs[r as usize];
                        q.emitted += 1;
                        rec.on_token_at(q.rec, done_t);
                        if q.emitted >= q.output_len {
                            q.phase = RPhase::Done;
                            rec.on_finish_at(q.rec, done_t);
                        }
                    }
                    vengs[vi].kv_used += batch.len();
                    journal.record(
                        t,
                        crate::obs::Event::Exec {
                            members: vengs[vi].unit_bits,
                            busy_s: dur,
                            batch: batch.len() as u32,
                            prefill: false,
                        },
                    );
                }
                // Schedule the engine-free event for the step just issued.
                {
                    let v = &mut vengs[vi];
                    v.stamp += 1;
                    heap.push(Event {
                        t: v.free_at,
                        kind: EvKind::EngineFree { veng: v.handle, stamp: v.stamp },
                    });
                }
                // Retire finished requests, maintaining the KV accounting.
                {
                    let v = &mut vengs[vi];
                    let mut w = 0usize;
                    let mut freed = false;
                    for k in 0..v.active.len() {
                        let r = v.active[k];
                        let q = &reqs[r as usize];
                        if q.phase == RPhase::Done {
                            v.kv_used -= kv_tokens(q);
                            freed = true; // capacity freed
                        } else {
                            v.active[w] = r;
                            w += 1;
                        }
                    }
                    v.active.truncate(w);
                    if v.active.is_empty() {
                        // Idle vengs never gate the clock (the reference's
                        // work_t ignores them): cancel the pending event.
                        v.stamp += 1;
                        // Shells stay committed capacity (never idle) even
                        // when their backfill work drains early.
                        if !v.is_shell() {
                            kernel.index.set_idle(v.unit_bits, true);
                        }
                    }
                    if freed {
                        kernel.on_event(SchedEvent::StepComplete);
                    }
                }
                debug_assert_eq!(
                    vengs[vi].kv_used,
                    vengs[vi]
                        .active
                        .iter()
                        .map(|&r| kv_tokens(&reqs[r as usize]))
                        .sum::<usize>()
                );
            }

            // ---- split transient TP groups whose work drained -------------
            if vengs.iter().any(|v| v.transient) {
                split_buf.clear();
                let queue_nonempty = !kernel.rings.is_empty();
                let mut split_any = false;
                for v in vengs.drain(..) {
                    // Migrated residents are *carried* traffic, not TP work:
                    // they ride the group while it exists and are gathered
                    // back to unit engines at split time, so they must not
                    // hold the split open (with `switch_migrate` off the
                    // flag is never set and this is the PR-3 expression).
                    let tp_work_left = v.active.iter().any(|&r| {
                        let q = &reqs[r as usize];
                        !q.paused && !q.migrated && q.phase != RPhase::Done
                    });
                    let has_paused = v.active.iter().any(|&r| reqs[r as usize].paused);
                    // The kernel's split rule: only under pressure (queued
                    // DP work or hard-preempted requests waiting to
                    // resume).  An idle merged group is kept so low-load
                    // traffic stays in the TP regime (Use Case 1) —
                    // migrated residents keep decoding inside it, so they
                    // add no pressure either.
                    if v.transient
                        && lifecycle::split_due(tp_work_left, queue_nonempty, has_paused)
                    {
                        let mut bits_left = v.unit_bits;
                        debug_assert_eq!(
                            bits_left.count_ones() as usize,
                            v.m,
                            "split: group must own one instance bit per member"
                        );
                        for i in 0..v.m {
                            let bit = if bits_left != 0 {
                                let b = bits_left & bits_left.wrapping_neg();
                                bits_left &= bits_left - 1;
                                b
                            } else {
                                0
                            };
                            let mut unit = VEng {
                                m: 1,
                                free_at: v.free_at,
                                active: Vec::new(),
                                transient: false,
                                handle: next_handle,
                                stamp: 0,
                                kv_used: 0,
                                settle_at: f64::INFINITY,
                                merge_into: u32::MAX,
                                pledged_kv: 0,
                                unit_bits: bit,
                                bf_bound: f64::NEG_INFINITY,
                            };
                            next_handle += 1;
                            handle_pos.push(usize::MAX);
                            // Round-robin the resumed requests over units.
                            for (j, &r) in v.active.iter().enumerate() {
                                if j % v.m == i {
                                    let q = &mut reqs[r as usize];
                                    // Inverse gather (TP→DP): the unit
                                    // collects the request's shard slices
                                    // and it decodes on without recompute
                                    // or a frozen window.  Not re-counted
                                    // in `recompute_tokens_avoided` (the
                                    // metric is once per carried request,
                                    // matching the real coordinator's
                                    // once-per-promotion semantics) and,
                                    // like the live-switch latency, not
                                    // time-charged — splits are free in
                                    // both implementations by convention.
                                    q.migrated = false;
                                    q.paused = false;
                                    unit.kv_used += kv_tokens(q);
                                    unit.active.push(r);
                                }
                            }
                            if !unit.active.is_empty() && unit.free_at > t {
                                unit.stamp += 1;
                                heap.push(Event {
                                    t: unit.free_at,
                                    kind: EvKind::EngineFree {
                                        veng: unit.handle,
                                        stamp: unit.stamp,
                                    },
                                });
                            }
                            kernel.index.set_unit(bit, true);
                            kernel.index.set_idle(bit, unit.active.is_empty());
                            split_buf.push(unit);
                        }
                        n_switches += 1;
                        journal.record(
                            t,
                            crate::obs::Event::Split {
                                group: v.handle,
                                width: v.m as u32,
                                members: v.unit_bits,
                            },
                        );
                        split_any = true;
                    } else {
                        split_buf.push(v);
                    }
                }
                std::mem::swap(&mut vengs, &mut split_buf);
                if split_any {
                    for (idx, v) in vengs.iter().enumerate() {
                        handle_pos[v.handle as usize] = idx;
                    }
                    kernel.on_event(SchedEvent::Settle);
                }
            }

            // Another same-time round only if some veng still has work it
            // could run at `t` (mirrors the reference's same-time
            // re-iteration through its outer loop).
            if !vengs.iter().any(|v| !v.active.is_empty() && v.free_at <= t) {
                break;
            }
        }
    }

    SimOutcome {
        recorder: rec,
        rejected,
        n_switches,
        switch_stall_s,
        recompute_tokens_avoided: recompute_avoided,
        prefill_tokens_avoided: prefill_avoided,
        stall,
        journal: if cfg.trace { Some(journal) } else { None },
    }
}

/// Merge contiguous unit vengs into a transient TP group for `ri`, or join
/// an existing compatible group.  Returns the bind time (incl. live-switch
/// latency) or None if no group is currently formable.
#[allow(clippy::too_many_arguments)]
fn bind_tp_sim(
    system: SimSystem,
    vengs: &mut Vec<VEng>,
    handle_pos: &mut Vec<usize>,
    next_handle: &mut u32,
    reqs: &mut [SimReq],
    heap: &mut BinaryHeap<Event>,
    unit_scratch: &mut Vec<usize>,
    index: &mut EngineIndex,
    ri: u32,
    want_m: usize,
    t: f64,
    live_switch_s: f64,
    cap_by_m: &[usize],
    cfg: &SimConfig,
    n_switches: &mut usize,
    backfill: bool,
    switch_stall_s: &mut f64,
    cm: &CostModel,
    migrate: bool,
    recompute_avoided: &mut usize,
    stall: &mut crate::obs::StallBreakdown,
    journal: &mut crate::obs::Journal,
) -> Option<f64> {
    let riu = ri as usize;
    let total = reqs[riu].prompt_len + reqs[riu].output_len;

    // An existing group of the right width with KV + batch room?  (First
    // match only, as the reference's `find` — a non-joinable first match
    // falls through to the merge path.)  Shells never match: their instance
    // is committed to a forming group.
    let mut joined = false;
    for v in vengs.iter_mut() {
        if v.is_shell() {
            continue;
        }
        let batch_cap = if matches!(system, SimSystem::Shift) {
            cfg.max_batch * v.m
        } else {
            cfg.max_batch
        };
        if v.m == want_m
            && v.active.len() < batch_cap
            && cap_by_m[v.m].saturating_sub(v.kv_used) >= total
        {
            // Static TP / Shift: groups are permanent; Flying: join
            // transient groups (or a unit veng for degenerate TP-1).
            if matches!(system, SimSystem::StaticTp(_) | SimSystem::Shift)
                || v.transient
                || v.m == 1
            {
                let used = kv_tokens(&reqs[riu]);
                v.active.push(ri);
                v.kv_used += used;
                index.set_idle(v.unit_bits, false);
                if v.free_at > t {
                    v.stamp += 1;
                    heap.push(Event {
                        t: v.free_at,
                        kind: EvKind::EngineFree { veng: v.handle, stamp: v.stamp },
                    });
                }
                reqs[riu].phase = RPhase::Prefill;
                joined = true;
            }
            break;
        }
    }
    if joined {
        return Some(t);
    }
    if !matches!(system, SimSystem::Flying | SimSystem::FlyingSequential) {
        return None;
    }

    // Collect want_m unit vengs to merge (prefer idle ones; stable sort so
    // ties fall back to vector order, as the reference).  Shells are
    // already committed to another forming group and are never re-chosen.
    unit_scratch.clear();
    unit_scratch
        .extend((0..vengs.len()).filter(|&i| vengs[i].m == 1 && !vengs[i].is_shell()));
    if unit_scratch.len() < want_m {
        return None;
    }
    unit_scratch.sort_by_key(|&i| vengs[i].active.len());
    unit_scratch.truncate(want_m);

    let busy = unit_scratch.iter().any(|&i| !vengs[i].active.is_empty());
    if busy && system == SimSystem::FlyingSequential {
        // Sequential switching: wait for the stragglers (Fig 7a) — the
        // request stays queued and the chosen engines drain naturally.
        return None;
    }

    // The group settles when the slowest member's in-flight step completes
    // plus the live-switch latency.  Until then each chosen member is idle
    // from its own free point — that window is the switch stall (per
    // member, in instance-seconds); backfill reclaims it by crediting work
    // shells execute inside the window.
    let drain_done = unit_scratch
        .iter()
        .map(|&i| vengs[i].free_at)
        .fold(t, f64::max);
    let horizon = drain_done + live_switch_s;
    for &i in unit_scratch.iter() {
        *switch_stall_s += horizon - vengs[i].free_at.max(t);
        // Attribution mirror of the aggregate charge, term by term: the
        // member waits for the slowest straggler (drain-wait), then rides
        // the live switch (settle).  Same inputs, so the components
        // reconstruct the aggregate to FP rounding.
        stall.drain_wait_s += drain_done - vengs[i].free_at.max(t);
        stall.settle_s += live_switch_s;
    }
    let member_bits = unit_scratch
        .iter()
        .fold(0u64, |acc, &i| acc | vengs[i].unit_bits);
    journal.record(
        t,
        crate::obs::Event::DrainBegin {
            group: *next_handle,
            width: want_m as u32,
            members: member_bits,
            horizon_s: horizon,
        },
    );

    if backfill {
        // Drain-stall elimination: chosen members become backfill shells
        // that keep serving their residents (and bounded new elastic work)
        // until the settle point, then fold into the forming group member
        // by member (incremental settle).  The TP request's bind time is
        // unchanged — only the would-be idle capacity is reclaimed.
        let merged_handle = *next_handle;
        *next_handle += 1;
        handle_pos.push(usize::MAX);
        let mut merged = VEng {
            m: want_m,
            free_at: horizon,
            active: Vec::with_capacity(8),
            transient: true,
            handle: merged_handle,
            stamp: 0,
            kv_used: 0,
            settle_at: f64::INFINITY,
            merge_into: u32::MAX,
            pledged_kv: 0,
            // The forming group inherits the shells' instance bits at fold
            // time; until then the shells carry them (marked draining).
            unit_bits: 0,
            bf_bound: f64::NEG_INFINITY,
        };
        merged.active.push(ri);
        merged.kv_used += kv_tokens(&reqs[riu]);
        reqs[riu].phase = RPhase::Prefill;
        heap.push(Event {
            t: horizon,
            kind: EvKind::SwitchSettle { veng: merged_handle, stamp: 0 },
        });
        for &i in unit_scratch.iter() {
            let v = &mut vengs[i];
            v.settle_at = horizon;
            v.merge_into = merged_handle;
            v.bf_bound = f64::NEG_INFINITY;
            // Pre-pledge the residents' KV footprint into the forming group
            // so mid-window joins see the capacity the fold will consume
            // (reconciled against actual footprints at settle).
            v.pledged_kv = v.kv_used;
            merged.kv_used += v.kv_used;
            // Shell conversion: committed capacity — draining, never idle.
            index.set_draining(v.unit_bits, true);
            index.set_idle(v.unit_bits, false);
        }
        vengs.push(merged);
        for (idx, v) in vengs.iter().enumerate() {
            handle_pos[v.handle as usize] = idx;
        }
        *n_switches += 1;
        journal.record(
            horizon,
            crate::obs::Event::Promote {
                group: merged_handle,
                p_from: 1,
                p_to: want_m as u32,
                members: member_bits,
                latency_s: horizon - t,
            },
        );
        return Some(horizon);
    }

    // Hard preempt (Fig 7c): pause members' DP requests in place — unless
    // KV migration (`switch_migrate`) carries a decode-phase resident live
    // into the forming group: the shared cost-model rule decides per
    // request, the carried KV's `migrate_t` is charged to the merge
    // horizon, and the resident keeps decoding through the window instead
    // of freezing behind it.
    let mut merged = VEng {
        m: want_m,
        free_at: horizon,
        active: Vec::with_capacity(8),
        transient: true,
        handle: *next_handle,
        stamp: 0,
        kv_used: 0,
        settle_at: f64::INFINITY,
        merge_into: u32::MAX,
        pledged_kv: 0,
        unit_bits: 0,
        bf_bound: f64::NEG_INFINITY,
    };
    *next_handle += 1;
    handle_pos.push(usize::MAX);
    let g_new = want_m * cm.model.min_gpus;
    let mut migrate_cost = 0.0f64;
    // Asynchronous migration collectives (ISSUE 9): with overlap on, the
    // carried KV's transfer runs concurrently with the drain window —
    // `horizon - t` of wall clock the members spend waiting anyway — and
    // only the spill past the window delays the group.  Off, the window is
    // pinned to zero so every arithmetic below reduces to the serial charge
    // bit for bit.
    let mut window_left = if cfg.overlap { (horizon - t).max(0.0) } else { 0.0 };
    let mut overlapped = 0.0f64;
    for &i in unit_scratch.iter() {
        for &r in &vengs[i].active {
            let q = &mut reqs[r as usize];
            if lifecycle::carry_wins(cm, migrate, q.phase == RPhase::Decode, kv_tokens(q), g_new)
            {
                q.migrated = true;
                *recompute_avoided += kv_tokens(q);
                let cost = cm.migrate_t(kv_tokens(q), g_new);
                migrate_cost += cost;
                journal.record(
                    t,
                    crate::obs::Event::MigrateApply {
                        rid: q.id,
                        tokens: kv_tokens(q) as u64,
                        cost_s: cost,
                    },
                );
                if cfg.overlap {
                    let overlapped_r = cost.min(window_left);
                    window_left -= overlapped_r;
                    overlapped += overlapped_r;
                    journal.record(
                        t,
                        crate::obs::Event::AsyncMigrateBegin {
                            rid: q.id,
                            tokens: kv_tokens(q) as u64,
                            window_s: horizon - t,
                        },
                    );
                    journal.record(
                        t,
                        crate::obs::Event::AsyncMigrateEnd { rid: q.id, overlapped_s: overlapped_r },
                    );
                }
            } else {
                q.paused = true;
            }
            merged.active.push(r);
        }
        merged.kv_used += vengs[i].kv_used;
        // The consumed units' instance bits move into the merged group.
        merged.unit_bits |= vengs[i].unit_bits;
    }
    index.set_unit(merged.unit_bits, false);
    index.set_idle(merged.unit_bits, false);
    merged.free_at = horizon + (migrate_cost - overlapped);
    if migrate_cost > 0.0 {
        // The carried KV's transfer holds every member at the migration-
        // augmented horizon; charge that wait to the aggregate and
        // attribute it to the migration component (guarded so a zero cost
        // adds nothing, keeping migrate-off byte-identical).  With overlap
        // on, the window-hidden share is credited back — the full charge
        // still lands in `migration_s`, the credit in `pipeline_overlap_s`,
        // so the stall-attribution identity reconstructs the aggregate
        // exactly.
        *switch_stall_s += (migrate_cost - overlapped) * want_m as f64;
        stall.migration_s += migrate_cost * want_m as f64;
        stall.pipeline_overlap_s += overlapped * want_m as f64;
    }
    merged.active.push(ri);
    merged.kv_used += kv_tokens(&reqs[riu]);
    reqs[riu].phase = RPhase::Prefill;
    let bind_t = merged.free_at;
    heap.push(Event {
        t: merged.free_at,
        kind: EvKind::SwitchSettle { veng: merged.handle, stamp: merged.stamp },
    });
    // Remove chosen (descending to keep indices valid), insert merged at
    // the end — the reference's exact vector-order semantics.
    unit_scratch.sort_unstable_by(|a, b| b.cmp(a));
    for &i in unit_scratch.iter() {
        vengs.remove(i);
    }
    vengs.push(merged);
    for (idx, v) in vengs.iter().enumerate() {
        handle_pos[v.handle as usize] = idx;
    }
    *n_switches += 1;
    journal.record(
        bind_t,
        crate::obs::Event::Promote {
            group: vengs.last().map(|v| v.handle).unwrap_or(0),
            p_from: 1,
            p_to: want_m as u32,
            members: member_bits,
            latency_s: bind_t - t,
        },
    );
    Some(bind_t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::costmodel::{HwSpec, PaperModel};
    use crate::workload::{generate, WorkloadCfg};

    fn cm() -> CostModel {
        CostModel::new(HwSpec::default(), PaperModel::llama70b())
    }

    fn bursty(n: usize) -> Vec<Request> {
        generate(&WorkloadCfg::paper_full(7, n))
    }

    fn run(system: SimSystem, n: usize) -> SimOutcome {
        simulate(system, &cm(), &bursty(n), &SimConfig::default())
    }

    #[test]
    fn all_systems_complete_the_trace() {
        for sys in [
            SimSystem::StaticDp,
            SimSystem::StaticTp(4),
            SimSystem::Shift,
            SimSystem::Flying,
            SimSystem::FlyingSequential,
        ] {
            let o = run(sys, 300);
            let s = o.recorder.summary(None);
            assert_eq!(s.finished + o.rejected.len(), 300, "{}", sys.label());
            assert!(s.mean_ttft > 0.0, "{}", sys.label());
        }
    }

    #[test]
    fn paper_shape_dp_beats_tp_on_burst_ttft() {
        // Under bursty load, static TP queues badly; DP and FLYING drain.
        let dp = run(SimSystem::StaticDp, 600).recorder.summary(None);
        let tp = run(SimSystem::StaticTp(4), 600).recorder.summary(None);
        let fly = run(SimSystem::Flying, 600).recorder.summary(None);
        assert!(
            tp.p90_ttft > 1.5 * dp.p90_ttft,
            "tp {} vs dp {}",
            tp.p90_ttft,
            dp.p90_ttft
        );
        assert!(
            fly.p90_ttft < 0.75 * tp.p90_ttft,
            "fly {} vs tp {}",
            fly.p90_ttft,
            tp.p90_ttft
        );
    }

    #[test]
    fn paper_shape_throughput_flying_near_dp() {
        let dp = run(SimSystem::StaticDp, 600).recorder.summary(None);
        let tp = run(SimSystem::StaticTp(4), 600).recorder.summary(None);
        let fly = run(SimSystem::Flying, 600).recorder.summary(None);
        // Fig 9: FLYING retains ~95% of DP peak throughput and beats TP
        // by >1.5x.
        assert!(fly.peak_throughput > 0.8 * dp.peak_throughput);
        assert!(fly.peak_throughput > 1.3 * tp.peak_throughput);
    }

    #[test]
    fn flying_switches_happen() {
        let o = run(SimSystem::Flying, 300);
        assert!(o.n_switches > 0);
    }

    #[test]
    fn deterministic() {
        let a = run(SimSystem::Flying, 200).recorder.summary(None);
        let b = run(SimSystem::Flying, 200).recorder.summary(None);
        assert_eq!(a.mean_ttft, b.mean_ttft);
        assert_eq!(a.peak_throughput, b.peak_throughput);
    }

    #[test]
    fn shared_kernel_rejoin_event_heals_mask_state_sim_style() {
        // The sim has no fault injector (single-process — nothing to kill),
        // but it drives the SAME kernel as the real coordinator, so the
        // rejoin lifecycle (fail → quarantine → `SchedEvent::EngineRejoin`
        // → mask refresh) must compose with sim-style mask-granular index
        // maintenance.  This pins that contract: a future sim fault model
        // plugs in by emitting the same event stream, and the two paths
        // cannot fork on what "an engine came back" means.
        let mut kernel: Kernel<usize> = Kernel::new();
        kernel.index.set_unit(0b1111, true);
        kernel.index.set_idle(0b1111, true);
        assert_eq!(kernel.index.idle_count(), 4);
        // Instance 2 fail-stops; capacity shrinks immediately.
        kernel.index.mark_failed(2);
        assert_eq!(kernel.index.idle_count(), 3);
        assert_eq!(kernel.index.dp_candidates(), 0b1011);
        // Revive: quarantine first (still excluded), then the rejoin event
        // dirties the walk gate and the mask refresh readmits the bits.
        kernel.index.clear_failed(2);
        assert_eq!(kernel.index.idle_count(), 3);
        kernel.index.clear_quarantine(2);
        kernel.on_event(SchedEvent::EngineRejoin { engine: 2 });
        assert!(kernel.walk_pending(), "rejoin must schedule a re-walk");
        kernel.index.set_unit(0b0100, true);
        kernel.index.set_idle(0b0100, true);
        assert_eq!(kernel.index.idle_count(), 4);
        assert_eq!(kernel.index.dp_candidates(), 0b1111);
    }

    #[test]
    fn stall_rejects_instead_of_spinning() {
        // max_batch = 0 blocks every DP admission forever: the seed loop
        // would advance the heartbeat clock indefinitely; the event core
        // must detect the stall and reject deterministically.
        let trace = bursty(5);
        let cfg = SimConfig { max_batch: 0, ..SimConfig::default() };
        let o = simulate(SimSystem::StaticDp, &cm(), &trace, &cfg);
        assert_eq!(o.rejected.len(), 5);
        assert_eq!(o.recorder.summary(None).finished, 5); // finish = reject record
    }

    #[test]
    fn oversized_shift_request_stalls_out_cleanly() {
        // Shift always decides Tp(n_inst); a request larger than the whole
        // cluster's KV can never bind — previously an infinite heartbeat
        // spin, now a deterministic rejection.
        let c = cm();
        let cluster_cap = c.kv_capacity_tokens(c.hw.n_gpus);
        let trace = vec![Request {
            id: 1,
            arrival: 0.0,
            prompt_len: cluster_cap + 1,
            output_len: 8,
            priority: crate::workload::Priority::Normal,
            tp_demand: None,
            prefix_family: None,
        }];
        let o = simulate(SimSystem::Shift, &c, &trace, &SimConfig::default());
        assert_eq!(o.rejected, vec![1]);
    }

    #[test]
    #[should_panic(expected = "non-finite arrival")]
    fn nan_arrival_is_rejected_up_front() {
        let trace = vec![Request {
            id: 1,
            arrival: f64::NAN,
            prompt_len: 10,
            output_len: 2,
            priority: crate::workload::Priority::Normal,
            tp_demand: None,
            prefix_family: None,
        }];
        simulate(SimSystem::StaticDp, &cm(), &trace, &SimConfig::default());
    }

    #[test]
    fn empty_trace_is_empty_outcome() {
        let o = simulate(SimSystem::Flying, &cm(), &[], &SimConfig::default());
        assert!(o.recorder.is_empty());
        assert!(o.rejected.is_empty());
        assert_eq!(o.n_switches, 0);
    }

    #[test]
    fn adaptive_hold_is_byte_identical_to_flying() {
        use crate::control::{ControlConfig, ControlRuntime, StaticController};
        // StaticController::hold() never leaves Plan::Hold, so every
        // decision falls through to the same FlyingPolicy the plain path
        // runs — outcomes must be exactly equivalent.
        let trace = bursty(400);
        let mut rt =
            ControlRuntime::new(Box::new(StaticController::hold()), ControlConfig::default());
        let a = simulate_adaptive(&cm(), &trace, &SimConfig::default(), &mut rt);
        let b = simulate(SimSystem::Flying, &cm(), &trace, &SimConfig::default());
        outcomes_equivalent(&a, &b).unwrap();
        assert!(rt.ticks() > 0);
        assert_eq!(rt.plan_changes(), 0);
    }

    #[test]
    fn adaptive_costmodel_completes_and_respects_cooldown() {
        use crate::control::{ControlConfig, ControlRuntime, CostModelController};
        let trace = bursty(400);
        let c = cm();
        let cfg = ControlConfig {
            cooldown_s: 10.0,
            long_threshold: c.kv_capacity_tokens(c.model.min_gpus),
            ..ControlConfig::default()
        };
        let mut rt = ControlRuntime::new(Box::new(CostModelController::new(c.clone())), cfg);
        let o = simulate_adaptive(&c, &trace, &SimConfig::default(), &mut rt);
        let s = o.recorder.summary(None);
        // Every request reaches a terminal record (rejects get a finish
        // timestamp too) — nothing may be lost under plan steering.
        assert_eq!(s.finished, 400);
        // Plan changes are hard-bounded by makespan / cooldown + 1.
        let makespan = o
            .recorder
            .records()
            .filter_map(|(_, r)| r.finished)
            .fold(0.0f64, f64::max);
        let bound = (makespan / 10.0).ceil() as usize + 1;
        assert!(
            rt.plan_changes() <= bound,
            "plan_changes={} bound={bound}",
            rt.plan_changes()
        );
    }

    #[test]
    fn adaptive_threshold_is_deterministic() {
        use crate::control::{ControlConfig, ControlRuntime, ThresholdController};
        let trace = bursty(250);
        let run = || {
            let mut rt = ControlRuntime::new(
                Box::new(ThresholdController::default()),
                ControlConfig::default(),
            );
            let o = simulate_adaptive(&cm(), &trace, &SimConfig::default(), &mut rt);
            let s = o.recorder.summary(None);
            (s.finished, o.rejected.len(), o.n_switches, s.mean_ttft)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn switch_stall_is_tracked_and_zero_without_merges() {
        // Static systems never merge at runtime: no transition windows.
        let o = run(SimSystem::StaticDp, 200);
        assert_eq!(o.switch_stall_s, 0.0);
        // Flying merges pay at least the live-switch latency per member.
        let o = run(SimSystem::Flying, 300);
        assert!(o.n_switches > 0);
        assert!(o.switch_stall_s > 0.0);
    }

    #[test]
    fn backfill_mode_terminates_with_terminal_records_and_nonnegative_stall() {
        use crate::workload::Scenario;
        let c = cm();
        for scenario in [Scenario::PriorityStorm, Scenario::PoissonBurst] {
            let trace = scenario.generate(7, 220);
            let on_cfg = SimConfig { switch_backfill: true, ..SimConfig::default() };
            let on = simulate(SimSystem::Flying, &c, &trace, &on_cfg);
            // Every request reaches a terminal record (finish or reject —
            // both stamp a finish time); shells must never strand work.
            assert_eq!(on.recorder.summary(None).finished, 220, "{scenario}");
            // Credits are bounded by each shell's window: reclaimed work
            // can never exceed the stall potential.
            assert!(
                on.switch_stall_s >= -1e-9,
                "{scenario}: negative stall {}",
                on.switch_stall_s
            );
        }
    }

    #[test]
    fn backfill_mode_is_deterministic() {
        use crate::workload::Scenario;
        let c = cm();
        let trace = Scenario::PriorityStorm.generate(11, 200);
        let cfg = SimConfig { switch_backfill: true, ..SimConfig::default() };
        let go = || {
            let o = simulate(SimSystem::Flying, &c, &trace, &cfg);
            let s = o.recorder.summary(None);
            (s.finished, o.rejected.len(), o.n_switches, o.switch_stall_s, s.mean_ttft)
        };
        assert_eq!(go(), go());
    }

    #[test]
    fn migrate_mode_terminates_and_counts_carried_tokens() {
        use crate::workload::Scenario;
        let c = cm();
        for scenario in [Scenario::LongContextWave, Scenario::SwitchChurn] {
            let trace = scenario.generate(7, 260);
            let on_cfg = SimConfig { switch_migrate: true, ..SimConfig::default() };
            let on = simulate(SimSystem::Flying, &c, &trace, &on_cfg);
            // Every request reaches a terminal record: carried residents
            // must never strand inside a group or a split.
            assert_eq!(on.recorder.summary(None).finished, 260, "{scenario}");
            // Merges on these scenarios hit busy decode residents, so live
            // KV crosses the layout boundary instead of recomputing.
            assert!(
                on.recompute_tokens_avoided > 0,
                "{scenario}: no KV carried across merges"
            );
            let off = simulate(SimSystem::Flying, &c, &trace, &SimConfig::default());
            assert_eq!(off.recompute_tokens_avoided, 0, "{scenario}");
        }
    }

    #[test]
    fn migrate_mode_is_deterministic() {
        use crate::workload::Scenario;
        let c = cm();
        let trace = Scenario::SwitchChurn.generate(11, 200);
        let cfg = SimConfig { switch_migrate: true, ..SimConfig::default() };
        let go = || {
            let o = simulate(SimSystem::Flying, &c, &trace, &cfg);
            let s = o.recorder.summary(None);
            (
                s.finished,
                o.rejected.len(),
                o.n_switches,
                o.recompute_tokens_avoided,
                s.mean_ttft,
            )
        };
        assert_eq!(go(), go());
    }

    #[test]
    fn migrate_composes_with_backfill() {
        use crate::workload::Scenario;
        let c = cm();
        let trace = Scenario::SwitchChurn.generate(5, 220);
        let cfg = SimConfig {
            switch_migrate: true,
            switch_backfill: true,
            ..SimConfig::default()
        };
        let o = simulate(SimSystem::Flying, &c, &trace, &cfg);
        assert_eq!(o.recorder.summary(None).finished, 220);
        assert!(o.switch_stall_s >= -1e-9, "negative stall {}", o.switch_stall_s);
    }

    #[test]
    fn batched_shell_admits_concurrent_backfills() {
        // ISSUE 5 satellite: a backfill shell whose residents have drained
        // may hold several concurrent backfills, each admitted behind the
        // shell's running work bound.  Construct the situation exactly:
        //
        //   * 2 serving instances (4 GPUs, min_gpus 2);
        //   * e0 carries a resident mid-way through a long prefill chunk
        //     (~0.26 s), so the merge window is wide; e1 drains early;
        //   * an explicit TP-2 demand merges both into backfill shells;
        //   * two micro requests (output 2, so the first stays resident on
        //     the shell after its prefill step) arrive 1 ms apart inside
        //     the window — the only admissible engine is shell e1 (e0's
        //     residents are not backfill work), so the second admission is
        //     concurrent with the first if and only if the batched-shell
        //     bound admits it alongside a live backfill.
        let c = CostModel::new(HwSpec { n_gpus: 4, ..HwSpec::default() }, PaperModel::llama70b());
        let mk = |id: u64, arrival: f64, prompt: usize, output: usize, demand: Option<usize>| {
            Request {
                id,
                arrival,
                prompt_len: prompt,
                output_len: output,
                priority: Priority::Normal,
                tp_demand: demand,
                prefix_family: None,
            }
        };
        let trace = vec![
            mk(1, 0.0, 6000, 300, None), // long resident (lands on e0)
            // Filler burst so request 1 is decided under backlog (stays DP
            // instead of opportunistically widening); e1's fillers finish
            // within a step or two.
            mk(2, 0.0, 16, 1, None),
            mk(3, 0.0, 16, 1, None),
            mk(4, 0.0, 16, 1, None),
            mk(5, 0.1, 64, 5, Some(2)), // explicit TP-2: merges both units
            mk(6, 0.101, 16, 2, None),  // micro backfill #1
            mk(7, 0.102, 16, 2, None),  // micro backfill #2
        ];
        let cfg = SimConfig { switch_backfill: true, ..SimConfig::default() };
        let o = simulate(SimSystem::Flying, &c, &trace, &cfg);
        assert!(o.rejected.is_empty(), "rejected {:?}", o.rejected);
        assert_eq!(o.recorder.summary(None).finished, 7);
        assert!(o.n_switches >= 2, "merge+split expected, got {}", o.n_switches);
        let first_sched = |id: u64| o.recorder.get(id).unwrap().first_sched.unwrap();
        let finished = |id: u64| o.recorder.get(id).unwrap().finished.unwrap();
        // Both micros were admitted essentially at arrival — inside the
        // transition window, not after the group resolved.
        assert!(first_sched(6) < 0.11, "micro 6 waited: {}", first_sched(6));
        assert!(first_sched(7) < 0.11, "micro 7 waited: {}", first_sched(7));
        // The concurrency witness: micro 7 was admitted to the shell while
        // micro 6 was still running on it (single-backfill shells would
        // defer it until 6 retired).
        assert!(
            first_sched(7) < finished(6) - 1e-9,
            "no concurrent backfill: sched(7)={} fin(6)={}",
            first_sched(7),
            finished(6)
        );
        // The long resident outlives the whole transition and still finishes.
        assert!(finished(1) > finished(7));
    }

    #[test]
    fn priority_rings_preserve_arrival_order_within_level() {
        // High-priority requests must be scheduled before Normal ones that
        // arrived earlier, once both are queued behind a saturated cluster.
        let mut wl = WorkloadCfg::paper_full(21, 400);
        wl.priority_frac = 0.3;
        let trace = generate(&wl);
        let o = simulate(SimSystem::Flying, &cm(), &trace, &SimConfig::default());
        let all = o.recorder.summary(None);
        let hi = o.recorder.summary(Some(Priority::High));
        assert_eq!(all.finished + o.rejected.len(), 400);
        assert!(hi.n > 0);
    }
}
