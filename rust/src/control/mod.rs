//! Adaptive reconfiguration control plane.
//!
//! FLYING SERVING's mechanism (live DP↔TP switching) needs a *decision
//! loop* to exploit it under non-stationary traffic: something must watch
//! the load, forecast where it is going, and plan fleet-wide merges/splits
//! without thrashing.  This module is that loop (cf. Shift Parallelism's
//! rate/mix estimation, arXiv:2509.16495):
//!
//! * [`telemetry`] — fixed-capacity ring-buffer sliding window over the
//!   serving event stream (arrival rate, length mix, TTFT/TPOT
//!   percentiles); zero steady-state allocation.
//! * [`forecast`] — time-aware fast/slow EWMAs + burst detector.
//! * [`planner`] — the [`Controller`] trait (`StaticController`,
//!   `ThresholdController`, `CostModelController`), the per-run
//!   [`ControlRuntime`] with tick/cooldown bookkeeping, and
//!   [`AdaptivePolicy`], the `Policy` adaptor for the real coordinator.
//!
//! Both execution paths consume plans through the same code:
//! `sim::simulate_adaptive` threads a `ControlRuntime` through the event
//! core's assignment walk, and the real coordinator runs the identical
//! runtime behind `AdaptivePolicy` — mirroring how `Policy` itself is
//! shared today, so simulated and real decisions are byte-identical given
//! the same event stream.

pub mod forecast;
pub mod planner;
pub mod telemetry;

pub use forecast::{Ewma, Forecaster};
pub use planner::{
    plan_decision, AdaptivePolicy, ControlConfig, ControlRuntime, Controller,
    CostModelController, CtrlSnapshot, Plan, StaticController, ThresholdController, TickInfo,
};
pub use telemetry::{Telemetry, WindowStats};
