//! Sliding-window serving telemetry for the reconfiguration control plane.
//!
//! A [`Telemetry`] instance ingests the same event stream the
//! `metrics::Recorder` sees — arrivals (with their length mix), first-token
//! emissions (TTFT), and decode-step completions (per-token latency) — and
//! answers windowed aggregate queries: arrival rate, prompt/output-length
//! means, long-context and high-priority fractions, TTFT p90, TPOT p50.
//!
//! # Hot-path discipline (ROADMAP invariants)
//!
//! Everything is built on fixed-capacity ring buffers allocated once at
//! construction; `note_*` ingestion is an index write (zero allocation,
//! O(1)), and windowed queries reuse a pre-allocated percentile scratch
//! buffer (`sort_unstable`, in-place).  Queries run at control ticks
//! (~1 Hz), never per event, so even the O(capacity) window walks are off
//! the per-step path.

/// Fixed-capacity ring of timestamped samples.  When full, new pushes
/// overwrite the oldest entry — for sliding-window telemetry that is exactly
/// the right loss mode (the overwritten sample is the one most likely to
/// have aged out of the window anyway).
#[derive(Clone, Debug)]
pub struct Ring<T: Copy> {
    buf: Vec<(f64, T)>,
    cap: usize,
    head: usize, // next write position
    len: usize,
}

impl<T: Copy + Default> Ring<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "Ring capacity must be positive");
        Ring {
            buf: vec![(0.0, T::default()); cap],
            cap,
            head: 0,
            len: 0,
        }
    }

    #[inline]
    pub fn push(&mut self, t: f64, v: T) {
        self.buf[self.head] = (t, v);
        self.head = (self.head + 1) % self.cap;
        self.len = (self.len + 1).min(self.cap);
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Iterate the stored samples with timestamp >= `t0`, oldest first.
    pub fn iter_since(&self, t0: f64) -> impl Iterator<Item = (f64, T)> + '_ {
        let start = (self.head + self.cap - self.len) % self.cap;
        (0..self.len)
            .map(move |i| self.buf[(start + i) % self.cap])
            .filter(move |&(t, _)| t >= t0)
    }
}

/// One arrival's load contribution.
#[derive(Clone, Copy, Debug, Default)]
pub struct ArrivalEvt {
    pub prompt_len: u32,
    pub output_len: u32,
    pub high_priority: bool,
}

/// Windowed aggregate view computed at a control tick.
#[derive(Clone, Copy, Debug)]
pub struct WindowStats {
    /// Requests/s over the window.
    pub arrival_rate: f64,
    pub mean_prompt: f64,
    pub mean_output: f64,
    /// Fraction of window arrivals whose prompt+output exceeds the
    /// configured long-context threshold (single-engine KV capacity).
    pub long_frac: f64,
    /// Fraction of window arrivals carrying high priority.
    pub high_frac: f64,
    /// NaN when no samples landed in the window.
    pub ttft_p90: f64,
    /// NaN when no samples landed in the window.
    pub tpot_p50: f64,
    pub n_arrivals: usize,
}

pub struct Telemetry {
    /// Sliding-window length in seconds.
    pub window_s: f64,
    /// prompt+output above this counts as long-context (DP KV capacity).
    pub long_threshold: usize,
    arrivals: Ring<ArrivalEvt>,
    ttft: Ring<f64>,
    tpot: Ring<f64>,
    /// Percentile scratch, reused across queries (no steady-state alloc).
    scratch: Vec<f64>,
}

impl Telemetry {
    pub fn new(window_s: f64, ring_cap: usize, long_threshold: usize) -> Self {
        assert!(window_s > 0.0);
        Telemetry {
            window_s,
            long_threshold,
            arrivals: Ring::new(ring_cap),
            ttft: Ring::new(ring_cap),
            tpot: Ring::new(ring_cap),
            scratch: Vec::with_capacity(ring_cap),
        }
    }

    // ---- ingestion (O(1), allocation-free) -------------------------------

    #[inline]
    pub fn note_arrival(&mut self, t: f64, prompt_len: usize, output_len: usize, high: bool) {
        self.arrivals.push(
            t,
            ArrivalEvt {
                prompt_len: prompt_len.min(u32::MAX as usize) as u32,
                output_len: output_len.min(u32::MAX as usize) as u32,
                high_priority: high,
            },
        );
    }

    #[inline]
    pub fn note_first_token(&mut self, t: f64, ttft_s: f64) {
        self.ttft.push(t, ttft_s);
    }

    /// One decode step completed; `per_token_s` is its inter-token latency
    /// contribution (the step duration — each batched request advanced one
    /// token).
    #[inline]
    pub fn note_step(&mut self, t: f64, per_token_s: f64) {
        self.tpot.push(t, per_token_s);
    }

    // ---- windowed queries (control-tick rate) ----------------------------

    pub fn window_stats(&mut self, now: f64) -> WindowStats {
        let t0 = now - self.window_s;
        // Effective window: clock start clips the early window so rates are
        // not under-estimated during the first `window_s` seconds.  Floored
        // at 1 s: with the first tick firing at the first arrival (t1 often
        // milliseconds), an unfloored span would report rate = 1/t1 — a
        // huge spike that primes both forecaster EWMAs absurdly high and
        // mutes the burst detector for minutes.
        let span = self.window_s.min(now).max(1.0);

        let mut n = 0usize;
        let mut prompt_sum = 0.0f64;
        let mut output_sum = 0.0f64;
        let mut long = 0usize;
        let mut high = 0usize;
        for (_, a) in self.arrivals.iter_since(t0) {
            n += 1;
            prompt_sum += a.prompt_len as f64;
            output_sum += a.output_len as f64;
            if (a.prompt_len as usize + a.output_len as usize) > self.long_threshold {
                long += 1;
            }
            if a.high_priority {
                high += 1;
            }
        }
        let nf = n as f64;

        let ttft_p90 = Self::percentile(&mut self.scratch, self.ttft.iter_since(t0), 0.90);
        let tpot_p50 = Self::percentile(&mut self.scratch, self.tpot.iter_since(t0), 0.50);

        WindowStats {
            arrival_rate: nf / span,
            mean_prompt: if n == 0 { 0.0 } else { prompt_sum / nf },
            mean_output: if n == 0 { 0.0 } else { output_sum / nf },
            long_frac: if n == 0 { 0.0 } else { long as f64 / nf },
            high_frac: if n == 0 { 0.0 } else { high as f64 / nf },
            ttft_p90,
            tpot_p50,
            n_arrivals: n,
        }
    }

    fn percentile(
        scratch: &mut Vec<f64>,
        samples: impl Iterator<Item = (f64, f64)>,
        q: f64,
    ) -> f64 {
        scratch.clear();
        scratch.extend(samples.map(|(_, v)| v));
        if scratch.is_empty() {
            return f64::NAN;
        }
        scratch.sort_unstable_by(|a, b| a.total_cmp(b));
        let pos = q * (scratch.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        scratch[lo] * (1.0 - frac) + scratch[hi] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overwrites_oldest_when_full() {
        let mut r: Ring<u32> = Ring::new(3);
        for i in 0..5u32 {
            r.push(i as f64, i);
        }
        assert_eq!(r.len(), 3);
        let vals: Vec<u32> = r.iter_since(f64::NEG_INFINITY).map(|(_, v)| v).collect();
        assert_eq!(vals, vec![2, 3, 4]);
    }

    #[test]
    fn ring_iter_since_filters_by_time() {
        let mut r: Ring<u32> = Ring::new(8);
        for i in 0..6u32 {
            r.push(i as f64, i);
        }
        let vals: Vec<u32> = r.iter_since(3.0).map(|(_, v)| v).collect();
        assert_eq!(vals, vec![3, 4, 5]);
    }

    #[test]
    fn arrival_rate_over_window() {
        let mut tm = Telemetry::new(10.0, 128, 1000);
        // 20 arrivals over [0, 10): 2 req/s.
        for i in 0..20 {
            tm.note_arrival(i as f64 * 0.5, 100, 50, false);
        }
        let s = tm.window_stats(10.0);
        assert_eq!(s.n_arrivals, 20);
        assert!((s.arrival_rate - 2.0).abs() < 1e-9, "rate={}", s.arrival_rate);
        assert!((s.mean_prompt - 100.0).abs() < 1e-9);
        assert!((s.mean_output - 50.0).abs() < 1e-9);
    }

    #[test]
    fn old_samples_age_out_of_window() {
        let mut tm = Telemetry::new(5.0, 128, 1000);
        tm.note_arrival(0.0, 100, 10, false);
        tm.note_arrival(1.0, 100, 10, false);
        tm.note_arrival(9.0, 100, 10, false);
        let s = tm.window_stats(10.0);
        assert_eq!(s.n_arrivals, 1); // only t=9 within [5, 10]
    }

    #[test]
    fn early_window_clip_keeps_rate_honest() {
        let mut tm = Telemetry::new(30.0, 128, 1000);
        // 4 arrivals in the first 2 s: the rate divisor must be ~2 s, not 30.
        for i in 0..4 {
            tm.note_arrival(i as f64 * 0.5, 10, 10, false);
        }
        let s = tm.window_stats(2.0);
        assert!((s.arrival_rate - 2.0).abs() < 1e-9, "rate={}", s.arrival_rate);
    }

    #[test]
    fn long_and_high_fractions() {
        let mut tm = Telemetry::new(10.0, 128, 500);
        tm.note_arrival(1.0, 400, 200, false); // long (600 > 500)
        tm.note_arrival(2.0, 100, 50, true); // high
        tm.note_arrival(3.0, 100, 50, false);
        tm.note_arrival(4.0, 100, 50, false);
        let s = tm.window_stats(5.0);
        assert!((s.long_frac - 0.25).abs() < 1e-9);
        assert!((s.high_frac - 0.25).abs() < 1e-9);
    }

    #[test]
    fn ttft_and_tpot_percentiles() {
        let mut tm = Telemetry::new(100.0, 256, 1000);
        for i in 1..=100 {
            tm.note_first_token(i as f64 * 0.1, i as f64 * 0.01);
            tm.note_step(i as f64 * 0.1, i as f64 * 0.001);
        }
        let s = tm.window_stats(10.0);
        assert!((s.ttft_p90 - 0.901).abs() < 1e-9, "p90={}", s.ttft_p90);
        assert!((s.tpot_p50 - 0.0505).abs() < 1e-9, "p50={}", s.tpot_p50);
    }

    #[test]
    fn empty_window_is_nan_percentiles_zero_rates() {
        let mut tm = Telemetry::new(10.0, 16, 1000);
        let s = tm.window_stats(50.0);
        assert_eq!(s.n_arrivals, 0);
        assert_eq!(s.arrival_rate, 0.0);
        assert!(s.ttft_p90.is_nan());
        assert!(s.tpot_p50.is_nan());
    }

    #[test]
    fn ingestion_does_not_allocate_once_built() {
        // Structural proxy for the counting-allocator bench: the ring's
        // backing store pointer must not move across a full wrap.
        let mut tm = Telemetry::new(10.0, 64, 1000);
        let p0 = tm.arrivals.buf.as_ptr();
        for i in 0..1000 {
            tm.note_arrival(i as f64, 10, 10, false);
        }
        assert_eq!(p0, tm.arrivals.buf.as_ptr());
        assert_eq!(tm.arrivals.len(), 64);
    }
}
