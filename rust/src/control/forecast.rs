//! Load forecasting for the control plane: time-aware EWMAs plus a
//! fast/slow-ratio burst detector.
//!
//! The planner needs two things from the arrival-rate signal: a smoothed
//! estimate robust to Poisson noise (the slow EWMA), and an early-warning
//! burst flag that reacts within a few seconds of a rate jump (the fast
//! EWMA racing ahead of the slow one).  Both are O(1) state — no history
//! buffers, no allocation — and deterministic: the same (t, rate) stream
//! always produces the same forecast, which is what keeps simulated and
//! real control decisions byte-identical.

/// Irregularly-sampled exponential moving average: decay is computed from
/// the elapsed time, so tick-rate jitter does not change the smoothing
/// horizon.
#[derive(Clone, Copy, Debug)]
pub struct Ewma {
    /// Time constant (seconds): samples older than ~3·tau are forgotten.
    pub tau_s: f64,
    value: f64,
    last_t: f64,
    primed: bool,
}

impl Ewma {
    pub fn new(tau_s: f64) -> Self {
        assert!(tau_s > 0.0);
        Ewma {
            tau_s,
            value: 0.0,
            last_t: 0.0,
            primed: false,
        }
    }

    pub fn observe(&mut self, t: f64, x: f64) {
        if !self.primed {
            self.value = x;
            self.last_t = t;
            self.primed = true;
            return;
        }
        let dt = (t - self.last_t).max(0.0);
        let alpha = 1.0 - (-dt / self.tau_s).exp();
        self.value += alpha * (x - self.value);
        self.last_t = t;
    }

    pub fn value(&self) -> f64 {
        self.value
    }

    pub fn primed(&self) -> bool {
        self.primed
    }
}

/// EWMA pair + burst detector over the arrival-rate signal.
#[derive(Clone, Copy, Debug)]
pub struct Forecaster {
    fast: Ewma,
    slow: Ewma,
    /// fast/slow ratio above which the load counts as bursting.
    pub burst_ratio: f64,
    /// Rates below this never count as a burst (idle-noise floor, req/s).
    pub min_burst_rate: f64,
}

impl Default for Forecaster {
    fn default() -> Self {
        Forecaster {
            fast: Ewma::new(4.0),
            slow: Ewma::new(45.0),
            burst_ratio: 1.6,
            min_burst_rate: 1.0,
        }
    }
}

impl Forecaster {
    pub fn new(tau_fast_s: f64, tau_slow_s: f64, burst_ratio: f64) -> Self {
        assert!(tau_fast_s < tau_slow_s, "fast EWMA must be faster than slow");
        Forecaster {
            fast: Ewma::new(tau_fast_s),
            slow: Ewma::new(tau_slow_s),
            burst_ratio,
            min_burst_rate: 1.0,
        }
    }

    pub fn observe_rate(&mut self, t: f64, rate: f64) {
        self.fast.observe(t, rate);
        self.slow.observe(t, rate);
    }

    pub fn rate_fast(&self) -> f64 {
        self.fast.value()
    }

    pub fn rate_slow(&self) -> f64 {
        self.slow.value()
    }

    /// Near-term rate forecast: the fast estimate, floored by the slow one
    /// while a burst decays so the planner does not flap back early.
    pub fn forecast_rate(&self) -> f64 {
        self.fast.value().max(0.0)
    }

    /// Burst = the fast estimate running well ahead of the slow baseline.
    pub fn bursting(&self) -> bool {
        self.fast.primed()
            && self.fast.value() > self.min_burst_rate
            && self.fast.value() > self.burst_ratio * self.slow.value()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_first_sample_primes() {
        let mut e = Ewma::new(10.0);
        assert!(!e.primed());
        e.observe(5.0, 3.0);
        assert!(e.primed());
        assert_eq!(e.value(), 3.0);
    }

    #[test]
    fn ewma_converges_to_constant_signal() {
        let mut e = Ewma::new(2.0);
        for i in 0..100 {
            e.observe(i as f64, 7.0);
        }
        assert!((e.value() - 7.0).abs() < 1e-9);
    }

    #[test]
    fn ewma_decay_depends_on_elapsed_time_not_tick_count() {
        // Same signal sampled at 1 Hz and 10 Hz must land near the same
        // value after the same wall time.
        let mut coarse = Ewma::new(5.0);
        let mut fine = Ewma::new(5.0);
        coarse.observe(0.0, 0.0);
        fine.observe(0.0, 0.0);
        for i in 1..=20 {
            coarse.observe(i as f64, 10.0);
        }
        for i in 1..=200 {
            fine.observe(i as f64 * 0.1, 10.0);
        }
        assert!(
            (coarse.value() - fine.value()).abs() < 0.2,
            "coarse={} fine={}",
            coarse.value(),
            fine.value()
        );
    }

    #[test]
    fn burst_fires_on_rate_jump_and_clears_after() {
        let mut f = Forecaster::default();
        // Long steady 2 req/s baseline.
        for i in 0..120 {
            f.observe_rate(i as f64, 2.0);
        }
        assert!(!f.bursting());
        // Jump to 20 req/s: the fast EWMA reacts within a few seconds.
        for i in 0..8 {
            f.observe_rate(120.0 + i as f64, 20.0);
        }
        assert!(f.bursting(), "fast={} slow={}", f.rate_fast(), f.rate_slow());
        // Back to baseline long enough for both EWMAs to settle.
        for i in 0..300 {
            f.observe_rate(128.0 + i as f64, 2.0);
        }
        assert!(!f.bursting(), "fast={} slow={}", f.rate_fast(), f.rate_slow());
    }

    #[test]
    fn idle_noise_never_bursts() {
        let mut f = Forecaster::default();
        for i in 0..60 {
            // 0.1 -> 0.5 req/s wiggle: below the burst-rate floor.
            f.observe_rate(i as f64, if i % 2 == 0 { 0.1 } else { 0.5 });
        }
        assert!(!f.bursting());
    }

    #[test]
    fn forecaster_is_deterministic() {
        let run = || {
            let mut f = Forecaster::default();
            for i in 0..50 {
                f.observe_rate(i as f64 * 0.7, (i % 7) as f64);
            }
            (f.rate_fast(), f.rate_slow(), f.bursting())
        };
        assert_eq!(run(), run());
    }
}
