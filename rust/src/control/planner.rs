//! Reconfiguration planning: the `Controller` trait, its three
//! implementations, and the `ControlRuntime` that both the discrete-event
//! simulator and the real coordinator drive.
//!
//! Architecture (mirrors how `Policy` is shared between the two paths):
//!
//! ```text
//!   events ──> Telemetry ──window──> Forecaster ──> CtrlSnapshot
//!                                                        │
//!                                          Controller::plan (every tick)
//!                                                        │ cooldown
//!                                                  Plan (Hold/Out/Up)
//!                                                        │
//!   per-request decide() ──────────> plan_decision ──> ModeDecision
//! ```
//!
//! The fleet-level `Plan` only steers the *elastic* traffic (paper Use
//! Case 1).  Correctness-constrained paths are never overridden: explicit
//! TP demands, memory-driven long-context binding (Use Case 3), and
//! priority binding (Use Case 2) behave exactly as `FlyingPolicy` — a plan
//! can make the system scale out or up, it cannot make it OOM or starve
//! priority traffic.
//!
//! Thrash control is layered: controllers carry their own hysteresis
//! (threshold dead-band, cost-model improvement margin) and the runtime
//! enforces a hard cooldown between plan changes, so the number of plan
//! changes over a run is bounded by `duration / cooldown_s + 1` by
//! construction.

use crate::coordinator::policy::{FlyingPolicy, ModeDecision, Policy, Snapshot};
use crate::sim::cluster::SimConfig;
use crate::sim::costmodel::CostModel;
use crate::workload::Priority;

use super::forecast::Forecaster;
use super::telemetry::{Telemetry, WindowStats};

/// Fleet-level reconfiguration plan for elastic traffic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Plan {
    /// Defer to the per-request `FlyingPolicy` heuristics unchanged.
    Hold,
    /// Serve elastic traffic DP (merged groups split as they drain).
    ScaleOut,
    /// Bind elastic traffic into TP groups `width` instances wide.
    ScaleUp { width: usize },
}

impl Plan {
    pub fn label(&self) -> &'static str {
        match self {
            Plan::Hold => "hold",
            Plan::ScaleOut => "scale-out",
            Plan::ScaleUp { .. } => "scale-up",
        }
    }
}

/// Everything a controller sees at a tick: windowed telemetry, forecast,
/// and instantaneous cluster state.
#[derive(Clone, Copy, Debug)]
pub struct CtrlSnapshot {
    pub now: f64,
    pub window: WindowStats,
    pub rate_fast: f64,
    pub rate_slow: f64,
    pub forecast_rate: f64,
    pub burst: bool,
    pub queue_len: usize,
    /// Cluster KV utilization in [0, 1].
    pub kv_frac: f64,
    /// Idle serving instances, in unit-instance terms.
    pub idle_units: usize,
    /// Total serving instances the node partitions into.
    pub n_units: usize,
    pub cur_plan: Plan,
}

/// Decision audit record of one control tick, kept `Copy` so storing it is
/// output-invariant (no allocation, no behavior change).  The flight
/// recorder (`obs::Event::CtrlTick`) carries this verbatim: telemetry
/// snapshot, forecaster state, the plan the controller wanted, the plan
/// actually adopted, and whether the cooldown held the change back.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TickInfo {
    /// Monotonic tick counter (1-based) — dedupe key for journal consumers
    /// that poll rather than subscribe.
    pub seq: usize,
    pub now: f64,
    pub arrival_rate: f64,
    pub rate_fast: f64,
    pub rate_slow: f64,
    pub forecast_rate: f64,
    pub burst: bool,
    pub queue_len: usize,
    pub kv_frac: f64,
    pub idle_units: usize,
    pub n_units: usize,
    /// What the controller asked for this tick.
    pub desired: Plan,
    /// What the runtime is actually running after the tick.
    pub adopted: Plan,
    /// `desired != adopted` solely because the cooldown dwell rejected it.
    pub held_by_cooldown: bool,
}

/// A reconfiguration controller: pure function of telemetry snapshots to
/// plans (plus private hysteresis state).  Deterministic by contract — the
/// same snapshot stream must yield the same plan stream, which is what
/// keeps simulated and real decisions byte-identical.
pub trait Controller: Send {
    fn name(&self) -> &'static str;
    fn plan(&mut self, snap: &CtrlSnapshot) -> Plan;
}

// ---------------------------------------------------------------------------
// StaticController — fixed-plan baselines
// ---------------------------------------------------------------------------

/// Emits one fixed plan forever.  `hold()` is the do-nothing baseline (the
/// event core must behave exactly like plain `FlyingPolicy` under it — the
/// differential harness asserts this); `dp()`/`tp(w)` pin the fleet to one
/// layout for controller ablations.
pub struct StaticController {
    fixed: Plan,
    label: &'static str,
}

impl StaticController {
    pub fn hold() -> Self {
        StaticController { fixed: Plan::Hold, label: "static-hold" }
    }

    pub fn dp() -> Self {
        StaticController { fixed: Plan::ScaleOut, label: "static-dp-plan" }
    }

    pub fn tp(width: usize) -> Self {
        StaticController {
            fixed: Plan::ScaleUp { width },
            label: "static-tp-plan",
        }
    }
}

impl Controller for StaticController {
    fn name(&self) -> &'static str {
        self.label
    }

    fn plan(&mut self, _snap: &CtrlSnapshot) -> Plan {
        self.fixed
    }
}

// ---------------------------------------------------------------------------
// ThresholdController — queue/burst bands with a hysteresis dead-band
// ---------------------------------------------------------------------------

/// Classic reactive control: scale out on backlog or burst, scale up when the
/// fleet is demonstrably idle, hold inside the dead-band between the two
/// thresholds so small oscillations never flip the plan.
pub struct ThresholdController {
    /// Scale out when queue_len >= hi_queue_per_unit * n_units.
    pub hi_queue_per_unit: f64,
    /// Scale up only when queue_len <= lo_queue ...
    pub lo_queue: usize,
    /// ... and at least this fraction of units is idle.
    pub idle_frac_up: f64,
    /// TP width to scale up to; 0 = widest (n_units).
    pub up_width: usize,
    state: Plan,
}

impl Default for ThresholdController {
    fn default() -> Self {
        ThresholdController {
            hi_queue_per_unit: 1.0,
            lo_queue: 0,
            idle_frac_up: 0.75,
            up_width: 0,
            state: Plan::Hold,
        }
    }
}

impl Controller for ThresholdController {
    fn name(&self) -> &'static str {
        "threshold"
    }

    fn plan(&mut self, snap: &CtrlSnapshot) -> Plan {
        let q = snap.queue_len as f64;
        if snap.burst || q >= self.hi_queue_per_unit * snap.n_units as f64 {
            self.state = Plan::ScaleOut;
        } else if snap.queue_len <= self.lo_queue
            && (snap.idle_units as f64) >= self.idle_frac_up * snap.n_units as f64
        {
            let w = if self.up_width == 0 { snap.n_units } else { self.up_width };
            if w > 1 {
                self.state = Plan::ScaleUp { width: w };
            }
        }
        // Between the bands: keep the previous plan (hysteresis).
        self.state
    }
}

// ---------------------------------------------------------------------------
// CostModelController — layout scoring against sim::costmodel::CostModel
// ---------------------------------------------------------------------------

/// Scores every candidate engine layout (k groups of w instances,
/// k·w = n_units) against the analytic cost model under the forecast
/// rate/mix and picks the feasible layout with the best expected TTFT.
///
/// Per width w (GPUs g = w · model.min_gpus, k = n_units / w groups):
///
/// * `service_s(w)` — expected busy time one request costs its group:
///   chunked prefill of the mean prompt plus its share of full-batch
///   decode steps for the mean output length.
/// * `util(w) = rate · service_s(w) / k` — offered utilization of the k
///   parallel groups.  Widths with `util > util_max` are infeasible
///   (queues would grow without bound).
/// * `score(w) = prefill_s(w) / (1 - util(w))` — prefill latency inflated
///   by the M/M/k-style congestion factor; lower is better.
///
/// Bursts override the model (the smoothed forecast lags rate jumps);
/// an improvement margin keeps the plan sticky near score ties.
pub struct CostModelController {
    cm: CostModel,
    /// Decode batch the capacity estimate assumes (SimConfig::max_batch).
    pub max_batch: usize,
    /// Utilization above which a layout counts as saturated.
    pub util_max: f64,
    /// A new width must score below margin · current score to displace it.
    pub improve_margin: f64,
    /// Hold until the window has at least this many arrivals.
    pub min_window_arrivals: usize,
    cur_width: usize, // 0 = not yet decided
}

impl CostModelController {
    pub fn new(cm: CostModel) -> Self {
        CostModelController {
            cm,
            // Score layouts against the decode batch the simulator actually
            // runs, not a second literal that could drift from it.
            max_batch: SimConfig::default().max_batch,
            util_max: 0.75,
            improve_margin: 0.85,
            min_window_arrivals: 5,
            cur_width: 0,
        }
    }

    /// (score, util) for serving the windowed mix at width `w`.
    fn score(&self, w: usize, rate: f64, mean_prompt: f64, mean_output: f64, n_units: usize) -> (f64, f64) {
        let g = w * self.cm.model.min_gpus;
        let k = (n_units / w).max(1) as f64;
        let prompt = (mean_prompt.max(1.0)) as usize;
        let output = mean_output.max(0.0);
        let ctx = prompt + (output / 2.0) as usize;
        let prefill = self.cm.prefill_s(prompt, g);
        let step = self.cm.decode_step_s(self.max_batch, ctx.max(1), g);
        let service = prefill + output * step / self.max_batch.max(1) as f64;
        let util = rate * service / k;
        if util >= self.util_max {
            return (f64::INFINITY, util);
        }
        (prefill / (1.0 - util), util)
    }

    fn width_plan(w: usize) -> Plan {
        if w <= 1 {
            Plan::ScaleOut
        } else {
            Plan::ScaleUp { width: w }
        }
    }
}

impl Controller for CostModelController {
    fn name(&self) -> &'static str {
        "costmodel"
    }

    fn plan(&mut self, snap: &CtrlSnapshot) -> Plan {
        // Bursts beat the model: the smoothed forecast lags a rate jump by
        // seconds, and the one safe answer under a burst is concurrency.
        if snap.burst {
            self.cur_width = 1;
            return Plan::ScaleOut;
        }
        if snap.window.n_arrivals < self.min_window_arrivals {
            return if self.cur_width == 0 {
                Plan::Hold
            } else {
                Self::width_plan(self.cur_width)
            };
        }
        let rate = snap.forecast_rate.max(snap.window.arrival_rate);
        let (mp, mo) = (snap.window.mean_prompt, snap.window.mean_output);

        let mut best: Option<(usize, f64)> = None;
        let mut w = 1usize;
        while w <= snap.n_units {
            let (score, _util) = self.score(w, rate, mp, mo, snap.n_units);
            if score.is_finite() && best.map(|(_, s)| score < s).unwrap_or(true) {
                best = Some((w, score));
            }
            w *= 2;
        }
        // Every width saturated: maximize concurrency and let per-request
        // admission control shed what it must.
        let (mut chosen, best_score) = best.unwrap_or((1, f64::INFINITY));

        // Hysteresis: displace the current width only on a clear win.
        if self.cur_width != 0 && chosen != self.cur_width {
            let (cur_score, _) = self.score(self.cur_width, rate, mp, mo, snap.n_units);
            if best_score > self.improve_margin * cur_score {
                chosen = self.cur_width;
            }
        }
        self.cur_width = chosen;
        Self::width_plan(chosen)
    }
}

// ---------------------------------------------------------------------------
// ControlRuntime — telemetry + forecast + controller + cooldown
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
pub struct ControlConfig {
    /// Telemetry sliding-window length (seconds).
    pub window_s: f64,
    /// Control-tick interval (seconds): how often plans are recomputed.
    pub tick_s: f64,
    /// Minimum dwell between plan changes (seconds).
    pub cooldown_s: f64,
    /// Telemetry ring capacity (fixed allocation at construction).
    pub ring_cap: usize,
    /// prompt+output above this counts as long-context in telemetry.
    pub long_threshold: usize,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            window_s: 20.0,
            tick_s: 1.0,
            cooldown_s: 15.0,
            ring_cap: 4096,
            long_threshold: usize::MAX,
        }
    }
}

/// The control plane an adaptive run carries: shared verbatim by
/// `sim::simulate_adaptive` and the real path's [`AdaptivePolicy`], so a
/// controller's decisions are byte-identical given the same event stream.
pub struct ControlRuntime {
    pub cfg: ControlConfig,
    telemetry: Telemetry,
    forecaster: Forecaster,
    controller: Box<dyn Controller>,
    inner: FlyingPolicy,
    plan: Plan,
    next_tick: f64,
    last_change: f64,
    plan_changes: usize,
    ticks: usize,
    last_tick: Option<TickInfo>,
}

impl ControlRuntime {
    pub fn new(controller: Box<dyn Controller>, cfg: ControlConfig) -> Self {
        ControlRuntime {
            telemetry: Telemetry::new(cfg.window_s, cfg.ring_cap, cfg.long_threshold),
            forecaster: Forecaster::default(),
            controller,
            inner: FlyingPolicy::default(),
            plan: Plan::Hold,
            next_tick: 0.0,
            last_change: f64::NEG_INFINITY,
            plan_changes: 0,
            ticks: 0,
            last_tick: None,
            cfg,
        }
    }

    pub fn controller_name(&self) -> &'static str {
        self.controller.name()
    }

    pub fn plan(&self) -> Plan {
        self.plan
    }

    /// Plan changes adopted so far — bounded by duration / cooldown_s + 1.
    pub fn plan_changes(&self) -> usize {
        self.plan_changes
    }

    pub fn ticks(&self) -> usize {
        self.ticks
    }

    /// Audit record of the most recent tick (None before the first).  The
    /// flight recorder journals this; consumers dedupe on `seq`.
    pub fn last_tick(&self) -> Option<TickInfo> {
        self.last_tick
    }

    // ---- telemetry taps (O(1), allocation-free) --------------------------

    #[inline]
    pub fn note_arrival(&mut self, t: f64, prompt_len: usize, output_len: usize, high: bool) {
        self.telemetry.note_arrival(t, prompt_len, output_len, high);
    }

    #[inline]
    pub fn note_first_token(&mut self, t: f64, ttft_s: f64) {
        self.telemetry.note_first_token(t, ttft_s);
    }

    #[inline]
    pub fn note_step(&mut self, t: f64, per_token_s: f64) {
        self.telemetry.note_step(t, per_token_s);
    }

    /// Whether a control tick is due at `now` (cheap guard so callers only
    /// gather tick inputs — queue depth, KV pressure — when needed).
    #[inline]
    pub fn due(&self, now: f64) -> bool {
        now >= self.next_tick
    }

    /// Windowed telemetry view at `now` (what the next tick's controller
    /// would see).  Exposed for tests and diagnostics; runs the same
    /// O(window) walk as a tick, so keep it off per-event paths.
    pub fn window_stats(&mut self, now: f64) -> WindowStats {
        self.telemetry.window_stats(now)
    }

    /// Run one control tick: fold the window into the forecaster, ask the
    /// controller for a plan, and adopt it if the cooldown allows.
    pub fn tick(&mut self, now: f64, queue_len: usize, kv_frac: f64, idle_units: usize, n_units: usize) {
        self.next_tick = now + self.cfg.tick_s;
        self.ticks += 1;
        let window = self.telemetry.window_stats(now);
        self.forecaster.observe_rate(now, window.arrival_rate);
        let snap = CtrlSnapshot {
            now,
            window,
            rate_fast: self.forecaster.rate_fast(),
            rate_slow: self.forecaster.rate_slow(),
            forecast_rate: self.forecaster.forecast_rate(),
            burst: self.forecaster.bursting(),
            queue_len,
            kv_frac,
            idle_units,
            n_units,
            cur_plan: self.plan,
        };
        let desired = self.controller.plan(&snap);
        let changeable = now - self.last_change >= self.cfg.cooldown_s;
        if desired != self.plan && changeable {
            self.plan = desired;
            self.last_change = now;
            self.plan_changes += 1;
        }
        // Output-invariant audit store: `Copy` struct, no allocation.  The
        // flight recorder picks this up when tracing is armed.
        self.last_tick = Some(TickInfo {
            seq: self.ticks,
            now,
            arrival_rate: snap.window.arrival_rate,
            rate_fast: snap.rate_fast,
            rate_slow: snap.rate_slow,
            forecast_rate: snap.forecast_rate,
            burst: snap.burst,
            queue_len,
            kv_frac,
            idle_units,
            n_units,
            desired,
            adopted: self.plan,
            held_by_cooldown: desired != self.plan && !changeable,
        });
    }

    /// Per-request mode decision under the current plan (steps ③ of
    /// Algorithm 1, plan-steered).  Shared by the simulator's assignment
    /// walk and the real coordinator via [`AdaptivePolicy`].
    pub fn decide(
        &mut self,
        prompt_len: usize,
        output_len_hint: usize,
        priority: Priority,
        tp_demand: Option<usize>,
        snap: &Snapshot,
    ) -> ModeDecision {
        plan_decision(
            self.plan,
            &mut self.inner,
            prompt_len,
            output_len_hint,
            priority,
            tp_demand,
            snap,
        )
    }
}

/// Map (plan, request, snapshot) to a mode decision.  The correctness
/// constraints (explicit demand, memory-driven binding, priority binding)
/// are identical to `FlyingPolicy`; only the elastic Use-Case-1 tail is
/// plan-steered.
pub fn plan_decision(
    plan: Plan,
    inner: &mut FlyingPolicy,
    prompt_len: usize,
    output_len_hint: usize,
    priority: Priority,
    tp_demand: Option<usize>,
    snap: &Snapshot,
) -> ModeDecision {
    // The scheduling kernel's constraint tiers (the single definition
    // FlyingPolicy itself runs) decide everything that is not elastic.
    if let Some(d) =
        crate::sched::constrained(prompt_len, output_len_hint, priority, tp_demand, snap)
    {
        return d;
    }
    match plan {
        Plan::Hold => inner.decide(prompt_len, output_len_hint, priority, tp_demand, snap),
        Plan::ScaleOut => ModeDecision::Dp,
        Plan::ScaleUp { width } => {
            ModeDecision::Tp(width.max(2).min(snap.max_tp).min(snap.n_engines))
        }
    }
}

/// The real serving path's adaptor: a `Policy` whose decisions come from a
/// [`ControlRuntime`].  Telemetry on this path is fed from the scheduler's
/// decide stream through [`Policy::decide_for`], **deduplicated by request
/// id**: the scheduler re-decides every waiting request each iteration, so
/// under requeue pressure the same request is decided many times — counting
/// each attempt as an arrival (the pre-ISSUE-3 behavior, still reachable
/// through the id-less `decide`) inflated the window's arrival rate exactly
/// when the queue backed up.  A bounded FIFO of recently-seen ids keeps the
/// dedupe O(log n) per attempt with a fixed memory footprint.
pub struct AdaptivePolicy {
    rt: ControlRuntime,
    seen: std::collections::BTreeSet<u64>,
    seen_fifo: std::collections::VecDeque<u64>,
}

/// Dedupe window: ids remembered at once.  Far above any realistic
/// in-flight+waiting population; eviction exists only to bound memory on
/// unbounded id streams.
const SEEN_CAP: usize = 8192;

impl AdaptivePolicy {
    pub fn new(rt: ControlRuntime) -> Self {
        AdaptivePolicy {
            rt,
            seen: Default::default(),
            seen_fifo: std::collections::VecDeque::with_capacity(SEEN_CAP),
        }
    }

    pub fn runtime(&self) -> &ControlRuntime {
        &self.rt
    }

    pub fn runtime_mut(&mut self) -> &mut ControlRuntime {
        &mut self.rt
    }

    fn tick_and_decide(
        &mut self,
        prompt_len: usize,
        output_len_hint: usize,
        priority: Priority,
        tp_demand: Option<usize>,
        snap: &Snapshot,
    ) -> ModeDecision {
        if self.rt.due(snap.now) {
            self.rt.tick(
                snap.now,
                snap.queue_len,
                snap.kv_frac,
                snap.idle_engines,
                snap.n_engines,
            );
        }
        self.rt
            .decide(prompt_len, output_len_hint, priority, tp_demand, snap)
    }
}

impl Policy for AdaptivePolicy {
    fn name(&self) -> &'static str {
        self.rt.controller_name()
    }

    fn last_tick(&self) -> Option<TickInfo> {
        self.rt.last_tick()
    }

    fn decide(
        &mut self,
        prompt_len: usize,
        output_len_hint: usize,
        priority: Priority,
        tp_demand: Option<usize>,
        snap: &Snapshot,
    ) -> ModeDecision {
        // No id: every attempt counts as an arrival (legacy over-counting
        // path — prefer `decide_for`, which the coordinator uses).
        self.rt
            .note_arrival(snap.now, prompt_len, output_len_hint, priority == Priority::High);
        self.tick_and_decide(prompt_len, output_len_hint, priority, tp_demand, snap)
    }

    fn decide_for(
        &mut self,
        rid: u64,
        prompt_len: usize,
        output_len_hint: usize,
        priority: Priority,
        tp_demand: Option<usize>,
        snap: &Snapshot,
    ) -> ModeDecision {
        if self.seen.insert(rid) {
            self.seen_fifo.push_back(rid);
            if self.seen_fifo.len() > SEEN_CAP {
                if let Some(old) = self.seen_fifo.pop_front() {
                    self.seen.remove(&old);
                }
            }
            self.rt.note_arrival(
                snap.now,
                prompt_len,
                output_len_hint,
                priority == Priority::High,
            );
        }
        self.tick_and_decide(prompt_len, output_len_hint, priority, tp_demand, snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::costmodel::{HwSpec, PaperModel};

    fn snap(queue: usize, idle: usize, rate_fast: f64, burst: bool, n_arr: usize) -> CtrlSnapshot {
        CtrlSnapshot {
            now: 100.0,
            window: WindowStats {
                arrival_rate: rate_fast,
                mean_prompt: 2000.0,
                mean_output: 300.0,
                long_frac: 0.0,
                high_frac: 0.0,
                ttft_p90: f64::NAN,
                tpot_p50: f64::NAN,
                n_arrivals: n_arr,
            },
            rate_fast,
            rate_slow: rate_fast,
            forecast_rate: rate_fast,
            burst,
            queue_len: queue,
            kv_frac: 0.1,
            idle_units: idle,
            n_units: 4,
            cur_plan: Plan::Hold,
        }
    }

    fn policy_snap() -> Snapshot {
        Snapshot {
            now: 0.0,
            queue_len: 0,
            idle_engines: 4,
            n_engines: 4,
            dp_capacity_tokens: 1000,
            max_tp: 4,
            kv_frac: 0.0,
        }
    }

    #[test]
    fn static_controller_never_moves() {
        let mut c = StaticController::tp(4);
        assert_eq!(c.plan(&snap(0, 4, 0.1, false, 0)), Plan::ScaleUp { width: 4 });
        assert_eq!(c.plan(&snap(99, 0, 50.0, true, 500)), Plan::ScaleUp { width: 4 });
    }

    #[test]
    fn threshold_scales_out_on_burst_and_backlog() {
        let mut c = ThresholdController::default();
        assert_eq!(c.plan(&snap(0, 0, 5.0, true, 50)), Plan::ScaleOut);
        let mut c = ThresholdController::default();
        assert_eq!(c.plan(&snap(8, 0, 5.0, false, 50)), Plan::ScaleOut);
    }

    #[test]
    fn threshold_scales_up_when_idle_and_holds_in_dead_band() {
        let mut c = ThresholdController::default();
        assert_eq!(c.plan(&snap(0, 4, 0.5, false, 5)), Plan::ScaleUp { width: 4 });
        // Dead band (some queue, not enough for scale-out): plan is sticky.
        assert_eq!(c.plan(&snap(2, 1, 3.0, false, 20)), Plan::ScaleUp { width: 4 });
        // Backlog crosses the hi threshold: flips to scale-out.
        assert_eq!(c.plan(&snap(4, 0, 3.0, false, 20)), Plan::ScaleOut);
        // Back in the dead band: stays scaled out.
        assert_eq!(c.plan(&snap(2, 1, 3.0, false, 20)), Plan::ScaleOut);
    }

    fn llama_ctrl() -> CostModelController {
        CostModelController::new(CostModel::new(HwSpec::default(), PaperModel::llama70b()))
    }

    #[test]
    fn costmodel_widens_at_low_load_narrows_at_high_load() {
        let mut c = llama_ctrl();
        // 1 req/s of the paper mix: wide TP is feasible and lowest-latency.
        match c.plan(&snap(0, 4, 1.0, false, 30)) {
            Plan::ScaleUp { width } => assert!(width >= 2, "width={width}"),
            p => panic!("expected scale-up at low load, got {p:?}"),
        }
        // 20 req/s: every width saturates; concurrency (DP) is the answer.
        let mut c = llama_ctrl();
        assert_eq!(c.plan(&snap(0, 0, 20.0, false, 200)), Plan::ScaleOut);
    }

    #[test]
    fn costmodel_burst_overrides_model() {
        let mut c = llama_ctrl();
        assert_eq!(c.plan(&snap(0, 4, 1.0, true, 30)), Plan::ScaleOut);
    }

    #[test]
    fn costmodel_holds_until_primed() {
        let mut c = llama_ctrl();
        assert_eq!(c.plan(&snap(0, 4, 0.2, false, 2)), Plan::Hold);
    }

    #[test]
    fn costmodel_hysteresis_is_sticky_near_ties() {
        let mut c = llama_ctrl();
        c.improve_margin = 0.0; // nothing ever displaces the current width
        let first = c.plan(&snap(0, 4, 1.0, false, 30));
        let again = c.plan(&snap(0, 2, 2.0, false, 60));
        assert_eq!(first, again);
    }

    #[test]
    fn runtime_cooldown_bounds_plan_changes() {
        let mut rt = ControlRuntime::new(
            Box::new(ThresholdController::default()),
            ControlConfig { tick_s: 1.0, cooldown_s: 10.0, ..ControlConfig::default() },
        );
        // Alternate between idle and saturated snapshots every tick: without
        // the cooldown this would flip the plan every second.
        for i in 0..100 {
            let t = i as f64;
            if rt.due(t) {
                if i % 2 == 0 {
                    rt.tick(t, 0, 0.0, 4, 4);
                } else {
                    rt.tick(t, 16, 0.9, 0, 4);
                }
            }
        }
        assert!(
            rt.plan_changes() <= 100 / 10 + 1,
            "plan_changes={}",
            rt.plan_changes()
        );
        assert!(rt.ticks() >= 99);
    }

    #[test]
    fn plan_decision_respects_correctness_constraints() {
        let mut inner = FlyingPolicy::default();
        let s = policy_snap();
        // Explicit demand wins over any plan.
        assert_eq!(
            plan_decision(Plan::ScaleOut, &mut inner, 10, 10, Priority::Normal, Some(4), &s),
            ModeDecision::Tp(4)
        );
        // Memory-driven binding wins over ScaleOut.
        assert_eq!(
            plan_decision(Plan::ScaleOut, &mut inner, 1500, 100, Priority::Normal, None, &s),
            ModeDecision::Tp(2)
        );
        // Priority binding wins over ScaleOut.
        assert_eq!(
            plan_decision(Plan::ScaleOut, &mut inner, 100, 50, Priority::High, None, &s),
            ModeDecision::Tp(2)
        );
        // Oversized requests still reject under any plan.
        assert_eq!(
            plan_decision(Plan::ScaleUp { width: 4 }, &mut inner, 10_000, 0, Priority::Normal, None, &s),
            ModeDecision::Reject
        );
    }

    #[test]
    fn adaptive_policy_dedupes_requeue_arrivals() {
        let mut p = AdaptivePolicy::new(ControlRuntime::new(
            Box::new(StaticController::hold()),
            ControlConfig::default(),
        ));
        let s = policy_snap();
        // The scheduler re-decides a queued request every iteration; only
        // the first attempt per id may count as an arrival (the ROADMAP's
        // requeue over-count).
        for _ in 0..5 {
            p.decide_for(42, 100, 50, Priority::Normal, None, &s);
        }
        p.decide_for(43, 100, 50, Priority::Normal, None, &s);
        assert_eq!(p.runtime_mut().window_stats(0.0).n_arrivals, 2);
        // The id-less legacy path still counts every call.
        p.decide(100, 50, Priority::Normal, None, &s);
        p.decide(100, 50, Priority::Normal, None, &s);
        assert_eq!(p.runtime_mut().window_stats(0.0).n_arrivals, 4);
    }

    #[test]
    fn plan_decision_steers_elastic_tail() {
        let mut inner = FlyingPolicy::default();
        let s = policy_snap();
        assert_eq!(
            plan_decision(Plan::ScaleOut, &mut inner, 100, 50, Priority::Normal, None, &s),
            ModeDecision::Dp
        );
        assert_eq!(
            plan_decision(Plan::ScaleUp { width: 4 }, &mut inner, 100, 50, Priority::Normal, None, &s),
            ModeDecision::Tp(4)
        );
        // Hold defers to FlyingPolicy (light load in `s` -> widen).
        assert_eq!(
            plan_decision(Plan::Hold, &mut inner, 100, 50, Priority::Normal, None, &s),
            inner.decide(100, 50, Priority::Normal, None, &s)
        );
    }
}
