//! Synthetic workload generation (paper §6.1.3).
//!
//! "Publicly available LLM datasets provide request contents but not
//! realistic, reproducible arrival-time traces" — the paper synthesizes
//! workloads, and so do we, with the same structure:
//!   (1) request lengths sampled uniformly from a prompt/output range,
//!   (2) arrival rates alternating between a low-load phase and high-load
//!       bursts (Poisson within each phase),
//!   (3) a fixed request volume to capture steady state across bursts.
//!
//! Lengths are scaled from the paper's [128, 4000]/[64, 512] token ranges to
//! this testbed's tiny models via `scale`; the simulator's cost model runs
//! at paper scale directly.  A fraction of requests carries high priority
//! (Use Case 2) and a fraction demands long context above DP capacity
//! (Use Case 3).

use crate::util::rng::Rng;

pub mod scenarios;

pub use scenarios::{Scenario, LONG_CTX_RANGE};

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    #[default]
    Normal,
    High,
}

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub arrival: f64, // seconds from trace start
    pub prompt_len: usize,
    pub output_len: usize,
    pub priority: Priority,
    /// Explicit TP demand (latency-strict or memory-driven requests).
    /// None = scheduler's choice.
    pub tp_demand: Option<usize>,
    /// Prompt-family membership for prefix-cache workloads (ISSUE 10):
    /// `(family_id, prefix_len)` means the first `prefix_len` prompt tokens
    /// are shared verbatim with every other request of `family_id` (see
    /// [`synth_prompt_tokens_family`]).  `None` = unique prompt.  Pure
    /// metadata: schedulers ignore it unless `--prefix-cache` is armed.
    pub prefix_family: Option<(u64, usize)>,
}

#[derive(Clone, Debug)]
pub struct WorkloadCfg {
    pub seed: u64,
    pub n_requests: usize,
    pub prompt_range: (usize, usize),
    pub output_range: (usize, usize),
    /// req/s during low-load phases (sampled uniformly per phase).
    pub low_rate: (f64, f64),
    /// req/s during bursts.
    pub high_rate: (f64, f64),
    /// Seconds per low/high phase.
    pub phase_secs: f64,
    /// Fraction of requests with high priority.
    pub priority_frac: f64,
    /// Fraction of requests demanding a long context (prompt_len is then
    /// sampled from (long_ctx_min, long_ctx_max)).
    pub long_frac: f64,
    pub long_ctx_range: (usize, usize),
}

impl WorkloadCfg {
    /// Paper §6.1.3 shape at testbed scale: prompts [16, 500], outputs
    /// [8, 64], 2–5 r/s low, 10–30 r/s bursts, 20 s phases.
    pub fn paper_scaled(seed: u64, n_requests: usize) -> Self {
        WorkloadCfg {
            seed,
            n_requests,
            prompt_range: (16, 500),
            output_range: (8, 64),
            low_rate: (2.0, 5.0),
            high_rate: (10.0, 30.0),
            phase_secs: 20.0,
            priority_frac: 0.0,
            long_frac: 0.0,
            long_ctx_range: (0, 0),
        }
    }

    /// Paper-scale lengths for the discrete-event simulator (no scaling).
    pub fn paper_full(seed: u64, n_requests: usize) -> Self {
        WorkloadCfg {
            seed,
            n_requests,
            prompt_range: (128, 4000),
            output_range: (64, 512),
            low_rate: (2.0, 5.0),
            high_rate: (10.0, 30.0),
            phase_secs: 20.0,
            priority_frac: 0.0,
            long_frac: 0.0,
            long_ctx_range: (0, 0),
        }
    }
}

/// Generate the arrival trace.  Deterministic in `cfg.seed`.
pub fn generate(cfg: &WorkloadCfg) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let mut out = Vec::with_capacity(cfg.n_requests);
    let mut t = 0.0f64;
    let mut phase_high = false;
    let mut phase_end = cfg.phase_secs;
    let mut rate = rng.uniform(cfg.low_rate.0, cfg.low_rate.1);
    for id in 0..cfg.n_requests as u64 {
        t += rng.exp(rate);
        while t >= phase_end {
            phase_high = !phase_high;
            phase_end += cfg.phase_secs;
            rate = if phase_high {
                rng.uniform(cfg.high_rate.0, cfg.high_rate.1)
            } else {
                rng.uniform(cfg.low_rate.0, cfg.low_rate.1)
            };
        }
        let long = cfg.long_frac > 0.0 && rng.bool(cfg.long_frac);
        let prompt_len = if long {
            rng.range_usize(cfg.long_ctx_range.0, cfg.long_ctx_range.1)
        } else {
            rng.range_usize(cfg.prompt_range.0, cfg.prompt_range.1)
        };
        let priority = if cfg.priority_frac > 0.0 && rng.bool(cfg.priority_frac) {
            Priority::High
        } else {
            Priority::Normal
        };
        out.push(Request {
            id,
            arrival: t,
            prompt_len,
            output_len: rng.range_usize(cfg.output_range.0, cfg.output_range.1),
            priority,
            tp_demand: None,
            prefix_family: None,
        });
    }
    out
}

/// Deterministic byte-level prompt content for the real serving path.
pub fn synth_prompt_tokens(id: u64, len: usize) -> Vec<i32> {
    let mut rng = Rng::new(0xC0FFEE ^ id);
    (0..len).map(|_| rng.range(0, 255) as i32).collect()
}

/// Family-aware variant of [`synth_prompt_tokens`] (ISSUE 10): requests in
/// the same family share a *byte-identical* token prefix (drawn from a
/// family-seeded stream) followed by the per-id unique stream, so the real
/// path's prefix tree genuinely matches across requests.  With
/// `family: None` this is exactly `synth_prompt_tokens`.
pub fn synth_prompt_tokens_family(
    id: u64,
    len: usize,
    family: Option<(u64, usize)>,
) -> Vec<i32> {
    let Some((fid, prefix_len)) = family else {
        return synth_prompt_tokens(id, len);
    };
    let shared = prefix_len.min(len);
    let mut fam_rng = Rng::new(0xFA317E ^ fid.wrapping_mul(0x9E37_79B9));
    let mut out: Vec<i32> = (0..shared).map(|_| fam_rng.range(0, 255) as i32).collect();
    let mut rng = Rng::new(0xC0FFEE ^ id);
    out.extend((shared..len).map(|_| rng.range(0, 255) as i32));
    out
}

/// Validate a trace before it reaches a scheduler: arrival times must be
/// finite and non-negative (NaN arrivals would poison every time-ordered
/// structure; the old `partial_cmp(..).unwrap()` comparisons panicked
/// mid-run instead of at the boundary).
pub fn validate(reqs: &[Request]) -> anyhow::Result<()> {
    for r in reqs {
        if !r.arrival.is_finite() {
            anyhow::bail!("request {}: non-finite arrival time {}", r.id, r.arrival);
        }
        if r.arrival < 0.0 {
            anyhow::bail!("request {}: negative arrival time {}", r.id, r.arrival);
        }
    }
    Ok(())
}

/// CSV trace record/replay, so benchmark runs are comparable across systems.
/// The two prefix-family columns (ISSUE 10) are empty for unique prompts.
pub fn to_csv(reqs: &[Request]) -> String {
    let mut s =
        String::from("id,arrival,prompt_len,output_len,priority,tp_demand,family,prefix_len\n");
    for r in reqs {
        let (fid, plen) = match r.prefix_family {
            Some((fid, plen)) => (fid.to_string(), plen.to_string()),
            None => (String::new(), String::new()),
        };
        s.push_str(&format!(
            "{},{:.6},{},{},{},{},{},{}\n",
            r.id,
            r.arrival,
            r.prompt_len,
            r.output_len,
            if r.priority == Priority::High { 1 } else { 0 },
            r.tp_demand.map(|p| p.to_string()).unwrap_or_default(),
            fid,
            plen,
        ));
    }
    s
}

/// Accepts both the pre-ISSUE-10 6-field layout (recorded traces stay
/// replayable) and the extended 8-field layout with the family columns.
pub fn from_csv(text: &str) -> anyhow::Result<Vec<Request>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate().skip(1) {
        if line.trim().is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split(',').collect();
        if f.len() != 6 && f.len() != 8 {
            anyhow::bail!("trace line {i}: expected 6 or 8 fields");
        }
        let prefix_family = if f.len() == 8 && !f[6].is_empty() {
            if f[7].is_empty() {
                anyhow::bail!("trace line {i}: family id without prefix_len");
            }
            Some((f[6].parse()?, f[7].parse()?))
        } else {
            None
        };
        out.push(Request {
            id: f[0].parse()?,
            arrival: f[1].parse()?,
            prompt_len: f[2].parse()?,
            output_len: f[3].parse()?,
            priority: if f[4] == "1" { Priority::High } else { Priority::Normal },
            tp_demand: if f[5].is_empty() { None } else { Some(f[5].parse()?) },
            prefix_family,
        });
    }
    validate(&out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let cfg = WorkloadCfg::paper_scaled(9, 200);
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), 200);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.prompt_len, y.prompt_len);
        }
    }

    #[test]
    fn arrivals_monotone_and_lengths_in_range() {
        let cfg = WorkloadCfg::paper_scaled(1, 500);
        let reqs = generate(&cfg);
        let mut last = 0.0;
        for r in &reqs {
            assert!(r.arrival >= last);
            last = r.arrival;
            assert!((cfg.prompt_range.0..=cfg.prompt_range.1).contains(&r.prompt_len));
            assert!((cfg.output_range.0..=cfg.output_range.1).contains(&r.output_len));
        }
    }

    #[test]
    fn bursty_phases_change_rate() {
        // Mean inter-arrival in high phases must be clearly below low phases.
        let cfg = WorkloadCfg::paper_scaled(2, 3000);
        let reqs = generate(&cfg);
        let phase = |t: f64| ((t / cfg.phase_secs) as usize) % 2; // 0=low,1=high
        let mut gaps = [Vec::new(), Vec::new()];
        for w in reqs.windows(2) {
            let ph = phase(w[1].arrival);
            if phase(w[0].arrival) == ph {
                gaps[ph].push(w[1].arrival - w[0].arrival);
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(
            mean(&gaps[1]) < mean(&gaps[0]) * 0.5,
            "high-phase gap {} vs low-phase {}",
            mean(&gaps[1]),
            mean(&gaps[0])
        );
    }

    #[test]
    fn priority_and_long_fractions() {
        let mut cfg = WorkloadCfg::paper_scaled(3, 2000);
        cfg.priority_frac = 0.25;
        cfg.long_frac = 0.1;
        cfg.long_ctx_range = (2000, 3000);
        let reqs = generate(&cfg);
        let hi = reqs.iter().filter(|r| r.priority == Priority::High).count();
        let long = reqs.iter().filter(|r| r.prompt_len >= 2000).count();
        assert!((0.18..0.32).contains(&(hi as f64 / 2000.0)), "hi={hi}");
        assert!((0.05..0.16).contains(&(long as f64 / 2000.0)), "long={long}");
    }

    #[test]
    fn csv_roundtrip() {
        let mut cfg = WorkloadCfg::paper_scaled(4, 50);
        cfg.priority_frac = 0.5;
        let mut reqs = generate(&cfg);
        reqs[7].tp_demand = Some(4);
        reqs[9].prefix_family = Some((3, 96));
        let parsed = from_csv(&to_csv(&reqs)).unwrap();
        assert_eq!(parsed.len(), reqs.len());
        assert_eq!(parsed[7].tp_demand, Some(4));
        assert_eq!(parsed[9].prefix_family, Some((3, 96)));
        for (a, b) in reqs.iter().zip(&parsed) {
            assert_eq!(a.id, b.id);
            assert!((a.arrival - b.arrival).abs() < 1e-5);
            assert_eq!(a.priority, b.priority);
            assert_eq!(a.prefix_family, b.prefix_family);
        }
    }

    #[test]
    fn from_csv_accepts_legacy_six_field_traces() {
        // Traces recorded before the family columns existed must replay
        // unchanged (prefix_family = None).
        let legacy = "id,arrival,prompt_len,output_len,priority,tp_demand\n\
                      0,0.000000,10,5,0,\n\
                      1,0.500000,20,5,1,2\n";
        let reqs = from_csv(legacy).unwrap();
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0].prefix_family, None);
        assert_eq!(reqs[1].tp_demand, Some(2));
        // A family id without a prefix length is malformed, not legacy.
        assert!(from_csv("h\n0,0.0,10,5,0,,7,\n").is_err());
    }

    #[test]
    fn validate_rejects_nan_and_negative_arrivals() {
        let mut reqs = generate(&WorkloadCfg::paper_scaled(5, 10));
        assert!(validate(&reqs).is_ok());
        reqs[3].arrival = f64::NAN;
        assert!(validate(&reqs).is_err());
        reqs[3].arrival = -1.0;
        assert!(validate(&reqs).is_err());
        // ...and from_csv refuses such traces at the boundary.
        let mut csv = to_csv(&generate(&WorkloadCfg::paper_scaled(5, 3)));
        csv = csv.replace(
            csv.lines().nth(1).unwrap(),
            "0,-5.000000,10,10,0,",
        );
        assert!(from_csv(&csv).is_err());
    }

    #[test]
    fn synth_prompt_deterministic_and_bytelevel() {
        let a = synth_prompt_tokens(5, 64);
        let b = synth_prompt_tokens(5, 64);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (0..256).contains(&t)));
        assert_ne!(a, synth_prompt_tokens(6, 64));
    }

    #[test]
    fn family_prompts_share_prefix_and_diverge_after() {
        let a = synth_prompt_tokens_family(10, 64, Some((7, 16)));
        let b = synth_prompt_tokens_family(11, 64, Some((7, 16)));
        assert_eq!(a[..16], b[..16], "same family shares the leading tokens");
        assert_ne!(a[16..], b[16..], "tails stay per-request");
        let c = synth_prompt_tokens_family(12, 64, Some((8, 16)));
        assert_ne!(a[..16], c[..16], "different family, different prefix");
        // None falls through to the legacy generator byte-for-byte.
        assert_eq!(synth_prompt_tokens_family(5, 64, None), synth_prompt_tokens(5, 64));
        // prefix_len longer than the prompt saturates.
        let short = synth_prompt_tokens_family(13, 8, Some((7, 16)));
        assert_eq!(short.len(), 8);
        assert_eq!(short[..8], a[..8]);
    }
}
