//! Scenario library: named non-stationary workloads for exercising the
//! reconfiguration control plane (ROADMAP: "as many scenarios as you can
//! imagine").
//!
//! The base generator (`workload::generate`) produces the paper's two-phase
//! bursty trace; an adaptive controller's interesting failure modes live in
//! richer shapes — slow diurnal swings, sharp Poisson bursts, oscillating
//! long-context pressure, priority storms, and regime shifts in the
//! prompt/output mix.  Each scenario is a deterministic function of its
//! seed, emits plain [`Request`]s at paper-scale lengths (the discrete-event
//! simulator's operating point), and round-trips through the CSV trace
//! format like any other trace.
//!
//! Rate modulation uses per-arrival evaluation of a piecewise/continuous
//! rate function (gap ~ Exp(rate(t))): exact for piecewise-constant phases,
//! and an adequate approximation for the slowly-varying diurnal curve.

use std::fmt;

use crate::util::rng::Rng;

use super::{Priority, Request};

/// Long-context prompt range (tokens) used by the scenarios that exercise
/// memory-driven TP binding.  Calibrated to the simulator's Llama-70B
/// operating point: above one 2-GPU instance's ~264K-token KV capacity,
/// within the full node's ~2.3M (so TP-2/TP-4 groups serve them).
pub const LONG_CTX_RANGE: (usize, usize) = (300_000, 900_000);

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// Slow sinusoidal load swing (period ~4 min): the fleet should ride
    /// wide TP through the troughs and scale out over the crests.
    Diurnal,
    /// Low steady base load punctured by short, intense Poisson bursts —
    /// the paper's Use-Case-1 stress, sharpened.
    PoissonBurst,
    /// Long-context demand arrives in waves: KV pressure oscillates between
    /// DP-friendly and merge-forcing (Use Case 3 under non-stationarity).
    LongContextWave,
    /// Bursts of high-priority traffic over a best-effort baseline
    /// (Use Case 2 under contention).
    PriorityStorm,
    /// The prompt/output mix itself shifts regime every minute:
    /// chat-shaped, ingest-shaped, then mixed with long-context stragglers.
    MixedShift,
    /// Alternating short phases of bursty elastic traffic and long-context
    /// TP demand: every long phase opens while the elastic phase's
    /// residents are still mid-decode, forcing frequent DP↔TP flips with
    /// live KV on the chosen engines — the KV-migration stress shape
    /// (ISSUE 4).  A slice of the long-phase short traffic carries explicit
    /// `tp_demand`, so merges happen even when memory alone would not force
    /// them.
    SwitchChurn,
    /// Three client tiers served simultaneously — latency-strict clients
    /// with explicit `tp_demand`, high-priority interactive traffic, and an
    /// elastic best-effort bulk — with the tier *mix* rotating every phase
    /// (ISSUE 5: tiered requests multiply the switch-decision surface, the
    /// scheduling kernel's stress shape).  Every constraint tier of the
    /// admission walk (explicit demand, priority binding, elastic
    /// steering) is live in the same queue at once.
    ElasticTiers,
    /// Clustered prompt families: most arrivals open with one of a handful
    /// of long shared prefixes (system prompts / few-shot preambles), the
    /// rest are unique — the cross-request prefix-cache stress shape
    /// (ISSUE 10).  `prefix_family` is pure metadata until `--prefix-cache`
    /// is armed; token-level generators derive the actual shared bytes from
    /// it via `synth_prompt_tokens_family`.
    SharedPrefix,
}

impl Scenario {
    pub const ALL: [Scenario; 8] = [
        Scenario::Diurnal,
        Scenario::PoissonBurst,
        Scenario::LongContextWave,
        Scenario::PriorityStorm,
        Scenario::MixedShift,
        Scenario::SwitchChurn,
        Scenario::ElasticTiers,
        Scenario::SharedPrefix,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Scenario::Diurnal => "diurnal",
            Scenario::PoissonBurst => "poisson_burst",
            Scenario::LongContextWave => "long_context_wave",
            Scenario::PriorityStorm => "priority_storm",
            Scenario::MixedShift => "mixed_shift",
            Scenario::SwitchChurn => "switch_churn",
            Scenario::ElasticTiers => "elastic_tiers",
            Scenario::SharedPrefix => "shared_prefix",
        }
    }

    /// Generate `n_requests` arrivals.  Deterministic in `seed`.
    pub fn generate(&self, seed: u64, n_requests: usize) -> Vec<Request> {
        // Per-scenario seed whitening so the same seed does not replay the
        // same arrival skeleton across scenarios.
        let mut rng = Rng::new(seed ^ 0x5CE7A110u64.wrapping_mul(*self as u64 + 1));
        match self {
            Scenario::Diurnal => diurnal(&mut rng, n_requests),
            Scenario::PoissonBurst => poisson_burst(&mut rng, n_requests),
            Scenario::LongContextWave => long_context_wave(&mut rng, n_requests),
            Scenario::PriorityStorm => priority_storm(&mut rng, n_requests),
            Scenario::MixedShift => mixed_shift(&mut rng, n_requests),
            Scenario::SwitchChurn => switch_churn(&mut rng, n_requests),
            Scenario::ElasticTiers => elastic_tiers(&mut rng, n_requests),
            Scenario::SharedPrefix => shared_prefix(&mut rng, n_requests),
        }
    }
}

impl fmt::Display for Scenario {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

impl std::str::FromStr for Scenario {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Scenario::ALL
            .into_iter()
            .find(|sc| sc.label() == s)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown scenario '{s}' (diurnal|poisson_burst|long_context_wave|priority_storm|mixed_shift|switch_churn|elastic_tiers|shared_prefix)"
                )
            })
    }
}

fn req(
    id: u64,
    arrival: f64,
    prompt_len: usize,
    output_len: usize,
    priority: Priority,
) -> Request {
    Request {
        id,
        arrival,
        prompt_len,
        output_len,
        priority,
        tp_demand: None,
        prefix_family: None,
    }
}

fn diurnal(rng: &mut Rng, n: usize) -> Vec<Request> {
    const PERIOD_S: f64 = 240.0;
    const MID_RPS: f64 = 7.0;
    const AMP: f64 = 0.8;
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    for id in 0..n as u64 {
        let rate = (MID_RPS * (1.0 + AMP * (2.0 * std::f64::consts::PI * t / PERIOD_S).sin()))
            .max(0.3);
        t += rng.exp(rate);
        let long = rng.bool(0.06);
        let prompt = if long {
            rng.range_usize(LONG_CTX_RANGE.0, LONG_CTX_RANGE.1)
        } else {
            rng.range_usize(128, 4000)
        };
        let pri = if rng.bool(0.02) { Priority::High } else { Priority::Normal };
        out.push(req(id, t, prompt, rng.range_usize(64, 512), pri));
    }
    out
}

fn poisson_burst(rng: &mut Rng, n: usize) -> Vec<Request> {
    const BASE_RPS: f64 = 2.5;
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    let mut next_burst = rng.uniform(40.0, 120.0);
    let mut burst_end = 0.0f64;
    let mut burst_rate = 0.0f64;
    for id in 0..n as u64 {
        while t >= next_burst {
            burst_end = next_burst + rng.uniform(8.0, 15.0);
            burst_rate = rng.uniform(25.0, 35.0);
            next_burst = burst_end + rng.uniform(60.0, 140.0);
        }
        let rate = if t < burst_end { burst_rate } else { BASE_RPS };
        t += rng.exp(rate);
        let long = rng.bool(0.04);
        let prompt = if long {
            rng.range_usize(LONG_CTX_RANGE.0, LONG_CTX_RANGE.1)
        } else {
            rng.range_usize(128, 4000)
        };
        out.push(req(id, t, prompt, rng.range_usize(64, 512), Priority::Normal));
    }
    out
}

fn long_context_wave(rng: &mut Rng, n: usize) -> Vec<Request> {
    const RPS: f64 = 4.0;
    const WAVE_PERIOD_S: f64 = 180.0;
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    for id in 0..n as u64 {
        t += rng.exp(RPS);
        // Long-context probability oscillates 0 -> 0.5 -> 0 per period.
        let p_long =
            0.25 * (1.0 - (2.0 * std::f64::consts::PI * t / WAVE_PERIOD_S).cos());
        let long = rng.bool(p_long);
        let (prompt, output) = if long {
            (
                rng.range_usize(LONG_CTX_RANGE.0, LONG_CTX_RANGE.1),
                rng.range_usize(64, 256),
            )
        } else {
            (rng.range_usize(128, 4000), rng.range_usize(64, 512))
        };
        out.push(req(id, t, prompt, output, Priority::Normal));
    }
    out
}

fn priority_storm(rng: &mut Rng, n: usize) -> Vec<Request> {
    const BASE_RPS: f64 = 4.0;
    const STORM_EXTRA_RPS: f64 = 12.0;
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    let mut next_storm = rng.uniform(60.0, 150.0);
    let mut storm_end = 0.0f64;
    for id in 0..n as u64 {
        while t >= next_storm {
            storm_end = next_storm + rng.uniform(10.0, 20.0);
            next_storm = storm_end + rng.uniform(90.0, 180.0);
        }
        let in_storm = t < storm_end;
        let rate = if in_storm { BASE_RPS + STORM_EXTRA_RPS } else { BASE_RPS };
        t += rng.exp(rate);
        // During a storm, the extra traffic is the high-priority flood.
        let p_high = if in_storm {
            STORM_EXTRA_RPS / (BASE_RPS + STORM_EXTRA_RPS)
        } else {
            0.02
        };
        let pri = if rng.bool(p_high) { Priority::High } else { Priority::Normal };
        out.push(req(
            id,
            t,
            rng.range_usize(128, 4000),
            rng.range_usize(64, 512),
            pri,
        ));
    }
    out
}

fn mixed_shift(rng: &mut Rng, n: usize) -> Vec<Request> {
    const REGIME_S: f64 = 60.0;
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    for id in 0..n as u64 {
        let regime = ((t / REGIME_S) as usize) % 3;
        let rate = match regime {
            0 => 6.0,  // chat
            1 => 5.0,  // ingest
            _ => 10.0, // mixed
        };
        t += rng.exp(rate);
        let (prompt, output, long_frac) = match ((t / REGIME_S) as usize) % 3 {
            // Chat: short prompts, long generations.
            0 => (rng.range_usize(64, 512), rng.range_usize(256, 512), 0.0),
            // Ingest/summarize: long prompts, terse outputs.
            1 => (rng.range_usize(2500, 4000), rng.range_usize(32, 64), 0.0),
            // Mixed with a long-context tail.
            _ => (rng.range_usize(128, 4000), rng.range_usize(64, 512), 0.10),
        };
        let prompt = if long_frac > 0.0 && rng.bool(long_frac) {
            rng.range_usize(LONG_CTX_RANGE.0, LONG_CTX_RANGE.1)
        } else {
            prompt
        };
        out.push(req(id, t, prompt, output, Priority::Normal));
    }
    out
}

fn switch_churn(rng: &mut Rng, n: usize) -> Vec<Request> {
    // Short alternating phases so even small traces (the differential
    // harness runs 150-request slices) see several full cycles: an elastic
    // burst (8 r/s of short chat traffic whose decodes outlive the phase)
    // immediately followed by a long-context phase (3 r/s, half of it above
    // single-engine KV capacity → memory-driven TP merges while the elastic
    // residents are still live).  A slice of the long phase's *short*
    // traffic carries explicit `tp_demand`, so flips also happen with small
    // KV in flight.
    const PHASE_S: f64 = 8.0;
    const ELASTIC_RPS: f64 = 8.0;
    const LONG_PHASE_RPS: f64 = 3.0;
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    for id in 0..n as u64 {
        let elastic_phase = ((t / PHASE_S) as usize) % 2 == 0;
        let rate = if elastic_phase { ELASTIC_RPS } else { LONG_PHASE_RPS };
        t += rng.exp(rate);
        // Classify by the phase the request actually lands in.
        let landed_elastic = ((t / PHASE_S) as usize) % 2 == 0;
        if landed_elastic {
            out.push(req(
                id,
                t,
                rng.range_usize(128, 4000),
                rng.range_usize(64, 512),
                Priority::Normal,
            ));
        } else if rng.bool(0.5) {
            // Long-context TP demand (memory-driven merge).
            out.push(req(
                id,
                t,
                rng.range_usize(LONG_CTX_RANGE.0, LONG_CTX_RANGE.1),
                rng.range_usize(64, 256),
                Priority::Normal,
            ));
        } else {
            // Short long-phase traffic; a slice demands TP explicitly.
            let mut r = req(
                id,
                t,
                rng.range_usize(128, 4000),
                rng.range_usize(64, 512),
                Priority::Normal,
            );
            if rng.bool(0.25) {
                r.tp_demand = Some(*rng.choose(&[2usize, 4]));
            }
            out.push(r);
        }
    }
    out
}

fn elastic_tiers(rng: &mut Rng, n: usize) -> Vec<Request> {
    // Three tiers, all live at once; the dominant tier rotates per phase so
    // the scheduler sees every admission constraint simultaneously and the
    // dominant pressure keeps shifting: 0 = elastic-heavy (bursty DP bulk),
    // 1 = demand-heavy (explicit TP clients), 2 = priority-heavy
    // (interactive flood over the bulk).
    const PHASE_S: f64 = 20.0;
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    for id in 0..n as u64 {
        let phase = ((t / PHASE_S) as usize) % 3;
        let rate = match phase {
            0 => 10.0, // elastic burst
            1 => 4.0,  // steady latency-tier load
            _ => 7.0,  // priority storm over the bulk
        };
        t += rng.exp(rate);
        // Classify by the phase the request actually lands in.
        let landed = ((t / PHASE_S) as usize) % 3;
        let (p_demand, p_high) = match landed {
            0 => (0.05, 0.02),
            1 => (0.45, 0.05),
            _ => (0.05, 0.45),
        };
        let roll = rng.uniform(0.0, 1.0);
        if roll < p_demand {
            // Latency-strict tier: short work, explicit TP width.
            let mut r = req(
                id,
                t,
                rng.range_usize(128, 2000),
                rng.range_usize(32, 256),
                Priority::Normal,
            );
            r.tp_demand = Some(*rng.choose(&[2usize, 4]));
            out.push(r);
        } else if roll < p_demand + p_high {
            // Interactive priority tier: chat-shaped.
            out.push(req(
                id,
                t,
                rng.range_usize(64, 1000),
                rng.range_usize(128, 512),
                Priority::High,
            ));
        } else {
            // Elastic bulk, with a thin long-context tail so the memory
            // tier is exercised too.
            let prompt = if rng.bool(0.03) {
                rng.range_usize(LONG_CTX_RANGE.0, LONG_CTX_RANGE.1)
            } else {
                rng.range_usize(128, 4000)
            };
            out.push(req(id, t, prompt, rng.range_usize(64, 512), Priority::Normal));
        }
    }
    out
}

fn shared_prefix(rng: &mut Rng, n: usize) -> Vec<Request> {
    // Steady Poisson arrivals where most requests open with one of a
    // handful of long shared prefixes — the SGLang-style system-prompt /
    // few-shot workload the prefix cache exists for.  Family shapes are
    // drawn once per trace (deterministic in the whitened seed); every
    // member's prompt is strictly longer than its family prefix so there
    // is always a per-request tail to prefill and decode from.
    const RPS: f64 = 6.0;
    const N_FAMILIES: usize = 6;
    const P_FAMILY: f64 = 0.8;
    let prefixes: Vec<usize> =
        (0..N_FAMILIES).map(|_| rng.range_usize(512, 2500)).collect();
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    for id in 0..n as u64 {
        t += rng.exp(RPS);
        if rng.bool(P_FAMILY) {
            let fid = rng.range_usize(0, N_FAMILIES - 1);
            let plen = prefixes[fid];
            let mut r = req(
                id,
                t,
                plen + rng.range_usize(32, 1200),
                rng.range_usize(64, 512),
                Priority::Normal,
            );
            r.prefix_family = Some((fid as u64, plen));
            out.push(r);
        } else {
            out.push(req(
                id,
                t,
                rng.range_usize(128, 4000),
                rng.range_usize(64, 512),
                Priority::Normal,
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::{from_csv, to_csv, validate};
    use super::*;

    #[test]
    fn labels_round_trip_through_fromstr() {
        for sc in Scenario::ALL {
            let parsed: Scenario = sc.label().parse().unwrap();
            assert_eq!(parsed, sc);
        }
        assert!("nope".parse::<Scenario>().is_err());
    }

    #[test]
    fn deterministic_per_seed_and_distinct_across_scenarios() {
        for sc in Scenario::ALL {
            let a = sc.generate(7, 300);
            let b = sc.generate(7, 300);
            assert_eq!(a.len(), 300);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.arrival, y.arrival, "{sc}");
                assert_eq!(x.prompt_len, y.prompt_len, "{sc}");
            }
        }
        let d = Scenario::Diurnal.generate(7, 50);
        let p = Scenario::PoissonBurst.generate(7, 50);
        assert!(d.iter().zip(&p).any(|(a, b)| a.arrival != b.arrival));
    }

    #[test]
    fn arrivals_monotone_and_valid_for_every_scenario() {
        for sc in Scenario::ALL {
            let reqs = sc.generate(3, 500);
            validate(&reqs).unwrap();
            let mut last = 0.0;
            for r in &reqs {
                assert!(r.arrival >= last, "{sc}: arrivals must be monotone");
                last = r.arrival;
                assert!(r.prompt_len >= 1 && r.output_len >= 1, "{sc}");
            }
        }
    }

    #[test]
    fn csv_round_trip_preserves_every_scenario() {
        for sc in Scenario::ALL {
            let reqs = sc.generate(11, 200);
            let parsed = from_csv(&to_csv(&reqs)).unwrap();
            assert_eq!(parsed.len(), reqs.len(), "{sc}");
            for (a, b) in reqs.iter().zip(&parsed) {
                assert_eq!(a.id, b.id);
                assert!((a.arrival - b.arrival).abs() < 1e-5, "{sc}");
                assert_eq!(a.prompt_len, b.prompt_len, "{sc}");
                assert_eq!(a.output_len, b.output_len, "{sc}");
                assert_eq!(a.priority, b.priority, "{sc}");
                assert_eq!(a.tp_demand, b.tp_demand, "{sc}");
                assert_eq!(a.prefix_family, b.prefix_family, "{sc}");
            }
        }
    }

    #[test]
    fn shared_prefix_clusters_families_and_leaves_tails() {
        let reqs = Scenario::SharedPrefix.generate(8, 3000);
        let fam = reqs.iter().filter(|r| r.prefix_family.is_some()).count();
        let frac = fam as f64 / reqs.len() as f64;
        assert!((0.7..0.9).contains(&frac), "family frac={frac}");
        // Family shapes are coherent: a family id always carries the same
        // prefix length, the prompt is strictly longer than the prefix
        // (there is always a per-request tail), and several distinct
        // families are live so the cache sees forks, not one chain.
        let mut shapes = std::collections::BTreeMap::new();
        for r in &reqs {
            if let Some((fid, plen)) = r.prefix_family {
                assert!(plen >= 512 && r.prompt_len > plen, "{fid}: plen={plen}");
                assert_eq!(*shapes.entry(fid).or_insert(plen), plen, "fid {fid}");
            }
        }
        assert!(shapes.len() >= 3, "only {} families", shapes.len());
        // Every family is genuinely shared (many members each).
        for (fid, _) in &shapes {
            let members = reqs
                .iter()
                .filter(|r| r.prefix_family.map(|(f, _)| f) == Some(*fid))
                .count();
            assert!(members > 20, "family {fid} has only {members} members");
        }
    }

    #[test]
    fn diurnal_rate_actually_swings() {
        let reqs = Scenario::Diurnal.generate(1, 4000);
        // Compare arrival density where sin(phase) is high vs low.
        let phase = |t: f64| (2.0 * std::f64::consts::PI * t / 240.0).sin();
        let peak = reqs.iter().filter(|r| phase(r.arrival) > 0.5).count();
        let trough = reqs.iter().filter(|r| phase(r.arrival) < -0.5).count();
        assert!(
            peak as f64 > 2.0 * trough as f64,
            "peak={peak} trough={trough}"
        );
    }

    #[test]
    fn poisson_burst_has_dense_windows() {
        let reqs = Scenario::PoissonBurst.generate(2, 3000);
        let span = reqs.last().unwrap().arrival;
        let n_buckets = (span / 10.0).ceil() as usize + 1;
        let mut buckets = vec![0usize; n_buckets];
        for r in &reqs {
            buckets[(r.arrival / 10.0) as usize] += 1;
        }
        let max = *buckets.iter().max().unwrap() as f64;
        let mean = reqs.len() as f64 / n_buckets as f64;
        assert!(max > 2.5 * mean, "max={max} mean={mean}");
    }

    #[test]
    fn long_context_arrives_in_waves() {
        let reqs = Scenario::LongContextWave.generate(3, 3000);
        let wave = |t: f64| 0.25 * (1.0 - (2.0 * std::f64::consts::PI * t / 180.0).cos());
        let longs: Vec<f64> = reqs
            .iter()
            .filter(|r| r.prompt_len >= LONG_CTX_RANGE.0)
            .map(|r| wave(r.arrival))
            .collect();
        let shorts: Vec<f64> = reqs
            .iter()
            .filter(|r| r.prompt_len < LONG_CTX_RANGE.0)
            .map(|r| wave(r.arrival))
            .collect();
        let frac = longs.len() as f64 / reqs.len() as f64;
        assert!((0.08..0.45).contains(&frac), "long frac={frac}");
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        // Longs concentrate at wave crests.
        assert!(
            mean(&longs) > mean(&shorts) + 0.05,
            "longs={} shorts={}",
            mean(&longs),
            mean(&shorts)
        );
    }

    #[test]
    fn priority_storms_cluster_high_priority() {
        let reqs = Scenario::PriorityStorm.generate(4, 3000);
        let span = reqs.last().unwrap().arrival;
        let n_buckets = (span / 15.0).ceil() as usize + 1;
        let mut high = vec![0usize; n_buckets];
        let mut all = vec![0usize; n_buckets];
        for r in &reqs {
            let b = (r.arrival / 15.0) as usize;
            all[b] += 1;
            if r.priority == Priority::High {
                high[b] += 1;
            }
        }
        let overall =
            reqs.iter().filter(|r| r.priority == Priority::High).count() as f64 / reqs.len() as f64;
        assert!((0.05..0.6).contains(&overall), "overall high frac={overall}");
        let max_frac = high
            .iter()
            .zip(&all)
            .filter(|(_, &a)| a >= 20)
            .map(|(&h, &a)| h as f64 / a as f64)
            .fold(0.0f64, f64::max);
        assert!(
            max_frac > 2.0 * overall,
            "max storm frac={max_frac} overall={overall}"
        );
    }

    #[test]
    fn switch_churn_alternates_elastic_and_tp_pressure() {
        let reqs = Scenario::SwitchChurn.generate(6, 3000);
        let elastic_phase = |t: f64| ((t / 8.0) as usize) % 2 == 0;
        // Long-context demand lives (exclusively) in the odd phases.
        let longs_elastic = reqs
            .iter()
            .filter(|r| r.prompt_len >= LONG_CTX_RANGE.0 && elastic_phase(r.arrival))
            .count();
        let longs_tp = reqs
            .iter()
            .filter(|r| r.prompt_len >= LONG_CTX_RANGE.0 && !elastic_phase(r.arrival))
            .count();
        assert_eq!(longs_elastic, 0, "elastic phases must stay elastic");
        assert!(longs_tp > 20, "long-context pressure missing ({longs_tp})");
        // Elastic phases are the bursts: clearly denser arrivals.
        let span = reqs.last().unwrap().arrival;
        let n_phases = (span / 8.0).ceil() as usize + 1;
        let (mut elastic_n, mut tp_n, mut elastic_ph, mut tp_ph) = (0usize, 0usize, 0usize, 0usize);
        for ph in 0..n_phases {
            let lo = ph as f64 * 8.0;
            let cnt = reqs
                .iter()
                .filter(|r| r.arrival >= lo && r.arrival < lo + 8.0)
                .count();
            if ph % 2 == 0 {
                elastic_n += cnt;
                elastic_ph += 1;
            } else {
                tp_n += cnt;
                tp_ph += 1;
            }
        }
        let elastic_rate = elastic_n as f64 / elastic_ph as f64;
        let tp_rate = tp_n as f64 / tp_ph.max(1) as f64;
        assert!(
            elastic_rate > 1.8 * tp_rate,
            "elastic {elastic_rate} vs long-phase {tp_rate}"
        );
        // Explicit TP demand present, confined to the long phases, and a
        // minority of the trace.
        let demands = reqs.iter().filter(|r| r.tp_demand.is_some()).count();
        assert!(demands > 10, "no explicit TP demand generated");
        assert!(demands < reqs.len() / 4);
        assert!(reqs
            .iter()
            .filter(|r| r.tp_demand.is_some())
            .all(|r| !elastic_phase(r.arrival)));
    }

    #[test]
    fn elastic_tiers_keeps_every_tier_live_and_rotates_dominance() {
        let reqs = Scenario::ElasticTiers.generate(9, 3000);
        let phase = |t: f64| ((t / 20.0) as usize) % 3;
        // All three tiers are present overall.
        let demands = reqs.iter().filter(|r| r.tp_demand.is_some()).count();
        let highs = reqs.iter().filter(|r| r.priority == Priority::High).count();
        let elastic = reqs
            .iter()
            .filter(|r| r.tp_demand.is_none() && r.priority == Priority::Normal)
            .count();
        assert!(demands > 50, "latency tier missing ({demands})");
        assert!(highs > 50, "priority tier missing ({highs})");
        assert!(elastic > reqs.len() / 3, "elastic bulk missing ({elastic})");
        // Dominance rotates: demand concentrates in phase 1, priority in
        // phase 2, relative to the other phases.
        let frac = |pred: &dyn Fn(&Request) -> bool, k: usize| {
            let in_phase: Vec<&Request> =
                reqs.iter().filter(|r| phase(r.arrival) == k).collect();
            in_phase.iter().filter(|r| pred(r)).count() as f64 / in_phase.len().max(1) as f64
        };
        let is_demand = |r: &Request| r.tp_demand.is_some();
        let is_high = |r: &Request| r.priority == Priority::High;
        assert!(
            frac(&is_demand, 1) > 2.0 * frac(&is_demand, 0),
            "demand tier never dominates"
        );
        assert!(
            frac(&is_high, 2) > 2.0 * frac(&is_high, 0),
            "priority tier never dominates"
        );
        // The elastic phase is the burst (densest arrivals).
        let span = reqs.last().unwrap().arrival;
        let mut counts = [0usize; 3];
        let mut phases = [0usize; 3];
        let n_phases = (span / 20.0).ceil() as usize + 1;
        for ph in 0..n_phases {
            let lo = ph as f64 * 20.0;
            counts[ph % 3] += reqs
                .iter()
                .filter(|r| r.arrival >= lo && r.arrival < lo + 20.0)
                .count();
            phases[ph % 3] += 1;
        }
        let rate = |k: usize| counts[k] as f64 / phases[k].max(1) as f64;
        assert!(rate(0) > 1.5 * rate(1), "elastic burst missing");
    }

    #[test]
    fn mixed_shift_changes_the_mix_between_regimes() {
        let reqs = Scenario::MixedShift.generate(5, 3000);
        let regime = |t: f64| ((t / 60.0) as usize) % 3;
        let mean_prompt = |k: usize| {
            let v: Vec<usize> = reqs
                .iter()
                .filter(|r| regime(r.arrival) == k && r.prompt_len < LONG_CTX_RANGE.0)
                .map(|r| r.prompt_len)
                .collect();
            v.iter().sum::<usize>() as f64 / v.len() as f64
        };
        let chat = mean_prompt(0);
        let ingest = mean_prompt(1);
        assert!(ingest > 3.0 * chat, "chat={chat} ingest={ingest}");
        let mean_out = |k: usize| {
            let v: Vec<usize> = reqs
                .iter()
                .filter(|r| regime(r.arrival) == k)
                .map(|r| r.output_len)
                .collect();
            v.iter().sum::<usize>() as f64 / v.len() as f64
        };
        assert!(mean_out(0) > 2.0 * mean_out(1));
    }
}
