//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) described
//! by `manifest.json`, compiles them once per engine, and provides a typed
//! execute path that follows the manifest's argument/output descriptors
//! mechanically (the contract validated end-to-end by
//! `python/tests/test_model.py` + `orchestrator.py`).
//!
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax>=0.5 serialized protos; the text parser reassigns instruction ids).
//!
//! Weights are uploaded to device buffers once per engine at startup
//! (`xla::PjRtBuffer`) — the Model Weights Manager invariant: loaded once,
//! never moved; TP sharding happens inside the kernels from the `rank`
//! scalar.  KV pools are host-resident (`Vec<f32>`) because the PJRT C API
//! returns results as one fused tuple literal (see rust/tests/pjrt_smoke.rs)
//! — pools are uploaded per step and the kernels return only the *new* KV
//! rows, which the KV Cache Adaptor scatters back host-side.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::json::Value;
use crate::model::{ModelCfg, StaticShapes, WeightEntry, WeightStore};

/// One argument descriptor from the manifest.
#[derive(Clone, Debug, PartialEq)]
pub enum ArgKind {
    /// Per-step host value (tokens, tables, slots, rank, ...).
    Dyn { name: String, shape: Vec<usize>, is_f32: bool },
    /// Concrete weight tensor (fused DP artifacts).
    Weight { role: String },
    /// Per-layer weight by role; the engine substitutes the running layer.
    WeightRole { role: String },
    /// This layer's K/V pool (layer index, or -1 = current layer).
    KPool { layer: i64 },
    VPool { layer: i64 },
}

/// One output descriptor.
#[derive(Clone, Debug, PartialEq)]
pub enum OutKind {
    Logits { shape: Vec<usize> },
    Partial { shape: Vec<usize> },
    KNew { layer: i64, shape: Vec<usize> },
    VNew { layer: i64, shape: Vec<usize> },
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub args: Vec<ArgKind>,
    pub outputs: Vec<OutKind>,
    pub tp: usize,
    pub phase: String,
}

/// Parsed manifest for one model.
#[derive(Clone)]
pub struct ModelManifest {
    pub cfg: ModelCfg,
    pub weights_bin: PathBuf,
    pub weight_entries: Vec<WeightEntry>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

/// The whole `artifacts/` directory, parsed.
#[derive(Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub shapes: StaticShapes,
    pub tp_degrees: Vec<usize>,
    pub models: BTreeMap<String, ModelManifest>,
}

fn parse_shape(v: &Value) -> Result<Vec<usize>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("shape not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
        .collect()
}

fn parse_arg(v: &Value) -> Result<ArgKind> {
    Ok(match v.str_field("kind")? {
        "dyn" => ArgKind::Dyn {
            name: v.str_field("name")?.to_string(),
            shape: parse_shape(v.field("shape")?)?,
            is_f32: v.str_field("dtype")? == "f32",
        },
        "weight" => ArgKind::Weight { role: v.str_field("role")?.to_string() },
        "weight_role" => ArgKind::WeightRole { role: v.str_field("role")?.to_string() },
        "kpool" => ArgKind::KPool { layer: v.field("layer")?.as_i64().unwrap_or(-1) },
        "vpool" => ArgKind::VPool { layer: v.field("layer")?.as_i64().unwrap_or(-1) },
        k => bail!("unknown arg kind '{k}'"),
    })
}

fn parse_out(v: &Value) -> Result<OutKind> {
    let shape = parse_shape(v.field("shape")?)?;
    Ok(match v.str_field("kind")? {
        "logits" => OutKind::Logits { shape },
        "partial" => OutKind::Partial { shape },
        "knew" => OutKind::KNew { layer: v.field("layer")?.as_i64().unwrap_or(-1), shape },
        "vnew" => OutKind::VNew { layer: v.field("layer")?.as_i64().unwrap_or(-1), shape },
        k => bail!("unknown output kind '{k}'"),
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
            format!("reading {}/manifest.json — run `make artifacts`", dir.display())
        })?;
        let v = Value::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let st = v.field("static")?;
        let shapes = StaticShapes {
            b_dec: st.usize_field("b_dec")?,
            c_prefill: st.usize_field("c_prefill")?,
        };
        let tp_degrees = st
            .field("tp_degrees")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|d| d.as_usize())
            .collect();
        let mut models = BTreeMap::new();
        for (mname, mv) in v.field("models")?.as_obj().into_iter().flatten() {
            let cfg = ModelCfg::from_json(mv.field("cfg")?)?;
            let weight_entries = mv
                .field("weights")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|e| {
                    Ok(WeightEntry {
                        name: e.str_field("name")?.to_string(),
                        shape: parse_shape(e.field("shape")?)?,
                        offset_elems: e.usize_field("offset_elems")?,
                        n_elems: e.usize_field("n_elems")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let mut artifacts = BTreeMap::new();
            for (aname, av) in mv.field("artifacts")?.as_obj().into_iter().flatten() {
                let args = av
                    .field("args")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(parse_arg)
                    .collect::<Result<Vec<_>>>()?;
                let outputs = av
                    .field("outputs")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(parse_out)
                    .collect::<Result<Vec<_>>>()?;
                artifacts.insert(
                    aname.clone(),
                    ArtifactSpec {
                        name: aname.clone(),
                        path: dir.join(av.str_field("path")?),
                        args,
                        outputs,
                        tp: av.usize_field("tp")?,
                        phase: av.str_field("phase")?.to_string(),
                    },
                );
            }
            models.insert(
                mname.clone(),
                ModelManifest {
                    cfg,
                    weights_bin: dir.join(mv.str_field("weights_bin")?),
                    weight_entries,
                    artifacts,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), shapes, tp_degrees, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models.get(name).ok_or_else(|| {
            anyhow::anyhow!("model '{name}' not in manifest ({:?})", self.models.keys())
        })
    }
}

impl ModelManifest {
    pub fn load_weights(&self) -> Result<WeightStore> {
        WeightStore::load(self.cfg.clone(), self.weight_entries.clone(), &self.weights_bin)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))
    }
}

/// Per-step dynamic inputs, keyed by the manifest's `dyn` names.
#[derive(Default, Debug)]
pub struct DynInputs {
    i32s: BTreeMap<String, Vec<i32>>,
    f32s: BTreeMap<String, Vec<f32>>,
}

impl DynInputs {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn i32(mut self, name: &str, v: Vec<i32>) -> Self {
        self.i32s.insert(name.to_string(), v);
        self
    }

    pub fn f32(mut self, name: &str, v: Vec<f32>) -> Self {
        self.f32s.insert(name.to_string(), v);
        self
    }

    /// In-place accessor for arena reuse: returns the named i32 buffer,
    /// creating it empty on first use.  Hot paths clear + refill it every
    /// step so the map allocates only during warmup.
    pub fn i32_mut(&mut self, name: &str) -> &mut Vec<i32> {
        if !self.i32s.contains_key(name) {
            self.i32s.insert(name.to_string(), Vec::new());
        }
        self.i32s.get_mut(name).unwrap()
    }

    /// In-place accessor for arena reuse (f32 variant of [`Self::i32_mut`]).
    pub fn f32_mut(&mut self, name: &str) -> &mut Vec<f32> {
        if !self.f32s.contains_key(name) {
            self.f32s.insert(name.to_string(), Vec::new());
        }
        self.f32s.get_mut(name).unwrap()
    }
}

/// Typed outputs of one step.
#[derive(Debug, Default)]
pub struct StepOutputs {
    /// Logits or partial activation (always the first output).
    pub primary: Vec<f32>,
    pub primary_shape: Vec<usize>,
    /// (layer, k_new, v_new) triples; layer == -1 for per-layer artifacts.
    pub kv_new: Vec<(i64, Vec<f32>, Vec<f32>)>,
}

/// Device-resident per-engine weight buffers, uploaded exactly once
/// (zero-copy thereafter: TP activates shard views via the rank scalar).
#[cfg(feature = "pjrt")]
pub struct EngineBuffers {
    by_name: BTreeMap<String, xla::PjRtBuffer>,
}

#[cfg(feature = "pjrt")]
impl EngineBuffers {
    pub fn upload(client: &xla::PjRtClient, ws: &WeightStore) -> Result<Self> {
        let mut by_name = BTreeMap::new();
        for e in &ws.entries {
            let data = ws.tensor(&e.name)?;
            let buf = client
                .buffer_from_host_buffer(data, &e.shape, None)
                .with_context(|| format!("uploading weight {}", e.name))?;
            by_name.insert(e.name.clone(), buf);
        }
        Ok(EngineBuffers { by_name })
    }

    pub fn get(&self, name: &str) -> Result<&xla::PjRtBuffer> {
        self.by_name
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no device buffer for weight '{name}'"))
    }
}

/// The runtime for one engine: PJRT client + compile + typed execute.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    pub client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    pub fn compile(&self, spec: &ArtifactSpec) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            spec.path
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", spec.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", spec.name))
    }

    /// Execute one artifact step.  `layer` resolves the WeightRole prefix
    /// (`l{layer}.`) and which pools `-1` layer markers refer to; `k_pools`
    /// / `v_pools` are the engine's host pools indexed by layer.
    pub fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        spec: &ArtifactSpec,
        bufs: &EngineBuffers,
        dyns: &DynInputs,
        layer: usize,
        k_pools: &[Vec<f32>],
        v_pools: &[Vec<f32>],
    ) -> Result<StepOutputs> {
        // Assemble positional args as device buffers: weights are resident,
        // dyns + pools are uploaded per call.
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        let mut order: Vec<Result<usize, usize>> = Vec::new(); // Ok=owned idx, Err=weight idx
        let mut weight_refs: Vec<&xla::PjRtBuffer> = Vec::new();
        for a in &spec.args {
            match a {
                ArgKind::Dyn { name, shape, is_f32 } => {
                    let n: usize = shape.iter().product();
                    let buf = if *is_f32 {
                        let v = dyns
                            .f32s
                            .get(name)
                            .ok_or_else(|| anyhow::anyhow!("missing f32 dyn '{name}'"))?;
                        anyhow::ensure!(v.len() == n, "dyn '{name}': {} != {n}", v.len());
                        self.client.buffer_from_host_buffer(v, shape, None)?
                    } else {
                        let v = dyns
                            .i32s
                            .get(name)
                            .ok_or_else(|| anyhow::anyhow!("missing i32 dyn '{name}'"))?;
                        anyhow::ensure!(v.len() == n, "dyn '{name}': {} != {n}", v.len());
                        self.client.buffer_from_host_buffer(v, shape, None)?
                    };
                    order.push(Ok(owned.len()));
                    owned.push(buf);
                }
                ArgKind::Weight { role } => {
                    order.push(Err(weight_refs.len()));
                    weight_refs.push(bufs.get(role)?);
                }
                ArgKind::WeightRole { role } => {
                    order.push(Err(weight_refs.len()));
                    weight_refs.push(bufs.get(&format!("l{layer}.{role}"))?);
                }
                ArgKind::KPool { layer: l } | ArgKind::VPool { layer: l } => {
                    let li = if *l < 0 { layer } else { *l as usize };
                    let pools = if matches!(a, ArgKind::KPool { .. }) { k_pools } else { v_pools };
                    let pool = pools
                        .get(li)
                        .ok_or_else(|| anyhow::anyhow!("missing pool for layer {li}"))?;
                    let buf = self.client.buffer_from_host_buffer(pool, &[pool.len()], None)?;
                    order.push(Ok(owned.len()));
                    owned.push(buf);
                }
            }
        }
        let args: Vec<&xla::PjRtBuffer> = order
            .iter()
            .map(|o| match o {
                Ok(i) => &owned[*i],
                Err(i) => weight_refs[*i],
            })
            .collect();

        let out = exe.execute_b(&args)?;
        let lit = out[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        anyhow::ensure!(
            parts.len() == spec.outputs.len(),
            "artifact {}: {} outputs vs manifest {}",
            spec.name,
            parts.len(),
            spec.outputs.len()
        );

        let mut res = StepOutputs::default();
        let mut k_tmp: BTreeMap<i64, Vec<f32>> = BTreeMap::new();
        for (o, lit) in spec.outputs.iter().zip(parts.into_iter()) {
            let v = lit.to_vec::<f32>()?;
            match o {
                OutKind::Logits { shape } | OutKind::Partial { shape } => {
                    res.primary = v;
                    res.primary_shape = shape.clone();
                }
                OutKind::KNew { layer: l, .. } => {
                    k_tmp.insert(*l, v);
                }
                OutKind::VNew { layer: l, .. } => {
                    let k = k_tmp
                        .remove(l)
                        .ok_or_else(|| anyhow::anyhow!("v_new before k_new for layer {l}"))?;
                    res.kv_new.push((*l, k, v));
                }
            }
        }
        Ok(res)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_parses_and_is_consistent() {
        let dir = art_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.shapes.b_dec > 0 && m.shapes.c_prefill > 0);
        assert!(m.models.contains_key("llama-tiny"));
        let lm = m.model("llama-tiny").unwrap();
        let a = lm.artifact("dp_decode").unwrap();
        match &a.args[0] {
            ArgKind::Dyn { name, shape, is_f32 } => {
                assert_eq!(name, "tokens");
                assert_eq!(shape, &vec![m.shapes.b_dec]);
                assert!(!is_f32);
            }
            other => panic!("unexpected first arg {other:?}"),
        }
        // Outputs: logits + (k_new, v_new) per layer.
        assert_eq!(a.outputs.len(), 1 + 2 * lm.cfg.n_layers);
        assert!(matches!(a.outputs[0], OutKind::Logits { .. }));
        for art in lm.artifacts.values() {
            assert!(art.path.exists(), "{} missing", art.path.display());
        }
    }

    #[test]
    fn weights_load_and_match_manifest() {
        let dir = art_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let lm = m.model("llama-tiny").unwrap();
        let ws = lm.load_weights().unwrap();
        assert_eq!(
            ws.total_param_count(),
            lm.weight_entries.iter().map(|e| e.n_elems).sum::<usize>()
        );
        // Norm weights were initialized to 1.0 (aot.make_weights).
        assert!(ws.tensor("final_norm").unwrap().iter().all(|&x| x == 1.0));
    }

    #[test]
    fn unknown_model_and_artifact_error() {
        let dir = art_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model("gpt-5").is_err());
        assert!(m.model("llama-tiny").unwrap().artifact("nope").is_err());
    }
}
