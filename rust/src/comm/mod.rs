//! Communicator Pool (paper §4.3): eagerly-initialized collective groups,
//! activated on demand in O(1), never created on the request's critical path.
//!
//! The paper's data plane is NCCL over NVLink; our engines are OS threads,
//! so the data plane is a shared-memory collective substrate (sense-counting
//! generation protocol over Mutex+Condvar).  The *life cycle* is the paper's:
//!
//!  1. Topology-aware group identification — only physically contiguous,
//!     degree-aligned rank segments are enumerated (for N engines and
//!     degrees P, that's sum_p N/p groups: linear, not exponential).
//!  2. Eager pre-initialization at startup; handles cached in a map keyed by
//!     member ranks.
//!  3. Runtime activation = hash-map lookup.
//!
//! Every collective carries a watchdog timeout: a mismatched membership or
//! ordering bug surfaces as a `CollectiveTimeout` error instead of a hang —
//! this is what makes the scheduler's safe-point protocol *testably*
//! deadlock-free.
//!
//! Hot-path discipline: the internal reduction/gather buffers are owned by
//! the communicator and recycled across rounds (`clear()` + `extend`, never
//! `take`/`clone`), so a warm communicator performs **zero heap allocations
//! per collective**.  `all_gather_into` exposes the same property to
//! callers by writing the flat gathered vector into a caller-provided
//! buffer.
//!
//! Asynchronous completion contract (ISSUE 9, `--overlap`): a collective
//! may be *issued* by the coordinator without its replies being collected
//! in the same scheduling round — the member workers still meet it in
//! lockstep on their own threads, concurrently with commands running on
//! non-member engines.  Two rules make this safe with no changes here:
//! the coordinator sends **at most one** uncollected command per member
//! (the engine channel depth is 2, so a queued reply can never block a
//! worker), and the in-flight transfer is drained at the next safe point
//! *before* any other command — in particular any `SetMode` that would
//! re-enter this pool with a different membership — is sent to a member.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum CommError {
    #[error("collective timed out after {0:?} (membership/ordering mismatch)")]
    CollectiveTimeout(Duration),
    #[error("no pre-initialized group for ranks {0:?} (topology-aware pool only builds contiguous aligned groups)")]
    NoSuchGroup(Vec<usize>),
    #[error("rank {rank} is not a member of group {ranks:?}")]
    NotAMember { rank: usize, ranks: Vec<usize> },
    #[error("scatter payload of {len} elements is not divisible by group size {p}")]
    ScatterShape { len: usize, p: usize },
    #[error("gather contributions have mismatched shapes")]
    GatherShape,
    #[error("collective abandoned: a member slot was reset for rejoin")]
    MemberReset,
}

#[derive(Debug)]
struct Inner {
    arrived: usize,
    generation: u64,
    buf: Vec<f32>,
    result: Vec<f32>,
    gather: Vec<Vec<f32>>,
    /// Set by the completing arrival of a `gather_into` round whose member
    /// contributions disagree in shape; every waiter of that round reads it
    /// and surfaces `CommError::GatherShape` instead of a misaligned result.
    shape_err: bool,
    /// Set by [`Communicator::reset_member`] when a rejoin tears down an
    /// in-flight round (ISSUE 8): the round's surviving waiters wake on
    /// the generation bump and surface `CommError::MemberReset` instead of
    /// reading a result no completed round produced.  Cleared by the first
    /// arrival of the next (fresh) round.
    torn: bool,
}

/// One pre-built communicator (the NCCL process-group analog).
///
/// Lock discipline (ISSUE 6): every collective takes `m` with
/// `unwrap_or_else(|p| p.into_inner())` rather than `unwrap()`.  A peer
/// that panics while holding the rendezvous lock poisons it; cascading
/// that panic into every surviving member would turn one engine fault
/// into a whole-group crash.  The `Inner` state is a counter/buffer
/// rendezvous that the generation protocol re-validates on every pass, so
/// entering a poisoned lock is safe — the *logical* fallout of the dead
/// peer (a member that never arrives) is what the timeout below and the
/// coordinator's watchdog are for.
#[derive(Debug)]
pub struct Communicator {
    pub ranks: Vec<usize>,
    m: Mutex<Inner>,
    cv: Condvar,
    timeout: Duration,
}

impl Communicator {
    fn new(ranks: Vec<usize>, timeout: Duration) -> Self {
        let p = ranks.len();
        Communicator {
            ranks,
            m: Mutex::new(Inner {
                arrived: 0,
                generation: 0,
                buf: Vec::new(),
                result: Vec::new(),
                gather: vec![Vec::new(); p],
                shape_err: false,
                torn: false,
            }),
            cv: Condvar::new(),
            timeout,
        }
    }

    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    fn member_index(&self, rank: usize) -> Result<usize, CommError> {
        self.ranks
            .iter()
            .position(|&r| r == rank)
            .ok_or(CommError::NotAMember {
                rank,
                ranks: self.ranks.clone(),
            })
    }

    /// Sum-all-reduce `data` in place across all members.  Every member must
    /// call with identically-shaped data; the call returns when the reduced
    /// vector is visible to all.
    pub fn all_reduce_sum(&self, rank: usize, data: &mut [f32]) -> Result<(), CommError> {
        self.member_index(rank)?;
        let p = self.size();
        if p == 1 {
            return Ok(()); // singleton group: no-op (DP mode)
        }
        let mut g = self.m.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        if g.arrived == 0 {
            g.torn = false;
            g.buf.clear();
            g.buf.extend_from_slice(data);
        } else {
            debug_assert_eq!(g.buf.len(), data.len(), "mismatched all-reduce shapes");
            for (b, d) in g.buf.iter_mut().zip(data.iter()) {
                *b += *d;
            }
        }
        g.arrived += 1;
        if g.arrived == p {
            // Swap (not take) so both buffers keep their capacity: a warm
            // communicator never allocates on the reduce path.
            std::mem::swap(&mut g.buf, &mut g.result);
            g.arrived = 0;
            g.generation += 1;
            data.copy_from_slice(&g.result);
            self.cv.notify_all();
            Ok(())
        } else {
            let gen0 = g.generation;
            let (g, to) = self
                .cv
                .wait_timeout_while(g, self.timeout, |g| g.generation == gen0)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            if to.timed_out() {
                return Err(CommError::CollectiveTimeout(self.timeout));
            }
            if g.torn {
                return Err(CommError::MemberReset);
            }
            data.copy_from_slice(&g.result);
            Ok(())
        }
    }

    /// Barrier: returns when all members have arrived.
    pub fn barrier(&self, rank: usize) -> Result<(), CommError> {
        self.member_index(rank)?;
        let p = self.size();
        if p == 1 {
            return Ok(());
        }
        let mut g = self.m.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        if g.arrived == 0 {
            g.torn = false;
        }
        g.arrived += 1;
        if g.arrived == p {
            g.arrived = 0;
            g.generation += 1;
            self.cv.notify_all();
            Ok(())
        } else {
            let gen0 = g.generation;
            let (g, to) = self
                .cv
                .wait_timeout_while(g, self.timeout, |g| g.generation == gen0)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            if to.timed_out() {
                return Err(CommError::CollectiveTimeout(self.timeout));
            }
            if g.torn {
                return Err(CommError::MemberReset);
            }
            Ok(())
        }
    }

    /// Broadcast `data` from the group-local root (ranks[0]) to all members.
    pub fn broadcast(&self, rank: usize, data: &mut Vec<f32>) -> Result<(), CommError> {
        let idx = self.member_index(rank)?;
        let p = self.size();
        if p == 1 {
            return Ok(());
        }
        let mut g = self.m.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        if g.arrived == 0 {
            g.torn = false;
        }
        if idx == 0 {
            // Stage into `buf`; only the completing arrival publishes it to
            // `result`.  A next-round root can therefore never clobber a
            // result that a slow waiter of this round has yet to read.
            g.buf.clear();
            g.buf.extend_from_slice(data);
        }
        g.arrived += 1;
        if g.arrived == p {
            std::mem::swap(&mut g.buf, &mut g.result);
            g.arrived = 0;
            g.generation += 1;
            data.clear();
            data.extend_from_slice(&g.result);
            self.cv.notify_all();
            Ok(())
        } else {
            let gen0 = g.generation;
            let (g, to) = self
                .cv
                .wait_timeout_while(g, self.timeout, |g| g.generation == gen0)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            if to.timed_out() {
                return Err(CommError::CollectiveTimeout(self.timeout));
            }
            if g.torn {
                return Err(CommError::MemberReset);
            }
            data.clear();
            data.extend_from_slice(&g.result);
            Ok(())
        }
    }

    /// All-gather into a caller-provided flat buffer: `out` receives every
    /// member's contribution concatenated in member-index order
    /// (`out.len() == p * data.len()`).  All members must contribute
    /// identically-shaped data.  Neither the communicator nor the caller
    /// allocates once warm (`out` is cleared and refilled in place).
    pub fn all_gather_into(
        &self,
        rank: usize,
        data: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<(), CommError> {
        let idx = self.member_index(rank)?;
        let p = self.size();
        if p == 1 {
            out.clear();
            out.extend_from_slice(data);
            return Ok(());
        }
        let mut g = self.m.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        if g.arrived == 0 {
            g.torn = false;
        }
        g.gather[idx].clear();
        g.gather[idx].extend_from_slice(data);
        g.arrived += 1;
        if g.arrived == p {
            g.arrived = 0;
            g.generation += 1;
            let inner = &mut *g;
            inner.result.clear();
            for m in inner.gather.iter() {
                debug_assert_eq!(m.len(), data.len(), "mismatched all-gather shapes");
                inner.result.extend_from_slice(m);
            }
            out.clear();
            out.extend_from_slice(&inner.result);
            self.cv.notify_all();
            Ok(())
        } else {
            let gen0 = g.generation;
            let (g, to) = self
                .cv
                .wait_timeout_while(g, self.timeout, |g| g.generation == gen0)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            if to.timed_out() {
                return Err(CommError::CollectiveTimeout(self.timeout));
            }
            if g.torn {
                return Err(CommError::MemberReset);
            }
            out.clear();
            out.extend_from_slice(&g.result);
            Ok(())
        }
    }

    /// Scatter from `root`: the root contributes `p * chunk` elements and
    /// member `i` (in member-index order) receives elements
    /// `[i*chunk, (i+1)*chunk)` into `out`.  Non-root members pass an empty
    /// `send`.  This is the KV-migration data plane (ISSUE 4): the home
    /// engine distributes the other members' shard slices through the
    /// eagerly-initialized group, so a DP→TP promotion moves KV bytes once
    /// over the interconnect instead of recomputing them.  Buffers recycle:
    /// neither the communicator nor the caller allocates once warm.
    pub fn scatter_into(
        &self,
        rank: usize,
        root: usize,
        send: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<(), CommError> {
        let idx = self.member_index(rank)?;
        let root_idx = self.member_index(root)?;
        let p = self.size();
        if p == 1 {
            out.clear();
            out.extend_from_slice(send);
            return Ok(());
        }
        if rank == root && send.len() % p != 0 {
            // Silent flooring would truncate the tail slice; fail loudly
            // instead (the waiting members surface it as a watchdog timeout,
            // like any other contract violation).
            return Err(CommError::ScatterShape { len: send.len(), p });
        }
        let mut g = self.m.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        if g.arrived == 0 {
            g.torn = false;
        }
        if idx == root_idx {
            // Stage into `buf`; only the completing arrival publishes it to
            // `result` (same protocol as broadcast), so a next-round root can
            // never clobber a result a slow reader has yet to slice.
            g.buf.clear();
            g.buf.extend_from_slice(send);
        }
        g.arrived += 1;
        if g.arrived == p {
            std::mem::swap(&mut g.buf, &mut g.result);
            g.arrived = 0;
            g.generation += 1;
            let chunk = g.result.len() / p;
            out.clear();
            out.extend_from_slice(&g.result[idx * chunk..(idx + 1) * chunk]);
            self.cv.notify_all();
            Ok(())
        } else {
            let gen0 = g.generation;
            let (g, to) = self
                .cv
                .wait_timeout_while(g, self.timeout, |g| g.generation == gen0)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            if to.timed_out() {
                return Err(CommError::CollectiveTimeout(self.timeout));
            }
            if g.torn {
                return Err(CommError::MemberReset);
            }
            let chunk = g.result.len() / p;
            out.clear();
            out.extend_from_slice(&g.result[idx * chunk..(idx + 1) * chunk]);
            Ok(())
        }
    }

    /// Gather to `root`: every member contributes identically-shaped `data`;
    /// the root's `out` receives the concatenation in member-index order
    /// (`p * data.len()` elements) and every other member's `out` is
    /// cleared.  Inverse of [`Self::scatter_into`] — the TP→DP direction of
    /// KV migration, where the DP target collects the shard slices it does
    /// not already hold.  Allocation-free once warm.
    pub fn gather_into(
        &self,
        rank: usize,
        root: usize,
        data: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<(), CommError> {
        let idx = self.member_index(rank)?;
        let root_idx = self.member_index(root)?;
        let p = self.size();
        if p == 1 {
            out.clear();
            out.extend_from_slice(data);
            return Ok(());
        }
        let mut g = self.m.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        if g.arrived == 0 {
            g.torn = false;
        }
        g.gather[idx].clear();
        g.gather[idx].extend_from_slice(data);
        g.arrived += 1;
        if g.arrived == p {
            g.arrived = 0;
            g.generation += 1;
            let inner = &mut *g;
            // Shape agreement is checked loudly (mirroring scatter_into's
            // ScatterShape): a silently shifted concatenation would hand the
            // root misaligned slices with no signal.
            inner.shape_err = inner.gather.iter().any(|m| m.len() != data.len());
            inner.result.clear();
            if !inner.shape_err {
                for m in inner.gather.iter() {
                    inner.result.extend_from_slice(m);
                }
            }
            let failed = inner.shape_err;
            out.clear();
            if !failed && idx == root_idx {
                out.extend_from_slice(&inner.result);
            }
            self.cv.notify_all();
            if failed {
                return Err(CommError::GatherShape);
            }
            Ok(())
        } else {
            let gen0 = g.generation;
            let (g, to) = self
                .cv
                .wait_timeout_while(g, self.timeout, |g| g.generation == gen0)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            if to.timed_out() {
                return Err(CommError::CollectiveTimeout(self.timeout));
            }
            if g.torn {
                return Err(CommError::MemberReset);
            }
            if g.shape_err {
                return Err(CommError::GatherShape);
            }
            out.clear();
            if idx == root_idx {
                out.extend_from_slice(&g.result);
            }
            Ok(())
        }
    }

    /// Re-register a member slot for a rejoining incarnation of `rank`
    /// (ISSUE 8).  If the dead incarnation left a torn round behind (it
    /// arrived and died before completion), the round is abandoned:
    /// `arrived` resets, the generation bumps, and every surviving waiter
    /// wakes with [`CommError::MemberReset`] instead of deadlocking until
    /// its timeout or reading a result no completed round produced.  With
    /// no round in flight this is a no-op — the pre-built group needs no
    /// re-initialization (the paper's eager pool is exactly what makes
    /// rejoin O(1)).
    ///
    /// The lockstep coordinator only calls this at a safe point (no
    /// commands in flight), so a fresh round can never race the torn
    /// round's wake-up.
    pub fn reset_member(&self, rank: usize) -> Result<(), CommError> {
        self.member_index(rank)?;
        if self.size() == 1 {
            return Ok(());
        }
        let mut g = self.m.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        if g.arrived > 0 {
            g.arrived = 0;
            g.torn = true;
            g.generation += 1;
            self.cv.notify_all();
        }
        Ok(())
    }

    /// All-gather, allocating convenience form: every member's contribution,
    /// ordered by member index.  Wrapper over [`Self::all_gather_into`];
    /// prefer that on hot paths.
    pub fn all_gather(&self, rank: usize, data: &[f32]) -> Result<Vec<Vec<f32>>, CommError> {
        let mut flat = Vec::new();
        self.all_gather_into(rank, data, &mut flat)?;
        if data.is_empty() {
            return Ok(vec![Vec::new(); self.size()]);
        }
        Ok(flat.chunks(data.len()).map(|c| c.to_vec()).collect())
    }
}

/// The pool: every topology-valid group, built eagerly at startup.
pub struct CommunicatorPool {
    pub n_engines: usize,
    groups: HashMap<Vec<usize>, Arc<Communicator>>,
}

impl CommunicatorPool {
    /// Enumerate contiguous aligned groups for each supported degree
    /// (paper §4.3.2 step 1) and pre-initialize them (step 2).
    pub fn new(n_engines: usize, degrees: &[usize], timeout: Duration) -> Self {
        let mut groups = HashMap::new();
        for &p in degrees {
            if p == 0 || p > n_engines {
                continue;
            }
            for start in (0..n_engines).step_by(p) {
                if start + p > n_engines {
                    break;
                }
                let ranks: Vec<usize> = (start..start + p).collect();
                groups.insert(ranks.clone(), Arc::new(Communicator::new(ranks, timeout)));
            }
        }
        CommunicatorPool { n_engines, groups }
    }

    /// O(1) activation (paper §4.3.2 step 3 / runtime behavior).
    pub fn get(&self, ranks: &[usize]) -> Result<Arc<Communicator>, CommError> {
        self.groups
            .get(ranks)
            .cloned()
            .ok_or_else(|| CommError::NoSuchGroup(ranks.to_vec()))
    }

    /// The contiguous aligned group of width p containing `rank`.
    pub fn group_of(&self, rank: usize, p: usize) -> Result<Arc<Communicator>, CommError> {
        let start = (rank / p) * p;
        let ranks: Vec<usize> = (start..start + p).collect();
        self.get(&ranks)
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// Rejoin `rank` across every pre-built group containing it (ISSUE 8):
    /// each group's member slot is reset ([`Communicator::reset_member`]),
    /// abandoning any round the dead incarnation tore.  Returns the number
    /// of groups touched.  No group is rebuilt — the eagerly-initialized
    /// pool is generation-protected, so a restarted worker re-registers in
    /// O(groups-of-rank) metadata work.
    pub fn rejoin_member(&self, rank: usize) -> usize {
        let mut n = 0;
        for g in self.groups.values() {
            if g.ranks.contains(&rank) && g.reset_member(rank).is_ok() {
                n += 1;
            }
        }
        n
    }

    /// All group rank-sets (sorted), for introspection/tests.
    pub fn group_list(&self) -> Vec<Vec<usize>> {
        let mut v: Vec<_> = self.groups.keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn pool() -> CommunicatorPool {
        CommunicatorPool::new(8, &[1, 2, 4, 8], Duration::from_secs(2))
    }

    #[test]
    fn topology_enumeration_is_linear() {
        let p = pool();
        // 8 singletons + 4 pairs + 2 quartets + 1 octet = 15 (sum N/p).
        assert_eq!(p.n_groups(), 15);
        assert!(p.get(&[0, 1]).is_ok());
        assert!(p.get(&[2, 3]).is_ok());
        assert!(p.get(&[0, 1, 2, 3]).is_ok());
        // Strided/unaligned combos are intentionally absent (paper: [0,2]
        // and [1,3] are never generated).
        assert_eq!(p.get(&[0, 2]).unwrap_err(), CommError::NoSuchGroup(vec![0, 2]));
        assert!(p.get(&[1, 2]).is_err());
        assert!(p.get(&[1, 2, 3, 4]).is_err());
    }

    #[test]
    fn group_of_alignment() {
        let p = pool();
        assert_eq!(p.group_of(3, 2).unwrap().ranks, vec![2, 3]);
        assert_eq!(p.group_of(5, 4).unwrap().ranks, vec![4, 5, 6, 7]);
        assert_eq!(p.group_of(6, 1).unwrap().ranks, vec![6]);
    }

    #[test]
    fn all_reduce_sums_across_threads() {
        for p in [2usize, 4] {
            let pool = pool();
            let g = pool.get(&(0..p).collect::<Vec<_>>()).unwrap();
            let handles: Vec<_> = (0..p)
                .map(|r| {
                    let g = g.clone();
                    thread::spawn(move || {
                        let mut data = vec![r as f32 + 1.0; 16];
                        g.all_reduce_sum(r, &mut data).unwrap();
                        data
                    })
                })
                .collect();
            let want = (1..=p).sum::<usize>() as f32;
            for h in handles {
                let out = h.join().unwrap();
                assert!(out.iter().all(|&x| x == want), "p={p} out={:?}", &out[..2]);
            }
        }
    }

    #[test]
    fn repeated_all_reduces_keep_generations_straight() {
        let pool = pool();
        let g = pool.get(&[0, 1]).unwrap();
        let handles: Vec<_> = (0..2)
            .map(|r| {
                let g = g.clone();
                thread::spawn(move || {
                    let mut outs = Vec::new();
                    for step in 0..50 {
                        let mut d = vec![(r * 100 + step) as f32];
                        g.all_reduce_sum(r, &mut d).unwrap();
                        outs.push(d[0]);
                    }
                    outs
                })
            })
            .collect();
        let a = handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>();
        for step in 0..50 {
            let want = (step + (100 + step)) as f32;
            assert_eq!(a[0][step], want);
            assert_eq!(a[1][step], want);
        }
    }

    #[test]
    fn broadcast_from_root() {
        let pool = pool();
        let g = pool.get(&[4, 5, 6, 7]).unwrap();
        let handles: Vec<_> = [4usize, 5, 6, 7]
            .into_iter()
            .map(|r| {
                let g = g.clone();
                thread::spawn(move || {
                    let mut d = if r == 4 { vec![9.0, 8.0] } else { vec![0.0, 0.0] };
                    g.broadcast(r, &mut d).unwrap();
                    d
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![9.0, 8.0]);
        }
    }

    #[test]
    fn all_gather_ordered_by_member() {
        let pool = pool();
        let g = pool.get(&[0, 1]).unwrap();
        let handles: Vec<_> = (0..2)
            .map(|r| {
                let g = g.clone();
                thread::spawn(move || g.all_gather(r, &[r as f32]).unwrap())
            })
            .collect();
        for h in handles {
            let out = h.join().unwrap();
            assert_eq!(out, vec![vec![0.0], vec![1.0]]);
        }
    }

    #[test]
    fn all_gather_into_flat_and_reusable() {
        let pool = pool();
        let g = pool.get(&[0, 1, 2, 3]).unwrap();
        // Two rounds through the same caller buffers: contents must be the
        // round's own, concatenated in member order.
        let handles: Vec<_> = (0..4)
            .map(|r| {
                let g = g.clone();
                thread::spawn(move || {
                    let mut out = Vec::new();
                    let mut rounds = Vec::new();
                    for round in 0..3 {
                        let data = [(100 * round + r) as f32, 0.5];
                        g.all_gather_into(r, &data, &mut out).unwrap();
                        rounds.push(out.clone());
                    }
                    rounds
                })
            })
            .collect();
        for h in handles {
            let rounds = h.join().unwrap();
            for (round, out) in rounds.iter().enumerate() {
                let want: Vec<f32> = (0..4)
                    .flat_map(|m| [(100 * round + m) as f32, 0.5])
                    .collect();
                assert_eq!(out, &want, "round {round}");
            }
        }
    }

    #[test]
    fn all_gather_into_singleton() {
        let pool = pool();
        let g = pool.get(&[3]).unwrap();
        let mut out = vec![9.0; 7]; // stale contents must be replaced
        g.all_gather_into(3, &[1.0, 2.0], &mut out).unwrap();
        assert_eq!(out, vec![1.0, 2.0]);
    }

    #[test]
    fn repeated_broadcasts_keep_rounds_straight() {
        let pool = pool();
        let g = pool.get(&[0, 1]).unwrap();
        let handles: Vec<_> = (0..2)
            .map(|r| {
                let g = g.clone();
                thread::spawn(move || {
                    let mut outs = Vec::new();
                    for step in 0..50 {
                        let mut d = if r == 0 { vec![step as f32] } else { vec![-1.0] };
                        g.broadcast(r, &mut d).unwrap();
                        outs.push(d[0]);
                    }
                    outs
                })
            })
            .collect();
        for h in handles {
            let outs = h.join().unwrap();
            for (step, &x) in outs.iter().enumerate() {
                assert_eq!(x, step as f32);
            }
        }
    }

    #[test]
    fn scatter_into_distributes_chunks_by_member_index() {
        let pool = pool();
        let g = pool.get(&[4, 5, 6, 7]).unwrap();
        // Root mid-group (rank 6) and three rounds through the same caller
        // buffers: member i must receive chunk i of that round's payload.
        let handles: Vec<_> = [4usize, 5, 6, 7]
            .into_iter()
            .map(|r| {
                let g = g.clone();
                thread::spawn(move || {
                    let mut out = Vec::new();
                    let mut rounds = Vec::new();
                    for round in 0..3 {
                        let send: Vec<f32> = if r == 6 {
                            (0..8).map(|i| (100 * round + i) as f32).collect()
                        } else {
                            Vec::new()
                        };
                        g.scatter_into(r, 6, &send, &mut out).unwrap();
                        rounds.push(out.clone());
                    }
                    rounds
                })
            })
            .collect();
        for (m, h) in handles.into_iter().enumerate() {
            let rounds = h.join().unwrap();
            for (round, out) in rounds.iter().enumerate() {
                let want: Vec<f32> =
                    (0..2).map(|i| (100 * round + 2 * m + i) as f32).collect();
                assert_eq!(out, &want, "member {m} round {round}");
            }
        }
    }

    #[test]
    fn gather_into_concatenates_at_root_only() {
        let pool = pool();
        let g = pool.get(&[0, 1, 2, 3]).unwrap();
        let handles: Vec<_> = (0..4)
            .map(|r| {
                let g = g.clone();
                thread::spawn(move || {
                    let mut out = vec![9.0; 3]; // stale contents must vanish
                    g.gather_into(r, 1, &[r as f32, 0.25], &mut out).unwrap();
                    out
                })
            })
            .collect();
        for (m, h) in handles.into_iter().enumerate() {
            let out = h.join().unwrap();
            if m == 1 {
                let want: Vec<f32> = (0..4).flat_map(|i| [i as f32, 0.25]).collect();
                assert_eq!(out, want, "root gather");
            } else {
                assert!(out.is_empty(), "non-root member {m} must receive nothing");
            }
        }
    }

    #[test]
    fn scatter_and_gather_singletons_are_copies() {
        let pool = pool();
        let g = pool.get(&[3]).unwrap();
        let mut out = vec![0.0; 4];
        g.scatter_into(3, 3, &[1.0, 2.0], &mut out).unwrap();
        assert_eq!(out, vec![1.0, 2.0]);
        g.gather_into(3, 3, &[5.0], &mut out).unwrap();
        assert_eq!(out, vec![5.0]);
    }

    #[test]
    fn scatter_rejects_non_member_root() {
        let pool = pool();
        let g = pool.get(&[0, 1]).unwrap();
        let mut out = Vec::new();
        assert!(matches!(
            g.scatter_into(0, 5, &[1.0, 2.0], &mut out).unwrap_err(),
            CommError::NotAMember { .. }
        ));
    }

    #[test]
    fn gather_rejects_mismatched_shapes_loudly() {
        let pool = pool();
        let g = pool.get(&[0, 1]).unwrap();
        // One member contributes a short buffer: every member must get the
        // shape error, not a silently misaligned concatenation.
        let handles: Vec<_> = (0..2)
            .map(|r| {
                let g = g.clone();
                thread::spawn(move || {
                    let data: Vec<f32> = if r == 0 { vec![1.0, 2.0] } else { vec![3.0] };
                    let mut out = Vec::new();
                    g.gather_into(r, 0, &data, &mut out).unwrap_err()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), CommError::GatherShape);
        }
        // The communicator stays usable for the next (well-shaped) round.
        let handles: Vec<_> = (0..2)
            .map(|r| {
                let g = g.clone();
                thread::spawn(move || {
                    let mut out = Vec::new();
                    g.gather_into(r, 0, &[r as f32], &mut out).unwrap();
                    out
                })
            })
            .collect();
        for (m, h) in handles.into_iter().enumerate() {
            let out = h.join().unwrap();
            if m == 0 {
                assert_eq!(out, vec![0.0, 1.0]);
            } else {
                assert!(out.is_empty());
            }
        }
    }

    #[test]
    fn scatter_rejects_indivisible_payload() {
        let pool = pool();
        let g = pool.get(&[0, 1]).unwrap();
        let mut out = Vec::new();
        // Root-side contract violation fails loudly before entering the
        // collective (no silent tail truncation).
        assert!(matches!(
            g.scatter_into(0, 0, &[1.0, 2.0, 3.0], &mut out).unwrap_err(),
            CommError::ScatterShape { len: 3, p: 2 }
        ));
    }

    #[test]
    fn watchdog_detects_missing_member() {
        let pool = CommunicatorPool::new(2, &[2], Duration::from_millis(100));
        let g = pool.get(&[0, 1]).unwrap();
        // Only rank 0 arrives: must time out, not hang.
        let mut d = vec![1.0];
        let err = g.all_reduce_sum(0, &mut d).unwrap_err();
        assert!(matches!(err, CommError::CollectiveTimeout(_)));
    }

    #[test]
    fn reset_member_unblocks_torn_round_with_error() {
        // Long timeout: without the reset, the waiter would block ~5s.
        let pool = CommunicatorPool::new(2, &[2], Duration::from_secs(5));
        let g = pool.get(&[0, 1]).unwrap();
        let g0 = g.clone();
        let t0 = std::time::Instant::now();
        let waiter = thread::spawn(move || {
            let mut d = vec![1.0];
            g0.all_reduce_sum(0, &mut d)
        });
        // Let rank 0 enter the round, then tear it down as a rejoin would.
        thread::sleep(Duration::from_millis(50));
        g.reset_member(1).unwrap();
        let err = waiter.join().unwrap().unwrap_err();
        assert_eq!(err, CommError::MemberReset);
        assert!(t0.elapsed() < Duration::from_secs(2), "woke on reset, not timeout");
        // The group is immediately usable by the next (full) round.
        let handles: Vec<_> = (0..2)
            .map(|r| {
                let g = g.clone();
                thread::spawn(move || {
                    let mut d = vec![r as f32 + 1.0];
                    g.all_reduce_sum(r, &mut d).unwrap();
                    d[0]
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 3.0);
        }
    }

    #[test]
    fn reset_member_is_a_noop_without_inflight_round() {
        let pool = pool();
        let g = pool.get(&[0, 1]).unwrap();
        g.reset_member(0).unwrap();
        assert!(matches!(
            g.reset_member(9).unwrap_err(),
            CommError::NotAMember { .. }
        ));
        // Singleton groups have no rendezvous state to reset.
        pool.get(&[3]).unwrap().reset_member(3).unwrap();
    }

    #[test]
    fn rejoin_member_touches_every_group_of_rank() {
        let pool = pool(); // 8 engines, degrees 1/2/4/8
        // Rank 2 sits in [2], [2,3], [0..4], [0..8] — the singleton resets
        // trivially, so 4 groups are touched.
        assert_eq!(pool.rejoin_member(2), 4);
        // Pool stays fully usable.
        let g = pool.get(&[2, 3]).unwrap();
        let handles: Vec<_> = (2..4)
            .map(|r| {
                let g = g.clone();
                thread::spawn(move || {
                    let mut d = vec![r as f32];
                    g.all_reduce_sum(r, &mut d).unwrap();
                    d[0]
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 5.0);
        }
    }

    #[test]
    fn non_member_rejected() {
        let pool = pool();
        let g = pool.get(&[0, 1]).unwrap();
        let mut d = vec![0.0];
        assert!(matches!(
            g.all_reduce_sum(5, &mut d).unwrap_err(),
            CommError::NotAMember { .. }
        ));
    }

    #[test]
    fn singleton_groups_are_noops() {
        let pool = pool();
        let g = pool.get(&[3]).unwrap();
        let mut d = vec![42.0];
        g.all_reduce_sum(3, &mut d).unwrap();
        assert_eq!(d, vec![42.0]);
        g.barrier(3).unwrap();
    }

    #[test]
    fn disjoint_groups_operate_concurrently() {
        let pool = pool();
        let g01 = pool.get(&[0, 1]).unwrap();
        let g23 = pool.get(&[2, 3]).unwrap();
        let mk = |g: Arc<Communicator>, r: usize, v: f32| {
            thread::spawn(move || {
                let mut d = vec![v];
                g.all_reduce_sum(r, &mut d).unwrap();
                d[0]
            })
        };
        let h = vec![
            mk(g01.clone(), 0, 1.0),
            mk(g01, 1, 2.0),
            mk(g23.clone(), 2, 10.0),
            mk(g23, 3, 20.0),
        ];
        let out: Vec<f32> = h.into_iter().map(|x| x.join().unwrap()).collect();
        assert_eq!(out, vec![3.0, 3.0, 30.0, 30.0]);
    }
}
