//! Per-priority FIFO waiting rings — the single waiting-queue structure
//! behind both scheduling paths (the simulator's former `ReadyQueue` and
//! the coordinator's former `waiting_hi`/`waiting_lo`).
//!
//! Arrivals are pushed in admission (time) order and requeues preserve
//! relative order, so draining high-priority-first reproduces the seed's
//! full (priority desc, arrival asc) sort without any per-iteration
//! sorting.  New priority levels mean new rings, never a sort.

use std::collections::VecDeque;

use crate::workload::Priority;

pub struct ReadyRings<H> {
    high: VecDeque<H>,
    normal: VecDeque<H>,
}

impl<H> Default for ReadyRings<H> {
    fn default() -> Self {
        ReadyRings::new()
    }
}

impl<H> ReadyRings<H> {
    pub fn new() -> Self {
        ReadyRings { high: VecDeque::new(), normal: VecDeque::new() }
    }

    pub fn len(&self) -> usize {
        self.high.len() + self.normal.len()
    }

    pub fn is_empty(&self) -> bool {
        self.high.is_empty() && self.normal.is_empty()
    }

    pub fn push(&mut self, pri: Priority, h: H) {
        match pri {
            Priority::High => self.high.push_back(h),
            Priority::Normal => self.normal.push_back(h),
        }
    }

    /// Pop in drain order (high first, then normal).  Used by stall
    /// resolution, which rejects the entire queue deterministically.
    pub fn pop_any(&mut self) -> Option<H> {
        self.high.pop_front().or_else(|| self.normal.pop_front())
    }

    /// Waiting entries in drain order (diagnostics; not a hot path).
    pub fn iter(&self) -> impl Iterator<Item = &H> {
        self.high.iter().chain(self.normal.iter())
    }

    pub(super) fn high_mut(&mut self) -> &mut VecDeque<H> {
        &mut self.high
    }

    pub(super) fn normal_mut(&mut self) -> &mut VecDeque<H> {
        &mut self.normal
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pop_any_drains_high_first() {
        let mut r: ReadyRings<u32> = ReadyRings::new();
        r.push(Priority::Normal, 1);
        r.push(Priority::High, 2);
        r.push(Priority::Normal, 3);
        assert_eq!(r.len(), 3);
        assert_eq!(r.pop_any(), Some(2));
        assert_eq!(r.pop_any(), Some(1));
        assert_eq!(r.pop_any(), Some(3));
        assert_eq!(r.pop_any(), None);
        assert!(r.is_empty());
    }

    #[test]
    fn iter_matches_drain_order() {
        let mut r: ReadyRings<u32> = ReadyRings::new();
        r.push(Priority::Normal, 7);
        r.push(Priority::High, 8);
        let got: Vec<u32> = r.iter().copied().collect();
        assert_eq!(got, vec![8, 7]);
    }
}
