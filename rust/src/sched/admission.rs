//! Shared admission predicates: the constraint tiers every mode decision
//! passes through, the least-loaded engine pick, and the drain-horizon
//! backfill predicate.
//!
//! Each predicate here has exactly one definition and one call-site layer —
//! this module.  The simulator and the coordinator (and the control plane's
//! `plan_decision`) call these; they never re-implement them.

use crate::coordinator::policy::{ModeDecision, Snapshot};
use crate::sim::costmodel::CostModel;
use crate::workload::Priority;

/// Narrowest TP degree whose pooled KV capacity fits `total_tokens`
/// (Use Case 3's memory-driven binding).  `None` when no supported width
/// fits — the request is unservable.
pub fn fit_tp(total_tokens: usize, snap: &Snapshot) -> Option<usize> {
    let mut p = 1;
    while p <= snap.max_tp {
        if total_tokens <= snap.dp_capacity_tokens * p {
            return Some(p);
        }
        p *= 2;
    }
    None
}

/// The correctness-constrained decision tiers — explicit TP demand,
/// memory-driven binding (Use Case 3), priority binding (Use Case 2) — or
/// `None` when the request is elastic (Use Case 1).  This is the single
/// definition shared by `FlyingPolicy::decide` and the control plane's
/// `plan_decision`: a fleet plan may steer only the elastic tail, so every
/// path must agree on where that tail begins.
pub fn constrained(
    prompt_len: usize,
    output_len_hint: usize,
    priority: Priority,
    tp_demand: Option<usize>,
    snap: &Snapshot,
) -> Option<ModeDecision> {
    let total = prompt_len + output_len_hint;
    // Explicit demand wins (latency-strict clients).
    if let Some(p) = tp_demand {
        return Some(ModeDecision::Tp(p.min(snap.max_tp).max(1)));
    }
    // Use Case 3: memory-driven.
    if total > snap.dp_capacity_tokens {
        return Some(match fit_tp(total, snap) {
            Some(p) => ModeDecision::Tp(p),
            None => ModeDecision::Reject,
        });
    }
    // Use Case 2: priority-driven.  The binding takes at most half the
    // cluster so best-effort traffic keeps DP engines (paper §2.3:
    // "normal tasks continue to execute on remaining DP engines").
    if priority == Priority::High {
        let width = (snap.n_engines / 2).max(2).min(snap.max_tp);
        return Some(ModeDecision::Tp(width));
    }
    None
}

/// Least-loaded candidate selection with the shared tie-break (first among
/// equals wins — `Iterator::min_by_key` semantics, which both paths
/// historically implemented by hand).  Offer candidates in scan order.
#[derive(Default)]
pub struct LeastLoaded {
    best: Option<(usize, usize)>, // (load, candidate)
}

impl LeastLoaded {
    pub fn new() -> Self {
        LeastLoaded::default()
    }

    #[inline]
    pub fn offer(&mut self, candidate: usize, load: usize) {
        if self.best.map(|(l, _)| load < l).unwrap_or(true) {
            self.best = Some((load, candidate));
        }
    }

    #[inline]
    pub fn pick(&self) -> Option<usize> {
        self.best.map(|(_, c)| c)
    }
}

/// Wall-clock cost of chunked prefill of `tokens` on a g-GPU instance:
/// per-chunk `prefill_s` floored at the scheduling heartbeat.  Every full
/// chunk costs the same, so this is closed-form — O(1), not O(tokens/chunk)
/// — which matters because the coordinator evaluates it per resident on
/// every drain-horizon refresh and long-context prompts run to hundreds of
/// thousands of tokens.  (The simulator's byte-exact step-for-step
/// accumulation lives in `CostModel::solo_completion_t`, not here.)
pub fn chunked_prefill_s(
    cm: &CostModel,
    tokens: usize,
    g: usize,
    chunk_tokens: usize,
    heartbeat_s: f64,
) -> f64 {
    if tokens == 0 {
        return 0.0;
    }
    let chunk = chunk_tokens.max(1);
    let full = tokens / chunk;
    let rem = tokens % chunk;
    let mut t = full as f64 * cm.prefill_s(chunk, g).max(heartbeat_s);
    if rem > 0 {
        t += cm.prefill_s(rem, g).max(heartbeat_s);
    }
    t
}

/// The drain-horizon backfill admission predicate — the one rule both
/// paths apply (ISSUE 5; formerly the simulator's exact
/// `solo_completion_t <= settle_at` check and the coordinator's separate
/// scheduler-step count heuristic).
///
/// A request is backfillable onto a draining/shell engine iff its solo-run
/// completion, started at `start`, lands at or before `deadline`.
///
/// * Simulator shells: `start` is the later of now, the shell's free point,
///   and the shell's current backfill-work bound (the batched-shell
///   over-approximation — see `sim::cluster`); `deadline` is the shell's
///   absolute settle stamp; `displace_prefill` is false (shells admit only
///   onto backfill-only residency, so there is no resident decode to
///   displace).  In the simulator the cost model IS the execution model,
///   so the prediction is exact.
/// * Coordinator: `start` is 0 and `deadline` is
///   `backfill_margin × horizon_s` (the drain window in calibrated
///   wall-clock seconds — see [`remaining_work_s`]); `displace_prefill` is
///   true because engines issue prefill-first, so each backfill prefill
///   chunk also displaces one resident decode step and extends the drain —
///   the request's prefill is charged twice to absorb that displacement.
///
/// Returns the predicted completion time when the request fits (callers
/// fold it into their running shell bound), `None` otherwise.
#[allow(clippy::too_many_arguments)]
pub fn backfill_fit(
    cm: &CostModel,
    start: f64,
    prompt: usize,
    output: usize,
    g: usize,
    chunk_tokens: usize,
    heartbeat_s: f64,
    displace_prefill: bool,
    deadline: f64,
) -> Option<f64> {
    let s0 = if displace_prefill {
        start + chunked_prefill_s(cm, prompt, g, chunk_tokens, heartbeat_s)
    } else {
        start
    };
    let fin = cm.solo_completion_t(s0, prompt, output, g, chunk_tokens, heartbeat_s, deadline);
    (fin <= deadline).then_some(fin)
}

/// The prefix-cache reuse decision (ISSUE 10): how many of a prompt's
/// `matched` cached tokens a request may skip prefilling.  This is the
/// single definition both paths apply — the real coordinator feeds it a
/// block-granular probe result with `block_tokens = B(1)`, the simulator a
/// family-metadata match with `block_tokens = 1` (the sim has no blocks;
/// token granularity is its exact analogue).
///
/// Two rules:
/// * The hit is floored to a whole number of blocks — a partial block
///   cannot be adopted by reference (its tail would be clobbered by the
///   adopter's own writes).
/// * At least one prompt token is always left to prefill (cap at
///   `prompt_len - 1`): both execution paths seed decode from the last
///   prompt position's forward pass, so a full-prompt hit must still
///   recompute the final token.  (This is also what chunked prefill
///   requires — an admitted request always has a non-empty first chunk.)
pub fn prefix_hit(matched: usize, prompt_len: usize, block_tokens: usize) -> usize {
    let bt = block_tokens.max(1);
    let cap = matched.min(prompt_len.saturating_sub(1));
    (cap / bt) * bt
}

/// Predicted wall-clock work a partially-served request still owes a g-GPU
/// engine: remaining chunked prefill plus one decode step per remaining
/// output token at the request's mid-tail context.  This is the per-
/// resident term of the coordinator's drain horizon (the largest value over
/// a draining group's residents), denominated in the same calibrated
/// seconds as [`backfill_fit`]'s request side, so the predicate compares
/// like with like.  The decode tail uses a closed-form midpoint context
/// instead of the exact per-step walk: the horizon is a bound, not a
/// schedule, and residents can owe thousands of tokens.
#[allow(clippy::too_many_arguments)]
pub fn remaining_work_s(
    cm: &CostModel,
    prefill_left_tokens: usize,
    decode_left: usize,
    ctx_now: usize,
    g: usize,
    chunk_tokens: usize,
    heartbeat_s: f64,
) -> f64 {
    let pre = chunked_prefill_s(cm, prefill_left_tokens, g, chunk_tokens, heartbeat_s);
    let mid_ctx = (ctx_now + decode_left / 2).max(1);
    let dec = decode_left as f64 * cm.decode_step_s(1, mid_ctx, g).max(heartbeat_s);
    pre + dec
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::costmodel::{HwSpec, PaperModel};

    fn snap() -> Snapshot {
        Snapshot {
            now: 0.0,
            queue_len: 0,
            idle_engines: 4,
            n_engines: 4,
            dp_capacity_tokens: 1000,
            max_tp: 4,
            kv_frac: 0.0,
        }
    }

    fn llama() -> CostModel {
        CostModel::new(HwSpec::default(), PaperModel::llama70b())
    }

    #[test]
    fn fit_tp_picks_narrowest_and_rejects_oversize() {
        let s = snap();
        assert_eq!(fit_tp(900, &s), Some(1));
        assert_eq!(fit_tp(1000, &s), Some(1));
        assert_eq!(fit_tp(1001, &s), Some(2));
        assert_eq!(fit_tp(4000, &s), Some(4));
        assert_eq!(fit_tp(4001, &s), None);
    }

    #[test]
    fn constrained_tiers_in_precedence_order() {
        let s = snap();
        // Explicit demand beats everything, clamped to max_tp.
        assert_eq!(
            constrained(5000, 0, Priority::High, Some(8), &s),
            Some(ModeDecision::Tp(4))
        );
        // Memory tier beats priority tier.
        assert_eq!(
            constrained(3500, 100, Priority::High, None, &s),
            Some(ModeDecision::Tp(4))
        );
        // Priority tier binds half the cluster.
        assert_eq!(
            constrained(100, 50, Priority::High, None, &s),
            Some(ModeDecision::Tp(2))
        );
        // Elastic tail: no constraint.
        assert_eq!(constrained(100, 50, Priority::Normal, None, &s), None);
        // Unservable: reject.
        assert_eq!(
            constrained(10_000, 0, Priority::Normal, None, &s),
            Some(ModeDecision::Reject)
        );
    }

    #[test]
    fn least_loaded_keeps_first_among_equals() {
        let mut ll = LeastLoaded::new();
        ll.offer(3, 2);
        ll.offer(1, 2); // tie: first offer wins
        assert_eq!(ll.pick(), Some(3));
        ll.offer(5, 1); // strictly better: replaces
        assert_eq!(ll.pick(), Some(5));
        assert_eq!(LeastLoaded::new().pick(), None);
    }

    #[test]
    fn backfill_fit_matches_solo_completion_against_deadline() {
        let cm = llama();
        let g = 2;
        let exact = cm.solo_completion_t(1.0, 512, 16, g, 2048, 0.004, f64::INFINITY);
        // Deadline just after the exact finish: fits, returns the finish.
        let fit = backfill_fit(&cm, 1.0, 512, 16, g, 2048, 0.004, false, exact + 1e-9);
        assert_eq!(fit, Some(exact));
        // Deadline just before: does not fit.
        assert!(backfill_fit(&cm, 1.0, 512, 16, g, 2048, 0.004, false, exact - 1e-9).is_none());
    }

    #[test]
    fn displaced_prefill_is_charged_twice() {
        let cm = llama();
        let g = 2;
        let pre = chunked_prefill_s(&cm, 512, g, 2048, 0.0);
        let plain =
            backfill_fit(&cm, 0.0, 512, 4, g, 2048, 0.0, false, f64::INFINITY).unwrap();
        let displaced =
            backfill_fit(&cm, 0.0, 512, 4, g, 2048, 0.0, true, f64::INFINITY).unwrap();
        assert!((displaced - plain - pre).abs() < 1e-12);
    }

    #[test]
    fn prefix_hit_rounds_down_and_never_eats_the_whole_prompt() {
        // Block-granular (real path, B(1) = 4): floor to whole blocks.
        assert_eq!(prefix_hit(0, 100, 4), 0);
        assert_eq!(prefix_hit(3, 100, 4), 0);
        assert_eq!(prefix_hit(11, 100, 4), 8);
        assert_eq!(prefix_hit(12, 100, 4), 12);
        // A full-prompt match must leave the last token to prefill.
        assert_eq!(prefix_hit(12, 12, 4), 8);
        assert_eq!(prefix_hit(16, 13, 4), 12);
        // Token-granular (simulator): same cap rule at bt = 1.
        assert_eq!(prefix_hit(7, 100, 1), 7);
        assert_eq!(prefix_hit(12, 12, 1), 11);
        // Degenerate inputs are total, never panic.
        assert_eq!(prefix_hit(5, 0, 4), 0);
        assert_eq!(prefix_hit(5, 1, 0), 0);
        assert_eq!(prefix_hit(usize::MAX, 9, 4), 8);
    }

    #[test]
    fn remaining_work_shrinks_as_the_request_progresses() {
        let cm = llama();
        let early = remaining_work_s(&cm, 4096, 256, 0, 2, 2048, 0.0);
        let mid = remaining_work_s(&cm, 0, 256, 4096, 2, 2048, 0.0);
        let late = remaining_work_s(&cm, 0, 8, 4344, 2, 2048, 0.0);
        assert!(early > mid && mid > late);
        assert_eq!(remaining_work_s(&cm, 0, 0, 5000, 2, 2048, 0.0), 0.0);
    }
}
