//! Group-lifecycle decision points: form → drain → backfill-shell →
//! incremental settle → promote, and the split inverse.
//!
//! The *state* of a group lives in each driver (the simulator's transient
//! vengs and backfill shells; the coordinator's `Group` table) because the
//! two paths genuinely differ in mechanism — but every *decision* along the
//! lifecycle is one of the functions below, so the rule can never fork.

use crate::sim::costmodel::CostModel;

/// Whether a drained transient TP group should split back to unit engines
/// now.  Splits happen only under pressure: queued work that wants DP
/// capacity, or hard-preempted requests waiting to resume.  An idle merged
/// group is kept so low-load traffic stays in the TP regime (Use Case 1);
/// carried (migrated) residents keep decoding inside it and add no
/// pressure.
#[inline]
pub fn split_due(tp_work_left: bool, queue_pressure: bool, paused_waiting: bool) -> bool {
    !tp_work_left && (queue_pressure || paused_waiting)
}

/// Incremental settle (backfill mode): whether one member of a draining
/// group should switch into the target mode now instead of idling behind
/// the slowest straggler.  A member settles as soon as its own work drains;
/// already-settled or already-switched members are skipped so the final
/// promotion only pays the stragglers' mode RPCs.
#[inline]
pub fn member_settle_due(already_settled: bool, at_unit_mode: bool, member_busy: bool) -> bool {
    !already_settled && at_unit_mode && !member_busy
}

/// The migrate-vs-recompute gate (ISSUE 4/5): whether a request's cached KV
/// is carried live across a DP→TP layout change instead of being
/// re-prefilled.  This is the single call site of
/// `CostModel::migrate_wins`; both paths answer through it:
///
/// * simulator — per resident at merge/fold time, `eligible` = the
///   resident is in decode phase (prefill-phase residents pause as before);
/// * coordinator — per promotion, `eligible` = the request ran
///   speculatively (soft preempt), so it owns DP-layout KV to carry.
///
/// `cached_tokens == 0` (nothing cached yet) or a disabled flag always
/// recomputes — the flag-off path must stay byte-identical to PR 1/3.
#[inline]
pub fn carry_wins(
    cm: &CostModel,
    migrate_enabled: bool,
    eligible: bool,
    cached_tokens: usize,
    g: usize,
) -> bool {
    migrate_enabled && eligible && cached_tokens > 0 && cm.migrate_wins(cached_tokens, g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::costmodel::{HwSpec, PaperModel};

    #[test]
    fn split_only_under_pressure() {
        // Work left: never split.
        assert!(!split_due(true, true, true));
        // Drained + queued work or paused requests: split.
        assert!(split_due(false, true, false));
        assert!(split_due(false, false, true));
        // Drained but idle cluster: keep the group (Use Case 1).
        assert!(!split_due(false, false, false));
    }

    #[test]
    fn member_settles_once_when_drained() {
        assert!(member_settle_due(false, true, false));
        assert!(!member_settle_due(true, true, false), "already settled");
        assert!(!member_settle_due(false, false, false), "already switched");
        assert!(!member_settle_due(false, true, true), "still busy");
    }

    #[test]
    fn carry_gated_by_flag_eligibility_and_cache() {
        let cm = CostModel::new(HwSpec::default(), PaperModel::llama70b());
        // At paper scale the cost rule always favors migration...
        assert!(carry_wins(&cm, true, true, 8192, 4));
        // ...but the flag, eligibility, and a non-empty cache all gate it.
        assert!(!carry_wins(&cm, false, true, 8192, 4));
        assert!(!carry_wins(&cm, true, false, 8192, 4));
        assert!(!carry_wins(&cm, true, true, 0, 4));
    }
}
