//! Unit/idle/draining engine bitmask index — O(1) engine-state queries for
//! the admission walk, shared by both scheduling paths.
//!
//! The coordinator maintains one bit per physical engine
//! ([`EngineIndex::refresh_engine`], [`EngineIndex::set_draining_mask`]);
//! the simulator maintains one bit per *serving instance*, with each
//! virtual engine owning the bits of the instances merged into it (a
//! merged TP group of `m` instances carries `m` bits, so
//! [`EngineIndex::idle_count`] equals the old Σ-over-vengs idle fold
//! exactly).  Maintenance discipline is the driver's: every mutation of
//! engine mode / active set / drain state must update the bits — queries
//! never re-derive by scanning.
//!
//! Semantic note: what "idle" *excludes* differs legitimately per path and
//! is encoded in maintenance, not in the query.  The simulator never marks
//! a backfill shell idle (committed capacity is represented by its forming
//! group); the coordinator counts an empty draining unit engine as idle
//! (the policy sees it until the switch lands) — both are the exact
//! pre-kernel behaviors of their paths.

#[derive(Clone, Copy, Debug, Default)]
pub struct EngineIndex {
    unit: u64,
    idle: u64,
    draining: u64,
    /// Fail-stopped engines (ISSUE 6): permanently excluded from every
    /// candidate set.  A failed engine's bit is sticky — `refresh_engine`
    /// cannot resurrect it.
    failed: u64,
    /// Rejoining engines (ISSUE 8): respawned but not yet probed.  A
    /// quarantined engine is excluded from every candidate set exactly
    /// like a failed one — only [`EngineIndex::clear_quarantine`] (called
    /// after a successful probe step) readmits it to candidacy, and only
    /// through a subsequent `refresh_engine`.
    quarantined: u64,
}

impl EngineIndex {
    pub fn new() -> Self {
        EngineIndex::default()
    }

    /// Coordinator-style per-engine refresh: call after any mutation of
    /// `engine_mode[e]` or `engine_active[e]`.  Failed engines stay out of
    /// every set regardless of the arguments.
    #[inline]
    pub fn refresh_engine(&mut self, e: usize, unit: bool, idle: bool) {
        let bit = 1u64 << e;
        if (self.failed | self.quarantined) & bit != 0 {
            self.unit &= !bit;
            self.idle &= !bit;
            return;
        }
        if unit {
            self.unit |= bit;
        } else {
            self.unit &= !bit;
        }
        if idle {
            self.idle |= bit;
        } else {
            self.idle &= !bit;
        }
    }

    /// Fail-stop engine `e`: sticky-failed, removed from the unit/idle
    /// candidate sets immediately.  Draining membership is the group
    /// table's to clean up (the coordinator rebuilds the draining mask
    /// when it dissolves the group).
    #[inline]
    pub fn mark_failed(&mut self, e: usize) {
        let bit = 1u64 << e;
        self.failed |= bit;
        self.quarantined &= !bit;
        self.unit &= !bit;
        self.idle &= !bit;
    }

    #[inline]
    pub fn is_failed(&self, e: usize) -> bool {
        self.failed & (1u64 << e) != 0
    }

    #[inline]
    pub fn failed_mask(&self) -> u64 {
        self.failed
    }

    /// Begin a rejoin (ISSUE 8): move engine `e` from failed to
    /// quarantined.  The engine is still excluded from every candidate
    /// set; a failed probe re-escalates with [`EngineIndex::mark_failed`],
    /// a successful one promotes with [`EngineIndex::clear_quarantine`].
    #[inline]
    pub fn clear_failed(&mut self, e: usize) {
        let bit = 1u64 << e;
        self.failed &= !bit;
        self.quarantined |= bit;
        self.unit &= !bit;
        self.idle &= !bit;
    }

    /// Complete a rejoin: lift the quarantine.  The engine rejoins the
    /// candidate sets only through the driver's next `refresh_engine`.
    #[inline]
    pub fn clear_quarantine(&mut self, e: usize) {
        self.quarantined &= !(1u64 << e);
    }

    #[inline]
    pub fn is_quarantined(&self, e: usize) -> bool {
        self.quarantined & (1u64 << e) != 0
    }

    #[inline]
    pub fn quarantined_mask(&self) -> u64 {
        self.quarantined
    }

    /// Mask-granular setters (simulator-style: a veng's `unit_bits` move
    /// together through merges, shells, folds, and splits).
    #[inline]
    pub fn set_unit(&mut self, bits: u64, on: bool) {
        if on {
            self.unit |= bits;
        } else {
            self.unit &= !bits;
        }
    }

    #[inline]
    pub fn set_idle(&mut self, bits: u64, on: bool) {
        if on {
            self.idle |= bits;
        } else {
            self.idle &= !bits;
        }
    }

    #[inline]
    pub fn set_draining(&mut self, bits: u64, on: bool) {
        if on {
            self.draining |= bits;
        } else {
            self.draining &= !bits;
        }
    }

    /// Replace the whole draining mask (coordinator: recomputed from the
    /// group table after any `tp_pending` mutation).
    #[inline]
    pub fn set_draining_mask(&mut self, mask: u64) {
        self.draining = mask;
    }

    #[inline]
    pub fn unit_mask(&self) -> u64 {
        self.unit
    }

    #[inline]
    pub fn idle_mask(&self) -> u64 {
        self.idle
    }

    #[inline]
    pub fn draining_mask(&self) -> u64 {
        self.draining
    }

    /// Idle serving capacity in unit-instance terms — the policy snapshot's
    /// `idle_engines`.
    #[inline]
    pub fn idle_count(&self) -> usize {
        (self.idle & !self.failed & !self.quarantined).count_ones() as usize
    }

    /// Engines eligible for a fresh elastic DP bind: unit mode, not
    /// committed to a draining group, not failed or quarantined.
    #[inline]
    pub fn dp_candidates(&self) -> u64 {
        self.unit & !self.draining & !self.failed & !self.quarantined
    }

    /// Draining unit engines — the backfill candidate set (admission still
    /// gated per engine by the horizon predicate).
    #[inline]
    pub fn backfill_candidates(&self) -> u64 {
        self.unit & self.draining & !self.failed & !self.quarantined
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refresh_engine_tracks_unit_and_idle() {
        let mut ix = EngineIndex::new();
        ix.refresh_engine(0, true, true);
        ix.refresh_engine(1, true, false);
        ix.refresh_engine(2, false, false);
        assert_eq!(ix.unit_mask(), 0b011);
        assert_eq!(ix.idle_mask(), 0b001);
        assert_eq!(ix.idle_count(), 1);
        // Back to unit+idle.
        ix.refresh_engine(2, true, true);
        assert_eq!(ix.dp_candidates(), 0b111);
    }

    #[test]
    fn draining_partitions_candidates() {
        let mut ix = EngineIndex::new();
        for e in 0..4 {
            ix.refresh_engine(e, true, true);
        }
        ix.set_draining_mask(0b1100);
        assert_eq!(ix.dp_candidates(), 0b0011);
        assert_eq!(ix.backfill_candidates(), 0b1100);
        ix.set_draining_mask(0);
        assert_eq!(ix.dp_candidates(), 0b1111);
    }

    #[test]
    fn failed_is_sticky_and_excluded_everywhere() {
        let mut ix = EngineIndex::new();
        for e in 0..4 {
            ix.refresh_engine(e, true, true);
        }
        ix.mark_failed(2);
        assert!(ix.is_failed(2));
        assert_eq!(ix.failed_mask(), 0b0100);
        assert_eq!(ix.unit_mask(), 0b1011);
        assert_eq!(ix.idle_count(), 3);
        assert_eq!(ix.dp_candidates(), 0b1011);
        // A refresh cannot resurrect a failed engine.
        ix.refresh_engine(2, true, true);
        assert_eq!(ix.unit_mask(), 0b1011);
        assert_eq!(ix.idle_mask() & 0b0100, 0);
        // Nor can it join the backfill set while draining.
        ix.set_draining_mask(0b0100);
        assert_eq!(ix.backfill_candidates(), 0);
    }

    #[test]
    fn rejoin_lifecycle_failed_quarantined_cleared() {
        let mut ix = EngineIndex::new();
        for e in 0..4 {
            ix.refresh_engine(e, true, true);
        }
        ix.mark_failed(2);
        // Respawn: failed -> quarantined.  Still excluded from everything.
        ix.clear_failed(2);
        assert!(!ix.is_failed(2));
        assert!(ix.is_quarantined(2));
        assert_eq!(ix.quarantined_mask(), 0b0100);
        assert_eq!(ix.idle_count(), 3);
        assert_eq!(ix.dp_candidates(), 0b1011);
        // Quarantine blocks resurrection-by-refresh just like failed.
        ix.refresh_engine(2, true, true);
        assert_eq!(ix.unit_mask(), 0b1011);
        ix.set_draining_mask(0b0100);
        assert_eq!(ix.backfill_candidates(), 0);
        ix.set_draining_mask(0);
        // Probe failure path: quarantined re-escalates back to failed.
        ix.mark_failed(2);
        assert!(ix.is_failed(2));
        assert!(!ix.is_quarantined(2));
        // Probe success path: quarantine lifts, then refresh readmits.
        ix.clear_failed(2);
        ix.clear_quarantine(2);
        assert!(!ix.is_quarantined(2) && !ix.is_failed(2));
        assert_eq!(ix.idle_count(), 3, "candidacy returns only via refresh");
        ix.refresh_engine(2, true, true);
        assert_eq!(ix.idle_count(), 4);
        assert_eq!(ix.dp_candidates(), 0b1111);
    }

    #[test]
    fn mask_setters_move_bit_groups_together() {
        let mut ix = EngineIndex::new();
        // A 2-instance veng owning bits {1,2}.
        ix.set_unit(0b110, true);
        ix.set_idle(0b110, true);
        assert_eq!(ix.idle_count(), 2);
        // Shell conversion: committed capacity, never idle.
        ix.set_idle(0b110, false);
        ix.set_draining(0b110, true);
        assert_eq!(ix.idle_count(), 0);
        assert_eq!(ix.backfill_candidates(), 0b110);
        // Fold: bits leave the unit/draining sets (now inside a group).
        ix.set_draining(0b110, false);
        ix.set_unit(0b110, false);
        assert_eq!(ix.unit_mask(), 0);
        assert_eq!(ix.draining_mask(), 0);
    }
}
