//! The unified scheduling kernel (ISSUE 5): one switch/admission state
//! machine shared by the discrete-event simulator (`sim::cluster`) and the
//! real coordinator (`coordinator`).
//!
//! Before this module existed, the paper's deadlock-free scheduler
//! (§ component iv) was implemented twice — once per path — and held in
//! sync only by differential tests and "one rule, two paths" ROADMAP
//! clauses.  The kernel extracts everything that must never fork:
//!
//! * **[`ReadyRings`]** — the per-priority FIFO waiting rings.  Arrivals
//!   are admitted in time order and requeues keep relative order, so
//!   draining high-first reproduces the seed's (priority desc, arrival
//!   asc) sort without per-iteration sorting.
//! * **[`Walk`]** — the admission-walk skeleton: ring drain order, backlog
//!   accounting (`backlog_now` for burst detection), defer/requeue
//!   semantics, progress tracking, and the optional decision trace.  Both
//!   paths run the *identical* walk; only the driver-side `place` body
//!   (capacity checks, binding mechanics) differs.
//! * **[`EngineIndex`]** — the unit/idle/draining engine bitmask index.
//!   Queries are O(1); drivers maintain the bits at each state mutation.
//! * **[`admission`]** — the shared decision predicates: the
//!   `fit_tp`/priority/memory constraint tiers ([`constrained`]), the
//!   least-loaded tie-break ([`LeastLoaded`]), and the drain-horizon
//!   backfill predicate ([`backfill_fit`] — the only caller of
//!   `CostModel::solo_completion_t`).
//! * **[`lifecycle`]** — the group state machine's decision points
//!   (form → drain → backfill-shell → incremental settle → promote, and
//!   the split inverse): [`lifecycle::split_due`],
//!   [`lifecycle::member_settle_due`], and the migrate-vs-recompute gate
//!   [`lifecycle::carry_wins`] (the only caller of
//!   `CostModel::migrate_wins`).
//!
//! # Event/action shape
//!
//! The kernel consumes a [`SchedEvent`] stream — arrivals, capacity-freeing
//! step completions, group settles, control-plan changes — and each walk
//! emits one [`Placement`] per waiting request (recorded as
//! [`SchedAction`]s when tracing is enabled).  `sim/cluster.rs` is a driver
//! that stamps kernel placements onto its event heap; `coordinator/mod.rs`
//! is a driver that turns them into `EngineCmd`s.  Because the ring order,
//! backlog math, constraint tiers, horizon predicate, and migrate gate are
//! single definitions here, byte-identical decisions across the two paths
//! hold **by construction**; `tests/sim_equivalence.rs` remains as
//! regression insurance and `tests/sched_kernel.rs` asserts the decision
//! traces directly.
//!
//! # Dirty tracking
//!
//! The kernel re-walks the rings only after an event that can change an
//! admission decision (arrival, completion, settle, plan change) — pure
//! decode steps ([`SchedEvent::EngineFree`]) only shrink capacity and never
//! flip a failed admission, so skipped walks are provably no-ops.  The
//! simulator relies on this (it is the PR-1 dirty-tracking optimization);
//! the real coordinator calls [`Kernel::note_dirty`] every iteration
//! because its policies are wall-clock-time-varying (an `AdaptivePolicy`
//! control tick can change a decision with no kernel event at all), which
//! makes event-gating unsound there.
//!
//! # Hot-path discipline
//!
//! Kernel scratch (ring deques, requeue ping-pong buffers, the trace
//! buffer) is allocated once and recycled: a steady-state walk performs
//! zero heap allocations, preserving the `sched_hotpath` alloc gate.

pub mod admission;
pub mod index;
pub mod lifecycle;
pub mod rings;

pub use admission::{
    backfill_fit, chunked_prefill_s, constrained, fit_tp, prefix_hit, remaining_work_s,
    LeastLoaded,
};
pub use index::EngineIndex;
pub use lifecycle::{carry_wins, member_settle_due, split_due};
pub use rings::ReadyRings;

use crate::workload::Priority;

/// An event the kernel's dirty tracking consumes.  `H` is the driver's
/// request handle (dense index for the simulator, `SlabHandle` for the
/// coordinator).
#[derive(Clone, Copy, Debug)]
pub enum SchedEvent<H: Copy> {
    /// A request became visible to the scheduler.  Pushes onto the ring of
    /// its priority level and dirties the walk.
    Arrival { h: H, priority: Priority },
    /// A step completed and freed capacity (some request finished).
    /// Dirties the walk: a previously failed admission may now succeed.
    StepComplete,
    /// An engine finished a step with no terminal request.  Does NOT dirty:
    /// pure decode steps only shrink capacity, so a failed admission stays
    /// failed and the skipped walk is provably a no-op.
    EngineFree,
    /// A group transition settled (merge formed, shell folded, group
    /// dissolved, split completed).  Dirties the walk.
    Settle,
    /// The control plane adopted a new fleet plan.  Dirties the walk.
    /// Reserved for event-gated drivers: neither current driver emits it —
    /// the simulator deliberately preserves the PR-1/2 behavior of not
    /// re-walking on plan adoption (see `sim::cluster`), and the real
    /// coordinator dirties every iteration via [`Kernel::note_dirty`]
    /// because its policies are wall-clock-time-varying.
    ControlPlan,
    /// A failed engine healed and rejoined candidacy (ISSUE 8): its probe
    /// step succeeded, its quarantine lifted, and its capacity is back.
    /// Dirties the walk — a previously failed admission may now succeed on
    /// the recovered capacity.  Both drivers heal through this one event,
    /// so recovery cannot fork the scheduling decision stream.
    EngineRejoin { engine: usize },
}

/// What the driver did with one waiting request during a walk.  `Defer`
/// requeues it (FIFO within its priority level); everything else counts as
/// walk progress.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Bound as DP onto the driver-local engine/unit `unit`; `backfill` is
    /// set when the bind landed on a draining engine under the horizon
    /// predicate.
    Dp { unit: u32, backfill: bool },
    /// Bound into (or made pending on) a TP group of `width` instances.
    Tp { width: u32 },
    /// Rejected (unservable under the policy).
    Reject,
    /// No placement possible this walk; requeued in arrival order.
    Defer,
}

/// One recorded kernel decision: the request id plus its placement.  The
/// decision-trace differential (`tests/sched_kernel.rs`) asserts these are
/// byte-identical when the same `SchedEvent` stream is driven through
/// differently-shaped drivers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SchedAction {
    pub rid: u64,
    pub placement: Placement,
}

/// The scheduling kernel: rings + index + dirty tracking + decision trace.
pub struct Kernel<H: Copy> {
    pub rings: ReadyRings<H>,
    pub index: EngineIndex,
    dirty: bool,
    /// Requeue ping-pong scratch, recycled across walks (zero steady-state
    /// allocation).
    scratch_hi: std::collections::VecDeque<H>,
    scratch_lo: std::collections::VecDeque<H>,
    trace_on: bool,
    trace_buf: Vec<SchedAction>,
}

impl<H: Copy> Default for Kernel<H> {
    fn default() -> Self {
        Kernel::new()
    }
}

impl<H: Copy> Kernel<H> {
    pub fn new() -> Self {
        Kernel {
            rings: ReadyRings::new(),
            index: EngineIndex::new(),
            dirty: false,
            scratch_hi: std::collections::VecDeque::new(),
            scratch_lo: std::collections::VecDeque::new(),
            trace_on: false,
            trace_buf: Vec::new(),
        }
    }

    /// Feed one event into the kernel (ring push + dirty tracking).
    pub fn on_event(&mut self, ev: SchedEvent<H>) {
        match ev {
            SchedEvent::Arrival { h, priority } => {
                self.rings.push(priority, h);
                self.dirty = true;
            }
            SchedEvent::StepComplete
            | SchedEvent::Settle
            | SchedEvent::ControlPlan
            | SchedEvent::EngineRejoin { .. } => {
                self.dirty = true;
            }
            SchedEvent::EngineFree => {}
        }
    }

    /// Force the next walk (for drivers whose decisions are wall-clock-
    /// time-varying and therefore cannot be event-gated).
    pub fn note_dirty(&mut self) {
        self.dirty = true;
    }

    /// Whether something since the last no-progress walk could have changed
    /// an admission decision.
    pub fn walk_pending(&self) -> bool {
        self.dirty
    }

    /// Whether a walk should run now: something dirtied the queue and there
    /// is work waiting.
    pub fn should_walk(&self) -> bool {
        self.dirty && !self.rings.is_empty()
    }

    /// Record decisions into a trace readable via [`Self::take_trace`].
    pub fn enable_trace(&mut self) {
        self.trace_on = true;
    }

    pub fn take_trace(&mut self) -> Vec<SchedAction> {
        std::mem::take(&mut self.trace_buf)
    }

    /// Start an admission walk: moves the ring contents into a [`Walk`]
    /// that owns them, so the driver keeps full mutable access to its own
    /// state (including `self.index`) while iterating.
    pub fn begin_walk(&mut self) -> Walk<H> {
        let drain_hi = std::mem::take(self.rings.high_mut());
        let drain_lo = std::mem::take(self.rings.normal_mut());
        let backlog_total = drain_hi.len() + drain_lo.len();
        Walk {
            drain_hi,
            drain_lo,
            requeue_hi: std::mem::take(&mut self.scratch_hi),
            requeue_lo: std::mem::take(&mut self.scratch_lo),
            backlog_total,
            processed: 0,
            progress: false,
            phase_high: true,
            trace_on: self.trace_on,
            trace: std::mem::take(&mut self.trace_buf),
        }
    }

    /// Finish a walk: restore the rings (requeued entries first, then any
    /// undrained leftovers from an aborted walk, preserving order), recycle
    /// the scratch buffers, and clear the dirty flag when the walk made no
    /// progress (identical future walks would be no-ops until the next
    /// dirtying event).  Returns whether the walk made progress.
    pub fn end_walk(&mut self, mut w: Walk<H>) -> bool {
        // On a normal completion the drain deques are empty and these are
        // no-ops; on an aborted walk the leftovers keep their order behind
        // the requeues.
        w.requeue_hi.append(&mut w.drain_hi);
        w.requeue_lo.append(&mut w.drain_lo);
        std::mem::swap(self.rings.high_mut(), &mut w.requeue_hi);
        std::mem::swap(self.rings.normal_mut(), &mut w.requeue_lo);
        // Keep the larger-capacity deques as next walk's scratch.
        self.scratch_hi = w.drain_hi;
        self.scratch_lo = w.drain_lo;
        self.trace_buf = w.trace;
        if !w.progress {
            self.dirty = false;
        }
        w.progress
    }
}

/// An in-progress admission walk.  Owns the drained ring contents, so the
/// driver's placement code runs with unrestricted access to its own state.
///
/// Protocol per request: `next()` → driver decides/binds → `settle(...)`.
/// The walk drains the high ring first, then normal — with FIFO rings this
/// is exactly the (priority desc, arrival asc) order both paths promise.
pub struct Walk<H: Copy> {
    drain_hi: std::collections::VecDeque<H>,
    drain_lo: std::collections::VecDeque<H>,
    requeue_hi: std::collections::VecDeque<H>,
    requeue_lo: std::collections::VecDeque<H>,
    backlog_total: usize,
    processed: usize,
    progress: bool,
    phase_high: bool,
    trace_on: bool,
    trace: Vec<SchedAction>,
}

impl<H: Copy> Walk<H> {
    /// Next waiting request, with its priority level.  High-priority ring
    /// drains fully before the normal ring.
    pub fn next(&mut self) -> Option<(H, bool)> {
        if self.phase_high {
            if let Some(h) = self.drain_hi.pop_front() {
                self.processed += 1;
                return Some((h, true));
            }
            self.phase_high = false;
        }
        let h = self.drain_lo.pop_front()?;
        self.processed += 1;
        Some((h, false))
    }

    /// Queue depth as seen by the request currently being decided: already-
    /// requeued entries plus everything not yet processed.  This is the
    /// burst signal both paths feed their policy snapshots.
    pub fn backlog_now(&self) -> usize {
        self.requeue_hi.len() + self.requeue_lo.len() + (self.backlog_total - self.processed)
    }

    /// Report the placement for the request returned by the last `next()`.
    /// `Defer` requeues it on its priority ring; anything else marks walk
    /// progress.  Records the decision when tracing is enabled.
    pub fn settle(&mut self, h: H, high: bool, rid: u64, placement: Placement) {
        if self.trace_on {
            self.trace.push(SchedAction { rid, placement });
        }
        match placement {
            Placement::Defer => {
                if high {
                    self.requeue_hi.push_back(h);
                } else {
                    self.requeue_lo.push_back(h);
                }
            }
            _ => self.progress = true,
        }
    }
}

/// Issue-time stamp for a pre-materialized decode batch (ISSUE 9, the
/// double-buffered pipeline's *bounded-staleness rule*).
///
/// While batch N executes, the coordinator may pre-build batch N+1's
/// engine-facing views from the scheduler state as of issue time.  That
/// state is bounded-stale: by the time batch N's reply lands, requests may
/// have finished, been preempted, recovered, or re-decided by the kernel.
/// The contract that keeps kernel decisions byte-identical is all-or-
/// nothing: a prebuilt batch is issueable **iff** the exact `(handle,
/// position)` sequence it was built from still describes the live batch;
/// any divergence discards the prebuild and the batch is rebuilt from the
/// authoritative state.  The prebuild is a cached materialization of
/// decisions already made — never a decision source.
///
/// Allocation-free in steady state: both vectors retain capacity across
/// `clear`.
#[derive(Debug, Default)]
pub struct PrebuildStamp<H: Copy + PartialEq> {
    hs: Vec<H>,
    pos: Vec<usize>,
}

impl<H: Copy + PartialEq> PrebuildStamp<H> {
    pub fn clear(&mut self) {
        self.hs.clear();
        self.pos.clear();
    }

    pub fn push(&mut self, h: H, pos: usize) {
        self.hs.push(h);
        self.pos.push(pos);
    }

    pub fn len(&self) -> usize {
        self.hs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.hs.is_empty()
    }

    pub fn get(&self, i: usize) -> (H, usize) {
        (self.hs[i], self.pos[i])
    }

    /// The bounded-staleness verdict: does the live `(handle, position)`
    /// sequence equal the captured one, element for element, in order?
    pub fn matches<I: IntoIterator<Item = (H, usize)>>(&self, live: I) -> bool {
        let mut i = 0;
        for (h, p) in live {
            if i >= self.hs.len() || self.hs[i] != h || self.pos[i] != p {
                return false;
            }
            i += 1;
        }
        i == self.hs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prebuild_stamp_matches_exact_sequence_only() {
        let mut s: PrebuildStamp<u32> = PrebuildStamp::default();
        assert!(s.is_empty() && s.matches(Vec::new()));
        s.push(7, 10);
        s.push(9, 4);
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(1), (9, 4));
        assert!(s.matches(vec![(7, 10), (9, 4)]));
        // Any divergence — position advance, different handle, reorder,
        // shrink, growth — fails the verdict.
        assert!(!s.matches(vec![(7, 11), (9, 4)]));
        assert!(!s.matches(vec![(8, 10), (9, 4)]));
        assert!(!s.matches(vec![(9, 4), (7, 10)]));
        assert!(!s.matches(vec![(7, 10)]));
        assert!(!s.matches(vec![(7, 10), (9, 4), (1, 0)]));
        s.clear();
        assert!(s.is_empty() && s.matches(Vec::new()));
    }

    #[test]
    fn walk_drains_high_first_and_preserves_fifo_within_level() {
        let mut k: Kernel<u32> = Kernel::new();
        k.on_event(SchedEvent::Arrival { h: 1, priority: Priority::Normal });
        k.on_event(SchedEvent::Arrival { h: 2, priority: Priority::High });
        k.on_event(SchedEvent::Arrival { h: 3, priority: Priority::Normal });
        k.on_event(SchedEvent::Arrival { h: 4, priority: Priority::High });
        assert!(k.should_walk());
        let mut walk = k.begin_walk();
        let mut order = Vec::new();
        while let Some((h, high)) = walk.next() {
            order.push((h, high));
            walk.settle(h, high, h as u64, Placement::Dp { unit: 0, backfill: false });
        }
        assert!(k.end_walk(walk));
        assert_eq!(order, vec![(2, true), (4, true), (1, false), (3, false)]);
        assert!(k.rings.is_empty());
    }

    #[test]
    fn defer_requeues_in_order_and_clears_dirty_on_no_progress() {
        let mut k: Kernel<u32> = Kernel::new();
        for h in [10u32, 11, 12] {
            k.on_event(SchedEvent::Arrival { h, priority: Priority::Normal });
        }
        let mut walk = k.begin_walk();
        while let Some((h, high)) = walk.next() {
            walk.settle(h, high, h as u64, Placement::Defer);
        }
        assert!(!k.end_walk(walk));
        // No progress: dirty cleared, next walk suppressed...
        assert!(!k.should_walk());
        // ...until a dirtying event; order preserved.
        k.on_event(SchedEvent::StepComplete);
        assert!(k.should_walk());
        let mut walk = k.begin_walk();
        let mut order = Vec::new();
        while let Some((h, high)) = walk.next() {
            order.push(h);
            walk.settle(h, high, h as u64, Placement::Reject);
        }
        k.end_walk(walk);
        assert_eq!(order, vec![10, 11, 12]);
    }

    #[test]
    fn backlog_now_counts_requeues_and_remaining() {
        let mut k: Kernel<u32> = Kernel::new();
        for h in 0..4u32 {
            k.on_event(SchedEvent::Arrival { h, priority: Priority::Normal });
        }
        let mut walk = k.begin_walk();
        // First pop: 3 others remain.
        let (h, high) = walk.next().unwrap();
        assert_eq!(walk.backlog_now(), 3);
        walk.settle(h, high, 0, Placement::Defer);
        // Second pop: 1 requeued + 2 remaining.
        let (h, high) = walk.next().unwrap();
        assert_eq!(walk.backlog_now(), 3);
        walk.settle(h, high, 1, Placement::Dp { unit: 0, backfill: false });
        let (h, high) = walk.next().unwrap();
        // 1 requeued + 1 remaining.
        assert_eq!(walk.backlog_now(), 2);
        walk.settle(h, high, 2, Placement::Defer);
        k.end_walk(walk);
    }

    #[test]
    fn engine_free_does_not_dirty() {
        let mut k: Kernel<u32> = Kernel::new();
        k.on_event(SchedEvent::Arrival { h: 1, priority: Priority::Normal });
        let mut walk = k.begin_walk();
        while let Some((h, high)) = walk.next() {
            walk.settle(h, high, 1, Placement::Defer);
        }
        k.end_walk(walk);
        k.on_event(SchedEvent::EngineFree);
        assert!(!k.should_walk(), "pure decode steps must not re-trigger the walk");
        k.on_event(SchedEvent::Settle);
        assert!(k.should_walk());
    }

    #[test]
    fn control_plan_dirties_like_any_decision_changing_event() {
        let mut k: Kernel<u32> = Kernel::new();
        k.on_event(SchedEvent::Arrival { h: 1, priority: Priority::Normal });
        let mut walk = k.begin_walk();
        while let Some((h, high)) = walk.next() {
            walk.settle(h, high, 1, Placement::Defer);
        }
        k.end_walk(walk);
        assert!(!k.should_walk());
        // A plan change can flip an elastic decision, so it must re-walk.
        k.on_event(SchedEvent::ControlPlan);
        assert!(k.should_walk());
    }

    #[test]
    fn engine_rejoin_dirties_and_heals_candidacy_through_the_kernel() {
        // The full heal path as both drivers run it: fail → clear_failed
        // (quarantine) → probe ok → clear_quarantine + refresh + rejoin
        // event.  The deferred request becomes schedulable again.
        let mut k: Kernel<u32> = Kernel::new();
        k.index.refresh_engine(0, true, true);
        k.index.mark_failed(0);
        k.on_event(SchedEvent::Arrival { h: 1, priority: Priority::Normal });
        let mut walk = k.begin_walk();
        while let Some((h, high)) = walk.next() {
            walk.settle(h, high, 1, Placement::Defer);
        }
        k.end_walk(walk);
        assert!(!k.should_walk());
        k.index.clear_failed(0);
        k.index.clear_quarantine(0);
        k.index.refresh_engine(0, true, true);
        k.on_event(SchedEvent::EngineRejoin { engine: 0 });
        assert!(k.should_walk(), "rejoin must re-trigger the walk");
        assert_eq!(k.index.dp_candidates(), 0b1);
    }

    #[test]
    fn trace_records_decisions_in_walk_order() {
        let mut k: Kernel<u32> = Kernel::new();
        k.enable_trace();
        k.on_event(SchedEvent::Arrival { h: 1, priority: Priority::Normal });
        k.on_event(SchedEvent::Arrival { h: 2, priority: Priority::High });
        let mut walk = k.begin_walk();
        while let Some((h, high)) = walk.next() {
            let p = if high { Placement::Tp { width: 4 } } else { Placement::Defer };
            walk.settle(h, high, h as u64, p);
        }
        k.end_walk(walk);
        assert_eq!(
            k.take_trace(),
            vec![
                SchedAction { rid: 2, placement: Placement::Tp { width: 4 } },
                SchedAction { rid: 1, placement: Placement::Defer },
            ]
        );
    }

    #[test]
    fn aborted_walk_keeps_leftovers_after_requeues() {
        let mut k: Kernel<u32> = Kernel::new();
        for h in 0..4u32 {
            k.on_event(SchedEvent::Arrival { h, priority: Priority::Normal });
        }
        let mut walk = k.begin_walk();
        // Process two (one defers), then abort mid-walk.
        let (h, high) = walk.next().unwrap();
        walk.settle(h, high, 0, Placement::Defer);
        let (h, high) = walk.next().unwrap();
        walk.settle(h, high, 1, Placement::Dp { unit: 0, backfill: false });
        k.end_walk(walk);
        let left: Vec<u32> = k.rings.iter().copied().collect();
        assert_eq!(left, vec![0, 2, 3], "requeues first, then undrained leftovers");
    }
}
