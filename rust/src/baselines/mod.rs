//! Baseline systems the paper compares against (§6.1.2):
//!
//! * **Static DP** — every request runs on one engine; long-context
//!   requests that exceed a single engine's KV capacity are *rejected*
//!   (the OOM failure motivating Use Case 3).
//! * **Static TP** — every request runs on a fixed p-way group: best
//!   latency at low load, throughput-limited under bursts.
//! * **Shift Parallelism** (arXiv:2509.16495) — the SoTA dynamic baseline:
//!   runtime switching between latency-optimal TP and throughput-oriented
//!   sequence parallelism by exploiting KV-cache invariance.  It has no DP
//!   fan-out: all engines always form one group; what changes is whether a
//!   batch is executed in TP (tight latency) or SP (token-parallel
//!   throughput) mode.  Its cost behavior is modeled in the simulator
//!   (`sim::shift`); on the real path only static DP/TP are meaningful
//!   comparators at this scale.

use crate::coordinator::policy::{ModeDecision, Policy, Snapshot};
use crate::workload::Priority;

/// Static DP: the "scale-out only" deployment.
pub struct StaticDpPolicy;

impl Policy for StaticDpPolicy {
    fn name(&self) -> &'static str {
        "static-dp"
    }

    fn decide(
        &mut self,
        prompt_len: usize,
        output_len_hint: usize,
        _priority: Priority,
        _tp_demand: Option<usize>,
        snap: &Snapshot,
    ) -> ModeDecision {
        if prompt_len + output_len_hint > snap.dp_capacity_tokens {
            // A static DP deployment OOMs on over-capacity requests.
            ModeDecision::Reject
        } else {
            ModeDecision::Dp
        }
    }
}

/// Static TP at fixed degree p: the "scale-up only" deployment.
pub struct StaticTpPolicy {
    pub p: usize,
}

impl Policy for StaticTpPolicy {
    fn name(&self) -> &'static str {
        "static-tp"
    }

    fn decide(
        &mut self,
        prompt_len: usize,
        output_len_hint: usize,
        _priority: Priority,
        _tp_demand: Option<usize>,
        snap: &Snapshot,
    ) -> ModeDecision {
        if prompt_len + output_len_hint > snap.dp_capacity_tokens * self.p {
            ModeDecision::Reject
        } else {
            ModeDecision::Tp(self.p)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap() -> Snapshot {
        Snapshot {
            now: 0.0,
            queue_len: 0,
            idle_engines: 4,
            n_engines: 4,
            dp_capacity_tokens: 1000,
            max_tp: 4,
            kv_frac: 0.0,
        }
    }

    #[test]
    fn static_dp_rejects_long_context() {
        let mut p = StaticDpPolicy;
        assert_eq!(p.decide(500, 100, Priority::Normal, None, &snap()), ModeDecision::Dp);
        assert_eq!(
            p.decide(1500, 100, Priority::Normal, None, &snap()),
            ModeDecision::Reject
        );
    }

    #[test]
    fn static_tp_always_p() {
        let mut p = StaticTpPolicy { p: 2 };
        assert_eq!(
            p.decide(500, 100, Priority::High, None, &snap()),
            ModeDecision::Tp(2)
        );
        assert_eq!(
            p.decide(1500, 100, Priority::Normal, None, &snap()),
            ModeDecision::Tp(2)
        );
        assert_eq!(
            p.decide(5000, 100, Priority::Normal, None, &snap()),
            ModeDecision::Reject
        );
    }
}
