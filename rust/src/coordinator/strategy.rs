//! Mode-switching strategies (paper §5.2, Fig. 7).
//!
//! When a TP-designated request needs engines that are still running DP
//! work (execution skew), the strategy decides how the transition happens:
//!
//! * `Sequential` — wait for the longest-running DP request on the member
//!   engines to finish (correct but idles capacity; Fig. 7a).
//! * `SoftPreempt` — while waiting, idle member engines speculatively run
//!   the TP request in DP mode; its KV is recomputed under the TP layout at
//!   bind time (decoding is memory-bound, recompute is parallel
//!   compute-bound — a favorable trade; Fig. 7b).
//! * `HardPreempt` — interrupt member engines immediately; their DP
//!   requests stay paused with KV resident (the adaptor's layout
//!   coexistence) and resume without recomputation (Fig. 7c).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Sequential,
    SoftPreempt,
    HardPreempt,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Sequential => "sequential",
            Strategy::SoftPreempt => "soft-preempt",
            Strategy::HardPreempt => "hard-preempt",
        }
    }
}

impl std::str::FromStr for Strategy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "sequential" => Ok(Strategy::Sequential),
            "soft" | "soft-preempt" => Ok(Strategy::SoftPreempt),
            "hard" | "hard-preempt" => Ok(Strategy::HardPreempt),
            _ => anyhow::bail!("unknown strategy '{s}' (sequential|soft|hard)"),
        }
    }
}

/// Switch-transition tuning (ISSUE 3): how aggressively the coordinator
/// keeps capacity busy *through* a DP→TP transition.
///
/// With `backfill = false` (the default) the transition path is exactly the
/// PR-1/2 behavior: once a TP bind is pending on a group, every member is
/// masked out of elastic assignment and the group switches in one shot when
/// the last resident request drains — the differential harness stays
/// byte-identical.
///
/// With `backfill = true`:
///
/// * **Drain backfill** — draining members may still accept elastic DP
///   requests whose predicted cost (the scheduling kernel's `backfill_fit`
///   in calibrated wall-clock seconds — the simulator's exact predicate;
///   prefill charged twice because prefill-first issue displaces resident
///   decodes) fits inside the group's drain horizon (the largest predicted
///   remaining work among resident requests), bounded to
///   `max_backfill_per_engine` concurrent backfill requests per member.
///   Capacity that would idle behind the slowest straggler serves short
///   requests instead.
/// * **Incremental settle** — members whose own work has drained are
///   switched into the target TP mode one by one instead of waiting for the
///   last straggler, so the final promotion only pays the stragglers' mode
///   RPCs.
/// With `migrate = true` (ISSUE 4):
///
/// * **Layout-preserving KV migration** — when a soft-preempted speculative
///   request is promoted into its TP group, its DP-layout KV is *carried*
///   instead of recomputed: the home engine re-tags a prefix of the
///   request's blocks in place as TP shard views (Eqs. 2–3 make the bytes
///   layout-invariant — zero copy), the other members allocate fresh blocks
///   and receive their head slices through `Communicator::scatter_into`,
///   and decoding resumes exactly where it left off.  Per request the
///   coordinator applies the cost model's migrate-vs-recompute rule
///   (`CostModel::migrate_wins`: KV bytes over the link vs re-prefill
///   FLOPs), the identical rule the simulator event core applies, so the
///   two paths stay byte-comparable.  Off (the default) keeps the PR-1/3
///   recompute path untouched.
#[derive(Clone, Copy, Debug)]
pub struct SwitchConfig {
    pub backfill: bool,
    /// Max concurrently-resident backfill requests per draining engine.
    pub max_backfill_per_engine: usize,
    /// Admission slack: a request is backfillable when its predicted
    /// completion (kernel `backfill_fit`) lands within `backfill_margin` x
    /// the drain-horizon window.
    pub backfill_margin: f64,
    /// Layout-preserving KV migration on DP→TP promotion (`--switch-migrate`).
    pub migrate: bool,
}

impl Default for SwitchConfig {
    fn default() -> Self {
        SwitchConfig {
            backfill: false,
            max_backfill_per_engine: 1,
            // Tuned by the margin sweep in `benches/sched_hotpath.rs`
            // (ISSUE 6): on the stub testbed against the calibrated cost
            // model, 1.2 admits the short-request tail that a strict 1.0
            // margin rejects without measurably extending drains; past
            // ~1.5 drain extensions start eating the win.
            backfill_margin: 1.2,
            migrate: false,
        }
    }
}

/// Lockstep-watchdog + graceful-degradation tuning (ISSUE 6).
///
/// With `enabled = false` (the default) the coordinator collects engine
/// replies with the exact blocking receives the pre-watchdog code ran —
/// byte-identical, the same differential-gate discipline as
/// `--switch-backfill`/`--switch-migrate`.  With it on, every reply is
/// deadline-bounded: a stall inside the budget is ridden out (counted,
/// not escalated), a stall past `reply_timeout + retries × backoff` or a
/// disconnected worker escalates to a typed `EngineFault`, and the
/// coordinator degrades gracefully — the failed engine fail-stops, its
/// groups dissolve to the survivors, and its requests are requeued for
/// recompute up to `max_request_retries` times before being rejected.
///
/// Invariant: the total reply budget must exceed the communicator
/// timeout, so the survivors of a dead peer's collective get to report
/// the timeout as a step error (absorbed, retried) before the watchdog
/// would misclassify *them* as failed.
#[derive(Clone, Copy, Debug)]
pub struct WatchdogConfig {
    pub enabled: bool,
    /// First reply deadline per engine command.
    pub reply_timeout: std::time::Duration,
    /// Bounded retries after the first deadline; each retry extends the
    /// deadline by a further `backoff` (linear backoff).
    pub retries: u32,
    pub backoff: std::time::Duration,
    /// Times a request may be rescued off a failed engine and requeued
    /// before it is rejected instead.
    pub max_request_retries: u32,
}

impl Default for WatchdogConfig {
    fn default() -> Self {
        WatchdogConfig {
            enabled: false,
            // 5s + 10s + 15s + 20s = 50s total budget, comfortably above
            // the 30s default communicator timeout (see invariant above).
            reply_timeout: std::time::Duration::from_secs(5),
            retries: 3,
            backoff: std::time::Duration::from_secs(5),
            max_request_retries: 2,
        }
    }
}
