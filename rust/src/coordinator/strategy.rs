//! Mode-switching strategies (paper §5.2, Fig. 7).
//!
//! When a TP-designated request needs engines that are still running DP
//! work (execution skew), the strategy decides how the transition happens:
//!
//! * `Sequential` — wait for the longest-running DP request on the member
//!   engines to finish (correct but idles capacity; Fig. 7a).
//! * `SoftPreempt` — while waiting, idle member engines speculatively run
//!   the TP request in DP mode; its KV is recomputed under the TP layout at
//!   bind time (decoding is memory-bound, recompute is parallel
//!   compute-bound — a favorable trade; Fig. 7b).
//! * `HardPreempt` — interrupt member engines immediately; their DP
//!   requests stay paused with KV resident (the adaptor's layout
//!   coexistence) and resume without recomputation (Fig. 7c).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Sequential,
    SoftPreempt,
    HardPreempt,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Sequential => "sequential",
            Strategy::SoftPreempt => "soft-preempt",
            Strategy::HardPreempt => "hard-preempt",
        }
    }
}

impl std::str::FromStr for Strategy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "sequential" => Ok(Strategy::Sequential),
            "soft" | "soft-preempt" => Ok(Strategy::SoftPreempt),
            "hard" | "hard-preempt" => Ok(Strategy::HardPreempt),
            _ => anyhow::bail!("unknown strategy '{s}' (sequential|soft|hard)"),
        }
    }
}

/// Switch-transition tuning (ISSUE 3): how aggressively the coordinator
/// keeps capacity busy *through* a DP→TP transition.
///
/// With `backfill = false` (the default) the transition path is exactly the
/// PR-1/2 behavior: once a TP bind is pending on a group, every member is
/// masked out of elastic assignment and the group switches in one shot when
/// the last resident request drains — the differential harness stays
/// byte-identical.
///
/// With `backfill = true`:
///
/// * **Drain backfill** — draining members may still accept elastic DP
///   requests whose predicted cost (the scheduling kernel's `backfill_fit`
///   in calibrated wall-clock seconds — the simulator's exact predicate;
///   prefill charged twice because prefill-first issue displaces resident
///   decodes) fits inside the group's drain horizon (the largest predicted
///   remaining work among resident requests), bounded to
///   `max_backfill_per_engine` concurrent backfill requests per member.
///   Capacity that would idle behind the slowest straggler serves short
///   requests instead.
/// * **Incremental settle** — members whose own work has drained are
///   switched into the target TP mode one by one instead of waiting for the
///   last straggler, so the final promotion only pays the stragglers' mode
///   RPCs.
/// With `migrate = true` (ISSUE 4):
///
/// * **Layout-preserving KV migration** — when a soft-preempted speculative
///   request is promoted into its TP group, its DP-layout KV is *carried*
///   instead of recomputed: the home engine re-tags a prefix of the
///   request's blocks in place as TP shard views (Eqs. 2–3 make the bytes
///   layout-invariant — zero copy), the other members allocate fresh blocks
///   and receive their head slices through `Communicator::scatter_into`,
///   and decoding resumes exactly where it left off.  Per request the
///   coordinator applies the cost model's migrate-vs-recompute rule
///   (`CostModel::migrate_wins`: KV bytes over the link vs re-prefill
///   FLOPs), the identical rule the simulator event core applies, so the
///   two paths stay byte-comparable.  Off (the default) keeps the PR-1/3
///   recompute path untouched.
#[derive(Clone, Copy, Debug)]
pub struct SwitchConfig {
    pub backfill: bool,
    /// Max concurrently-resident backfill requests per draining engine.
    pub max_backfill_per_engine: usize,
    /// Admission slack: a request is backfillable when its predicted
    /// completion (kernel `backfill_fit`) lands within `backfill_margin` x
    /// the drain-horizon window.
    pub backfill_margin: f64,
    /// Layout-preserving KV migration on DP→TP promotion (`--switch-migrate`).
    pub migrate: bool,
}

impl Default for SwitchConfig {
    fn default() -> Self {
        SwitchConfig {
            backfill: false,
            max_backfill_per_engine: 1,
            backfill_margin: 1.0,
            migrate: false,
        }
    }
}
