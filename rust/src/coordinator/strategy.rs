//! Mode-switching strategies (paper §5.2, Fig. 7).
//!
//! When a TP-designated request needs engines that are still running DP
//! work (execution skew), the strategy decides how the transition happens:
//!
//! * `Sequential` — wait for the longest-running DP request on the member
//!   engines to finish (correct but idles capacity; Fig. 7a).
//! * `SoftPreempt` — while waiting, idle member engines speculatively run
//!   the TP request in DP mode; its KV is recomputed under the TP layout at
//!   bind time (decoding is memory-bound, recompute is parallel
//!   compute-bound — a favorable trade; Fig. 7b).
//! * `HardPreempt` — interrupt member engines immediately; their DP
//!   requests stay paused with KV resident (the adaptor's layout
//!   coexistence) and resume without recomputation (Fig. 7c).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Sequential,
    SoftPreempt,
    HardPreempt,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Sequential => "sequential",
            Strategy::SoftPreempt => "soft-preempt",
            Strategy::HardPreempt => "hard-preempt",
        }
    }
}

impl std::str::FromStr for Strategy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "sequential" => Ok(Strategy::Sequential),
            "soft" | "soft-preempt" => Ok(Strategy::SoftPreempt),
            "hard" | "hard-preempt" => Ok(Strategy::HardPreempt),
            _ => anyhow::bail!("unknown strategy '{s}' (sequential|soft|hard)"),
        }
    }
}

/// Switch-transition tuning (ISSUE 3): how aggressively the coordinator
/// keeps capacity busy *through* a DP→TP transition.
///
/// With `backfill = false` (the default) the transition path is exactly the
/// PR-1/2 behavior: once a TP bind is pending on a group, every member is
/// masked out of elastic assignment and the group switches in one shot when
/// the last resident request drains — the differential harness stays
/// byte-identical.
///
/// With `backfill = true`:
///
/// * **Drain backfill** — draining members may still accept elastic DP
///   requests whose predicted cost (the scheduling kernel's `backfill_fit`
///   in calibrated wall-clock seconds — the simulator's exact predicate;
///   prefill charged twice because prefill-first issue displaces resident
///   decodes) fits inside the group's drain horizon (the largest predicted
///   remaining work among resident requests), bounded to
///   `max_backfill_per_engine` concurrent backfill requests per member.
///   Capacity that would idle behind the slowest straggler serves short
///   requests instead.
/// * **Incremental settle** — members whose own work has drained are
///   switched into the target TP mode one by one instead of waiting for the
///   last straggler, so the final promotion only pays the stragglers' mode
///   RPCs.
/// With `migrate = true` (ISSUE 4):
///
/// * **Layout-preserving KV migration** — when a soft-preempted speculative
///   request is promoted into its TP group, its DP-layout KV is *carried*
///   instead of recomputed: the home engine re-tags a prefix of the
///   request's blocks in place as TP shard views (Eqs. 2–3 make the bytes
///   layout-invariant — zero copy), the other members allocate fresh blocks
///   and receive their head slices through `Communicator::scatter_into`,
///   and decoding resumes exactly where it left off.  Per request the
///   coordinator applies the cost model's migrate-vs-recompute rule
///   (`CostModel::migrate_wins`: KV bytes over the link vs re-prefill
///   FLOPs), the identical rule the simulator event core applies, so the
///   two paths stay byte-comparable.  Off (the default) keeps the PR-1/3
///   recompute path untouched.
#[derive(Clone, Copy, Debug)]
pub struct SwitchConfig {
    pub backfill: bool,
    /// Max concurrently-resident backfill requests per draining engine.
    pub max_backfill_per_engine: usize,
    /// Admission slack: a request is backfillable when its predicted
    /// completion (kernel `backfill_fit`) lands within `backfill_margin` x
    /// the drain-horizon window.
    pub backfill_margin: f64,
    /// Layout-preserving KV migration on DP→TP promotion (`--switch-migrate`).
    pub migrate: bool,
}

impl Default for SwitchConfig {
    fn default() -> Self {
        SwitchConfig {
            backfill: false,
            max_backfill_per_engine: 1,
            // Tuned by the margin sweep in `benches/sched_hotpath.rs`
            // (ISSUE 6): on the stub testbed against the calibrated cost
            // model, 1.2 admits the short-request tail that a strict 1.0
            // margin rejects without measurably extending drains; past
            // ~1.5 drain extensions start eating the win.
            backfill_margin: 1.2,
            migrate: false,
        }
    }
}

/// Lockstep-watchdog + graceful-degradation tuning (ISSUE 6).
///
/// With `enabled = false` (the default) the coordinator collects engine
/// replies with the exact blocking receives the pre-watchdog code ran —
/// byte-identical, the same differential-gate discipline as
/// `--switch-backfill`/`--switch-migrate`.  With it on, every reply is
/// deadline-bounded: a stall inside the budget is ridden out (counted,
/// not escalated), a stall past `reply_timeout + retries × backoff` or a
/// disconnected worker escalates to a typed `EngineFault`, and the
/// coordinator degrades gracefully — the failed engine fail-stops, its
/// groups dissolve to the survivors, and its requests are requeued for
/// recompute up to `max_request_retries` times before being rejected.
///
/// Invariant: the total reply budget must exceed the communicator
/// timeout, so the survivors of a dead peer's collective get to report
/// the timeout as a step error (absorbed, retried) before the watchdog
/// would misclassify *them* as failed.
#[derive(Clone, Copy, Debug)]
pub struct WatchdogConfig {
    pub enabled: bool,
    /// First reply deadline per engine command.
    pub reply_timeout: std::time::Duration,
    /// Bounded retries after the first deadline; each retry extends the
    /// deadline by a further `backoff` (linear backoff).
    pub retries: u32,
    pub backoff: std::time::Duration,
    /// Times a request may be rescued off a failed engine and requeued
    /// before it is rejected instead.
    pub max_request_retries: u32,
    /// Consecutive degraded step-error replies from one engine before it
    /// escalates to fail-stop (anti-livelock: degraded-retry must be
    /// bounded).  Was a hard-coded const before ISSUE 8; 32 remains the
    /// default.
    pub max_step_err_streak: u32,
    /// Idle scheduler iterations (watchdog on, failed engines present)
    /// before waiting requests that no surviving capacity can ever host
    /// are swept into rejection instead of hanging the trace.
    pub stranded_sweep_iters: usize,
    /// Fail-recover (ISSUE 8, `--recover`): revive transiently-dead
    /// engines and rejoin them through quarantine + probe.  Off by
    /// default — the PR-6 fail-stop path stays byte-identical.
    pub recover: bool,
    /// Rejoin attempts per engine before recovery re-escalates to
    /// permanent fail-stop (crash-loop anti-livelock, same rule as
    /// `max_step_err_streak`).  The budget is cumulative per engine, not
    /// per fault, so a crash loop can never ride the budget forever.
    pub max_rejoin_attempts: u32,
    /// Base delay before the first rejoin attempt; doubles per attempt
    /// (exponential backoff).
    pub rejoin_backoff: std::time::Duration,
}

impl Default for WatchdogConfig {
    fn default() -> Self {
        WatchdogConfig {
            enabled: false,
            // 5s + 10s + 15s + 20s = 50s total budget, comfortably above
            // the 30s default communicator timeout (see invariant above,
            // now asserted by `WatchdogConfig::validate`).
            reply_timeout: std::time::Duration::from_secs(5),
            retries: 3,
            backoff: std::time::Duration::from_secs(5),
            max_request_retries: 2,
            max_step_err_streak: 32,
            stranded_sweep_iters: 1_000,
            recover: false,
            max_rejoin_attempts: 3,
            rejoin_backoff: std::time::Duration::from_secs(1),
        }
    }
}

impl WatchdogConfig {
    /// Total per-command reply budget: the first deadline plus every
    /// linear-backoff retry window,
    /// `Σ_{i=0..=retries} (reply_timeout + i·backoff)`.
    /// Defaults: 5+10+15+20 s = 50 s.
    pub fn total_reply_budget(&self) -> std::time::Duration {
        let n = self.retries;
        self.reply_timeout * (n + 1) + self.backoff * (n * (n + 1) / 2)
    }

    /// Check the config's internal ordering invariants against the
    /// communicator timeout it will run next to.  The load-bearing one
    /// (previously prose-only): the total reply budget must exceed the
    /// communicator timeout, so survivors of a dead peer's collective get
    /// to report `CollectiveTimeout` as an absorbable step error before
    /// the watchdog misclassifies *them* as failed.
    pub fn validate(&self, comm_timeout: std::time::Duration) -> anyhow::Result<()> {
        if !self.enabled {
            if self.recover {
                anyhow::bail!("--recover requires the watchdog (faults are only survivable with it on)");
            }
            return Ok(());
        }
        if self.total_reply_budget() <= comm_timeout {
            anyhow::bail!(
                "watchdog total reply budget {:?} must exceed the communicator timeout {:?} \
                 (survivors must surface a dead peer's collective timeout before being \
                 misclassified as failed themselves)",
                self.total_reply_budget(),
                comm_timeout
            );
        }
        if self.max_step_err_streak == 0 {
            anyhow::bail!("max_step_err_streak must be >= 1 (0 would fail-stop on any step error)");
        }
        if self.recover && self.max_rejoin_attempts == 0 {
            anyhow::bail!("--recover with max_rejoin_attempts = 0 can never rejoin anything");
        }
        Ok(())
    }
}

/// Pipelined-execution tuning (ISSUE 9, `--overlap`): break the lockstep
/// protocol's strict build→issue→collect serialization without changing a
/// single scheduling decision.
///
/// With `enabled = false` (the default) the step loop is exactly the PR-8
/// behavior — byte-identical outputs, journals, and counters; the same
/// differential-gate discipline as every other flag.  With it on, three
/// overlaps open up, each individually gateable:
///
/// * **`double_buffer`** — two decode-batch arenas per engine.  While batch
///   N executes, the coordinator pre-materializes batch N+1's block-table
///   views into the back arena, stamped with the exact `(handle, position)`
///   set it was built from.  At the next issue the stamp is compared
///   against the live scheduler state (the *bounded-staleness rule*): on a
///   match the arenas swap (the lockstep reply was the slot-swap barrier)
///   and only per-slot tokens/seq-lens are patched; on any divergence —
///   finish, preemption, recovery, a kernel decision that changed the
///   batch — the prebuilt arena is discarded and the batch is rebuilt from
///   scratch.  The prebuilt batch is a cached materialization of decisions
///   already made, never a decision source, so kernel decision traces are
///   byte-identical by construction.
/// * **`async_migrate`** — `EngineCmd::KvMigrate` scatters become tagged
///   in-flight transfers: the coordinator issues them and returns to the
///   step loop instead of blocking inside `settle_groups`, so non-member
///   engines keep decoding through the transfer window.  The transfer is
///   drained at the next safe point (settle entry / idle / shutdown);
///   at most one transfer is in flight per engine (the bounded engine
///   channels hold `CHANNEL_DEPTH = 2` commands — a second outstanding
///   migrate could deadlock the lockstep).
/// * **`co_issue`** — an engine with both a prefill chunk and a decode
///   batch pending receives them in one `EngineCmd::CoIssue` envelope
///   (one reply, one fault-clock tick) so the backend can interleave them.
#[derive(Clone, Copy, Debug)]
pub struct OverlapConfig {
    pub enabled: bool,
    /// Double-buffered step arenas (overlap 1).
    pub double_buffer: bool,
    /// Asynchronous KV-migration collectives (overlap 2).
    pub async_migrate: bool,
    /// Prefill/decode co-issue envelopes (overlap 3).
    pub co_issue: bool,
}

impl Default for OverlapConfig {
    fn default() -> Self {
        // Sub-knobs default on so `--overlap` alone arms all three; the
        // master switch off keeps the whole path byte-identical.
        OverlapConfig { enabled: false, double_buffer: true, async_migrate: true, co_issue: true }
    }
}

impl OverlapConfig {
    #[inline]
    pub fn double_buffer_on(&self) -> bool {
        self.enabled && self.double_buffer
    }

    #[inline]
    pub fn async_migrate_on(&self) -> bool {
        self.enabled && self.async_migrate
    }

    #[inline]
    pub fn co_issue_on(&self) -> bool {
        self.enabled && self.co_issue
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn overlap_defaults_off_with_all_sub_knobs_armed() {
        let o = OverlapConfig::default();
        assert!(!o.enabled);
        assert!(o.double_buffer && o.async_migrate && o.co_issue);
        // Master switch gates every sub-knob.
        assert!(!o.double_buffer_on() && !o.async_migrate_on() && !o.co_issue_on());
        let on = OverlapConfig { enabled: true, ..OverlapConfig::default() };
        assert!(on.double_buffer_on() && on.async_migrate_on() && on.co_issue_on());
        let partial = OverlapConfig { enabled: true, co_issue: false, ..OverlapConfig::default() };
        assert!(partial.double_buffer_on() && !partial.co_issue_on());
    }

    #[test]
    fn watchdog_budget_ordering_is_validated() {
        let mut w = WatchdogConfig { enabled: true, ..WatchdogConfig::default() };
        // 5 + 10 + 15 + 20 s of deadline windows.
        assert_eq!(w.total_reply_budget(), Duration::from_secs(50));
        w.validate(Duration::from_secs(30)).unwrap();
        // Budget == timeout is not enough; neither is below.
        assert!(w.validate(Duration::from_secs(50)).is_err());
        assert!(w.validate(Duration::from_secs(60)).is_err());
        w.max_step_err_streak = 0;
        assert!(w.validate(Duration::from_secs(30)).is_err());
    }

    #[test]
    fn recover_requires_watchdog_and_a_rejoin_budget() {
        let w = WatchdogConfig { recover: true, ..WatchdogConfig::default() };
        assert!(w.validate(Duration::from_secs(30)).is_err());
        let w = WatchdogConfig {
            enabled: true,
            recover: true,
            max_rejoin_attempts: 0,
            ..WatchdogConfig::default()
        };
        assert!(w.validate(Duration::from_secs(30)).is_err());
        let w = WatchdogConfig { enabled: true, recover: true, ..WatchdogConfig::default() };
        w.validate(Duration::from_secs(30)).unwrap();
    }

    #[test]
    fn disabled_watchdog_validates_vacuously() {
        WatchdogConfig::default().validate(Duration::from_secs(999)).unwrap();
    }
}
