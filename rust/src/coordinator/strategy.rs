//! Mode-switching strategies (paper §5.2, Fig. 7).
//!
//! When a TP-designated request needs engines that are still running DP
//! work (execution skew), the strategy decides how the transition happens:
//!
//! * `Sequential` — wait for the longest-running DP request on the member
//!   engines to finish (correct but idles capacity; Fig. 7a).
//! * `SoftPreempt` — while waiting, idle member engines speculatively run
//!   the TP request in DP mode; its KV is recomputed under the TP layout at
//!   bind time (decoding is memory-bound, recompute is parallel
//!   compute-bound — a favorable trade; Fig. 7b).
//! * `HardPreempt` — interrupt member engines immediately; their DP
//!   requests stay paused with KV resident (the adaptor's layout
//!   coexistence) and resume without recomputation (Fig. 7c).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Sequential,
    SoftPreempt,
    HardPreempt,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Sequential => "sequential",
            Strategy::SoftPreempt => "soft-preempt",
            Strategy::HardPreempt => "hard-preempt",
        }
    }
}

impl std::str::FromStr for Strategy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "sequential" => Ok(Strategy::Sequential),
            "soft" | "soft-preempt" => Ok(Strategy::SoftPreempt),
            "hard" | "hard-preempt" => Ok(Strategy::HardPreempt),
            _ => anyhow::bail!("unknown strategy '{s}' (sequential|soft|hard)"),
        }
    }
}
