//! Mode-determination policy (Algorithm 1, step 3).
//!
//! The policy decides, per request, whether it executes as DP or inside a
//! TP group — this is where the paper's three user scenarios (§2.3) are
//! encoded.  The same trait drives the real thread-cluster coordinator and
//! the discrete-event simulator, so the policy code under benchmark is
//! byte-identical in both.

use crate::workload::Priority;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModeDecision {
    Dp,
    Tp(usize),
    /// The request cannot be served under this policy (e.g. long-context
    /// under static DP): counted as an OOM failure, the paper's Use-Case-3
    /// motivation.
    Reject,
}

/// System snapshot the policy sees each scheduling iteration.
#[derive(Clone, Copy, Debug)]
pub struct Snapshot {
    /// Scheduler-clock time of this iteration (seconds).  `FlyingPolicy`
    /// ignores it; the control plane's `AdaptivePolicy` keys its telemetry
    /// window and control ticks off it.
    pub now: f64,
    pub queue_len: usize,
    pub idle_engines: usize,
    pub n_engines: usize,
    /// Max tokens (prompt + output) a single DP engine can cache.
    pub dp_capacity_tokens: usize,
    /// Widest supported TP degree for this model.
    pub max_tp: usize,
    /// Cluster-wide KV utilization in [0, 1] (committed / capacity).
    pub kv_frac: f64,
}

pub trait Policy: Send {
    fn name(&self) -> &'static str;

    fn decide(
        &mut self,
        prompt_len: usize,
        output_len_hint: usize,
        priority: Priority,
        tp_demand: Option<usize>,
        snap: &Snapshot,
    ) -> ModeDecision;

    /// Per-request decision with the request's id attached.  The scheduler
    /// re-decides every waiting request each iteration, so a request that
    /// cannot bind is decided many times; stateless policies don't care (the
    /// default forwards to [`Policy::decide`]) but stateful ones — e.g. the
    /// control plane's telemetry tap — override this to deduplicate repeated
    /// attempts by id instead of over-counting requeues as fresh arrivals.
    fn decide_for(
        &mut self,
        _rid: u64,
        prompt_len: usize,
        output_len_hint: usize,
        priority: Priority,
        tp_demand: Option<usize>,
        snap: &Snapshot,
    ) -> ModeDecision {
        self.decide(prompt_len, output_len_hint, priority, tp_demand, snap)
    }

    /// Audit record of the policy's most recent control tick, if it runs a
    /// control plane (the flight recorder journals it; consumers dedupe on
    /// `TickInfo::seq`).  Plain heuristics have no ticks — default None.
    fn last_tick(&self) -> Option<crate::control::TickInfo> {
        None
    }
}

/// FLYING SERVING's workload-aware policy:
///   * Use Case 3 — requests that exceed DP KV capacity get the narrowest
///     TP degree that fits (memory-driven binding).
///   * Use Case 2 — high-priority requests get a TP binding for latency.
///   * Use Case 1 — under light load (queue fits in the idle engines),
///     opportunistically widen to TP to cut latency; under bursts, stay DP
///     to maximize concurrency and drain the queue.
pub struct FlyingPolicy {
    /// Queue length (relative to engine count) above which the system is
    /// considered bursting and everything stays DP.
    pub burst_factor: f64,
}

impl Default for FlyingPolicy {
    fn default() -> Self {
        FlyingPolicy { burst_factor: 1.0 }
    }
}

impl Policy for FlyingPolicy {
    fn name(&self) -> &'static str {
        "flying"
    }

    fn decide(
        &mut self,
        prompt_len: usize,
        output_len_hint: usize,
        priority: Priority,
        tp_demand: Option<usize>,
        snap: &Snapshot,
    ) -> ModeDecision {
        // The constraint tiers (explicit demand / memory / priority) are the
        // scheduling kernel's single definition — shared verbatim with the
        // control plane's `plan_decision`, never re-implemented per path.
        if let Some(d) =
            crate::sched::constrained(prompt_len, output_len_hint, priority, tp_demand, snap)
        {
            return d;
        }
        // Use Case 1: load-adaptive.
        let bursting = snap.queue_len as f64 > self.burst_factor * snap.n_engines as f64;
        if !bursting && snap.idle_engines >= snap.n_engines.min(snap.max_tp) {
            ModeDecision::Tp(snap.max_tp.min(snap.n_engines))
        } else {
            ModeDecision::Dp
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(queue: usize, idle: usize) -> Snapshot {
        Snapshot {
            now: 0.0,
            queue_len: queue,
            idle_engines: idle,
            n_engines: 4,
            dp_capacity_tokens: 1000,
            max_tp: 4,
            kv_frac: 0.0,
        }
    }

    #[test]
    fn light_load_widens_to_tp() {
        let mut p = FlyingPolicy::default();
        assert_eq!(
            p.decide(100, 50, Priority::Normal, None, &snap(0, 4)),
            ModeDecision::Tp(4)
        );
    }

    #[test]
    fn burst_stays_dp() {
        let mut p = FlyingPolicy::default();
        assert_eq!(
            p.decide(100, 50, Priority::Normal, None, &snap(20, 0)),
            ModeDecision::Dp
        );
    }

    #[test]
    fn long_context_gets_narrowest_fitting_tp_even_under_burst() {
        let mut p = FlyingPolicy::default();
        assert_eq!(
            p.decide(1500, 100, Priority::Normal, None, &snap(20, 0)),
            ModeDecision::Tp(2)
        );
        assert_eq!(
            p.decide(3500, 100, Priority::Normal, None, &snap(20, 0)),
            ModeDecision::Tp(4)
        );
    }

    #[test]
    fn impossible_context_rejected() {
        let mut p = FlyingPolicy::default();
        assert_eq!(
            p.decide(10_000, 0, Priority::Normal, None, &snap(0, 4)),
            ModeDecision::Reject
        );
    }

    #[test]
    fn priority_binds_tp_even_when_busy() {
        let mut p = FlyingPolicy::default();
        // Priority takes at most half the cluster (4 engines -> width 2) so
        // best-effort traffic keeps DP engines.
        assert_eq!(
            p.decide(100, 50, Priority::High, None, &snap(20, 0)),
            ModeDecision::Tp(2)
        );
    }

    #[test]
    fn explicit_demand_clamped_to_max() {
        let mut p = FlyingPolicy::default();
        assert_eq!(
            p.decide(10, 10, Priority::Normal, Some(8), &snap(0, 4)),
            ModeDecision::Tp(4)
        );
    }

    // ---- decision-boundary coverage ------------------------------------

    #[test]
    fn dp_capacity_boundary_is_inclusive() {
        let mut p = FlyingPolicy::default();
        // total == capacity stays in the elastic path (DP under burst)...
        assert_eq!(
            p.decide(900, 100, Priority::Normal, None, &snap(20, 0)),
            ModeDecision::Dp
        );
        // ...one token over crosses into memory-driven TP binding.
        assert_eq!(
            p.decide(901, 100, Priority::Normal, None, &snap(20, 0)),
            ModeDecision::Tp(2)
        );
    }

    #[test]
    fn long_context_reject_boundary_at_max_tp() {
        let mut p = FlyingPolicy::default();
        // cap * max_tp = 4000: the widest group exactly fits...
        assert_eq!(
            p.decide(3900, 100, Priority::Normal, None, &snap(0, 4)),
            ModeDecision::Tp(4)
        );
        // ...and one more token is unservable at any width.
        assert_eq!(
            p.decide(3901, 100, Priority::Normal, None, &snap(0, 4)),
            ModeDecision::Reject
        );
    }

    #[test]
    fn priority_width_is_load_independent() {
        // Use Case 2 binds the same half-cluster group whether the node is
        // fully idle or fully saturated — priority must not starve under
        // load, and must not over-claim engines when idle.
        let mut p = FlyingPolicy::default();
        let idle = p.decide(100, 50, Priority::High, None, &snap(0, 4));
        let saturated = p.decide(100, 50, Priority::High, None, &snap(50, 0));
        assert_eq!(idle, ModeDecision::Tp(2));
        assert_eq!(idle, saturated);
    }

    #[test]
    fn priority_long_context_takes_memory_width_not_priority_width() {
        // A high-priority request that exceeds DP capacity is bound by the
        // memory constraint (narrowest fitting width), not the fixed
        // half-cluster priority width.
        let mut p = FlyingPolicy::default();
        assert_eq!(
            p.decide(3500, 100, Priority::High, None, &snap(0, 4)),
            ModeDecision::Tp(4)
        );
    }

    #[test]
    fn burst_threshold_boundary() {
        // bursting iff queue_len > burst_factor * n_engines (strict).
        let mut p = FlyingPolicy::default();
        // queue == n_engines: not bursting, but engines busy -> Dp anyway.
        assert_eq!(
            p.decide(100, 50, Priority::Normal, None, &snap(4, 0)),
            ModeDecision::Dp
        );
        // queue == n_engines with all idle: not bursting -> widen.
        assert_eq!(
            p.decide(100, 50, Priority::Normal, None, &snap(4, 4)),
            ModeDecision::Tp(4)
        );
        // queue just over the threshold: bursting -> Dp even when idle.
        assert_eq!(
            p.decide(100, 50, Priority::Normal, None, &snap(5, 4)),
            ModeDecision::Dp
        );
    }
}
