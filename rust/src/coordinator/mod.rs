//! The FLYING SERVING coordinator (paper §3, §5): a middleware layer between
//! the global task pool and the engine workers that binds subsets of DP
//! engines into TP groups and releases them — the single switching
//! primitive — under a workload-aware policy and a switching strategy.
//!
//! The scheduling loop is Algorithm 1:
//!   ① ProcessInputSocket  — drain arrivals into the task pool
//!   ② SyncWorkload        — a globally-agreed waiting queue (priority,
//!                            arrival) — single-coordinator equivalent of
//!                            the paper's heartbeat all-reduce
//!   ③ Mode determination  — `Policy::decide` per request
//!   ④ KV parameterization — `B_req = B_base · N_eng` via the adaptor's
//!                            layout registration + block allocation
//!   ⑤ Mode signaling      — `SetMode` collective RPC to group members at
//!                            the iteration safe point
//!   ⑥ execute_model       — step commands to engines/groups; publish
//!
//! Engines run lockstep per scheduling iteration (the coordinator waits for
//! every issued step before the next iteration); TP members execute
//! concurrently on their threads and meet in the Communicator Pool's
//! collectives.
//!
//! # Hot-path discipline
//!
//! The steady-state loop performs **zero heap allocations on the
//! coordinator thread once warm** (asserted by the counting allocator in
//! `benches/sched_hotpath.rs`):
//!
//!  * request state lives in a generational dense slab
//!    (`util::slab::Slab<Active>`): the id→handle map is consulted once at
//!    admission, and every per-step access afterwards — scheduling walks,
//!    batch building, token publication, finish — is an O(1) array index
//!    through a `SlabHandle`;
//!  * the waiting queue and admission walk live in the scheduling kernel
//!    (`crate::sched`, ISSUE 5): one FIFO ring per priority level (drained
//!    high-first; arrivals are admitted in time order and requeues keep
//!    relative order) replacing the seed's per-iteration O(n log n) sort,
//!    with ring order, backlog accounting, and every decision predicate
//!    (constraint tiers, least-loaded pick, backfill horizon, migrate
//!    gate) shared verbatim with the simulator — this module is the
//!    driver that turns kernel placements into adaptor/engine commands;
//!  * step inputs live in per-engine `Arc`'d arenas — by the lockstep
//!    protocol the engine has dropped its clone by reply time, so
//!    `Arc::make_mut` recycles the same allocation every step;
//!  * block-table rows are copied from the KV adaptor's incrementally
//!    maintained cache (`table_row_ref_h`), never rebuilt, addressed by the
//!    `KvHandle` captured at registration;
//!  * plan/collection bookkeeping uses `StepScratch` buffers swapped in
//!    and out of the cluster;
//!  * engine lookups (`idle`, unit-mode, draining) are O(1) reads of the
//!    kernel's `EngineIndex` bitmasks, maintained by
//!    `refresh_engine`/`refresh_draining` instead of linear scans per
//!    waiting request.
//!
//! # Switch transitions (ISSUE 3)
//!
//! With `SwitchConfig::backfill` off (default) a pending TP bind masks the
//! whole member set out of elastic assignment until the slowest resident
//! request drains — the PR-1/2 behavior, byte-identical for the harness.
//! With it on, draining members accept bounded elastic work predicted (in
//! calibrated wall-clock seconds — the kernel's `backfill_fit`, the same
//! predicate the simulator runs) to finish inside the drain horizon, and
//! members switch
//! into the target mode *incrementally* as they drain (`Group::settled_mask`)
//! so the final promotion only pays the stragglers' mode RPCs.
//!
//! # KV migration (ISSUE 4)
//!
//! With `SwitchConfig::migrate` on, promoting a soft-preempted speculative
//! request *carries* its cached KV across the DP→TP layout change instead of
//! re-prefilling it: the home engine re-tags a prefix of the request's
//! blocks in place as TP shard views (Eqs. 2–3 make the bytes
//! layout-invariant), the other members allocate fresh blocks and receive
//! their head slices through `Communicator::scatter_into`, and decoding
//! resumes at the same position.  The per-request migrate-vs-recompute
//! decision is `CostModel::migrate_wins` — the identical rule the simulator
//! event core applies.  Off (the default) keeps the PR-1/3 recompute path
//! byte-identical.

pub mod policy;
pub mod strategy;

use std::collections::BTreeMap;
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::comm::CommunicatorPool;
use crate::engine::{DecodeSlot, EngineCmd, EngineHandle, EngineReply, FaultPlan, PrefillChunk};
use crate::error::FaultKind;
use crate::kv::{KvCacheAdaptor, KvHandle, MigrationPlan};
use crate::metrics::{FaultStats, RecSlot, Recorder};
use crate::model::{ModelCfg, StaticShapes};
use crate::sched::{lifecycle, Kernel, LeastLoaded, Placement, PrebuildStamp, SchedEvent};
use crate::sim::{CostModel, HwSpec, PaperModel};
use crate::util::slab::{Slab, SlabHandle};
use crate::workload::Priority;
use policy::{ModeDecision, Policy, Snapshot};
use strategy::{OverlapConfig, Strategy, SwitchConfig, WatchdogConfig};

pub const EOS: i32 = 257;

/// Per-engine fail-recover bookkeeping (ISSUE 8, `--recover`).
///
/// `attempts` is *cumulative per engine* — it is never reset, not even by a
/// successful rejoin — so a crash-looping engine consumes its budget across
/// incarnations and re-escalates to permanent fail-stop instead of riding
/// revive/die cycles forever.
#[derive(Clone, Copy, Debug, Default)]
struct RejoinState {
    /// Rejoin attempts consumed (bounded by `WatchdogConfig::max_rejoin_attempts`).
    attempts: u32,
    /// Deadline of the current exponential-backoff window; `None` until the
    /// next `process_rejoins` pass arms it for a freshly-detected fault.
    next_try: Option<Instant>,
    /// Budget exhausted: the engine is permanently fail-stopped and no
    /// further revive is attempted.
    abandoned: bool,
}

/// A request as submitted to the cluster (the real serving path).
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub priority: Priority,
    pub tp_demand: Option<usize>,
    /// Arrival offset in seconds from cluster-clock zero (trace replay);
    /// requests become visible to the scheduler at this time.
    pub arrival: f64,
}

// No `Done` variant: terminal requests leave the slab immediately
// (`maybe_finish` / the reject path remove the entry), so a live entry is
// always either prefilling or decoding.
#[derive(Clone, Debug, PartialEq)]
enum Phase {
    Prefill,
    Decode,
}

#[derive(Clone, Debug)]
struct Active {
    sr: ServeRequest,
    mode_p: usize,
    /// Engine id (DP) or group start (TP).
    home: usize,
    phase: Phase,
    /// Tokens whose KV is cached (prompt progress + fed output tokens).
    pos: usize,
    emitted: Vec<i32>,
    paused: bool,
    /// Soft-preempt: running speculatively in DP while its TP group drains.
    speculative: bool,
    /// Worst-case block commitment per engine (admission control): the
    /// blocks this request may grow into, reserved at bind time so the pool
    /// can never be overcommitted mid-decode.
    committed: Vec<(usize, usize)>,
    /// Metrics slot, resolved once at admission (O(1) token recording).
    rec: RecSlot,
    /// KV handles per registered engine, resolved once at bind time —
    /// `slot`/`table_row_ref` become O(1) slab lookups through these.
    kvh: Vec<(usize, KvHandle)>,
    /// Admitted onto a draining engine under the backfill predicate.
    backfill: bool,
    /// Fault-recovery count (ISSUE 6): how many times this request was
    /// rescued off a failed engine and requeued for recompute.  Bounded by
    /// `WatchdogConfig::max_request_retries`; past the budget the request
    /// is rejected instead of recovered.
    retries: u32,
}

#[derive(Clone, Debug, Default)]
struct Group {
    p: usize,
    tp_active: Vec<SlabHandle>,
    /// TP requests waiting for this group to finish draining.
    tp_pending: Vec<SlabHandle>,
    /// Members already switched into the target mode by incremental settle
    /// (backfill mode only; always 0 when `SwitchConfig::backfill` is off).
    settled_mask: u64,
}

/// Mode-switch event log (feeds the Table-2 switching-latency measurement).
#[derive(Clone, Debug)]
pub struct SwitchEvent {
    pub t: f64,
    pub group_start: usize,
    pub p_from: usize,
    pub p_to: usize,
    pub latency_s: f64,
}

pub struct ClusterOutcome {
    pub recorder: Recorder,
    pub outputs: BTreeMap<u64, Vec<i32>>,
    pub rejected: Vec<u64>,
    pub switches: Vec<SwitchEvent>,
    /// Scheduling iterations that issued at least one engine step.
    pub n_steps: usize,
    /// Tokens whose cached KV was carried across a DP→TP layout change by
    /// migration instead of being re-prefilled (`SwitchConfig::migrate`;
    /// always 0 with the flag off).
    pub recompute_tokens_avoided: usize,
    /// Prompt tokens adopted from the prefix cache at admission instead of
    /// being prefilled (`--prefix-cache`; always 0 with the flag off).
    pub prefill_tokens_avoided: usize,
    /// Fault/recovery counters (ISSUE 6); all zero on a fault-free run.
    pub fault_stats: FaultStats,
}

/// One work-issue record: enough to collect replies and publish results
/// without any per-step allocation (handles are read back from the engine
/// scratch arenas).
#[derive(Clone, Copy, Debug)]
struct Issued {
    home: usize,
    p: usize,
    is_prefill: bool,
    /// Prefill/decode co-issue envelope (ISSUE 9, `--overlap` only): the
    /// reply is `EngineReply::CoStep`, publishing the stashed prefill
    /// handle *and* the decode batch in `issued_hs`.
    co: bool,
}

/// Per-engine step-input arenas.  The `Arc`s are shared with the engine
/// worker for the duration of one step; `Arc::make_mut` on the next step
/// reuses the allocation (the worker has dropped its clone by reply time).
struct EngineScratch {
    decode_batch: Arc<Vec<DecodeSlot>>,
    prefill_chunk: Arc<PrefillChunk>,
    /// Retired `DecodeSlot`s (with their row buffers) for reuse.
    spare_slots: Vec<DecodeSlot>,
    /// Handles of the requests in the step just issued to this engine
    /// (prefill: one entry; decode: batch order) — read back at publish
    /// time so result routing needs no id lookups.
    issued_hs: Vec<SlabHandle>,
    /// Back arena of the double-buffered pipeline (ISSUE 9,
    /// `OverlapConfig::double_buffer` only): batch N+1's slots, pre-
    /// materialized while batch N executes.  Never in flight — the engine
    /// only ever holds `decode_batch`'s clone, so both arenas are uniquely
    /// owned whenever the coordinator touches them (`Arc::make_mut` never
    /// copies; the lockstep reply is the slot-swap barrier).
    next_batch: Arc<Vec<DecodeSlot>>,
    /// The exact `(handle, position)` sequence `next_batch` was built from
    /// — the bounded-staleness stamp compared against live state at issue
    /// time.  Empty = no prebuild pending.
    next_stamp: PrebuildStamp<SlabHandle>,
    /// Logical id (0/1) of the arena currently in `decode_batch`, for the
    /// `slot_issue`/`slot_retire` journal events; flips on every swap.
    front: u8,
    /// Prefill handle stashed by a co-issue envelope (`issued_hs` carries
    /// the decode batch); taken back when the `CoStep` reply publishes.
    co_prefill_h: Option<SlabHandle>,
}

impl Default for EngineScratch {
    fn default() -> Self {
        EngineScratch {
            decode_batch: Arc::new(Vec::new()),
            prefill_chunk: Arc::new(PrefillChunk::default()),
            spare_slots: Vec::new(),
            issued_hs: Vec::new(),
            next_batch: Arc::new(Vec::new()),
            next_stamp: PrebuildStamp::default(),
            front: 0,
            co_prefill_h: None,
        }
    }
}

/// Reusable coordinator-side buffers (swapped out with `mem::take` for the
/// duration of a call, then restored, so the borrow checker sees disjoint
/// state).
#[derive(Default)]
struct StepScratch {
    covered: Vec<bool>,
    issued: Vec<Issued>,
    decode_hs: Vec<SlabHandle>,
    publish_hs: Vec<SlabHandle>,
    starts: Vec<usize>,
    busy: Vec<SlabHandle>,
    ids: Vec<SlabHandle>,
    /// Held-committed-blocks per engine for the request currently being
    /// promoted (filled once per request in `settle_groups` instead of
    /// re-filtering its committed list for every group member).
    held_by_engine: Vec<usize>,
    /// Reusable KV-migration plan buffers (`SwitchConfig::migrate`): the
    /// promotion path plans/applies into these, so migration performs zero
    /// steady-state heap allocation once warm.
    migration_plan: MigrationPlan,
    /// Per-engine drain horizons in calibrated wall-clock seconds,
    /// recomputed once per `assign_waiting` pass (0.0 = engine not
    /// backfillable).  Horizons only move between execute steps, so one
    /// scan serves the whole walk.  Denominated by the same cost model as
    /// the kernel's `backfill_fit` request side — the simulator's exact
    /// predicate, now shared (ISSUE 5).
    horizon_s_by_engine: Vec<f64>,
    /// Engines with a command in flight whose reply has not been collected
    /// yet.  Used to re-synchronize the persistent per-engine reply
    /// channels if a step aborts mid-collection.
    pending_mask: u64,
}

/// The real serving cluster: N engine threads + adaptors + communicator
/// pool + the dynamic scheduler.
pub struct Cluster {
    pub cfg: ModelCfg,
    engines: Vec<EngineHandle>,
    adaptors: Vec<KvCacheAdaptor>,
    pub comm: Arc<CommunicatorPool>,
    max_tp: usize,
    b_dec: usize,
    c_prefill: usize,

    // scheduler state
    /// The scheduling kernel (ISSUE 5): per-priority waiting rings, the
    /// admission-walk skeleton, and the unit/idle/draining engine bitmask
    /// index — the identical state machine the simulator drives, so
    /// decisions cannot fork between paths.  This coordinator is a driver:
    /// it feeds the kernel arrivals and turns its placements into
    /// adaptor/engine commands.
    kernel: Kernel<SlabHandle>,
    /// Dense request-state slab; finished/rejected entries are removed, so
    /// occupancy equals in-flight requests.
    active: Slab<Active>,
    /// id → handle, consulted only at admission boundaries.
    by_id: BTreeMap<u64, SlabHandle>,
    engine_active: Vec<Vec<SlabHandle>>, // DP requests per engine
    engine_mode: Vec<usize>,
    /// Blocks committed per engine by admission control.
    engine_committed: Vec<usize>,
    groups: BTreeMap<usize, Group>,
    outputs: Vec<(u64, Vec<i32>)>,
    rejected: Vec<u64>,
    switches: Vec<SwitchEvent>,
    t0: Instant,
    n_steps: usize,
    switch_cfg: SwitchConfig,
    /// Lockstep watchdog configuration (ISSUE 6).  Disabled by default:
    /// the fault-free path then uses the exact blocking collection the
    /// pre-watchdog coordinator ran, byte-identical.
    watchdog: WatchdogConfig,
    /// Per-trace fault/recovery counters (reset by `run_trace`).
    fault_stats: FaultStats,
    /// Engines whose fault was detected but whose graceful degradation has
    /// not run yet — drained at safe points by `process_faults` (removing
    /// groups mid-`settle_groups` would invalidate its iteration state).
    pending_faults: Vec<usize>,
    /// Requests marked for recovery at the next safe point (e.g. a
    /// transition whose migration step faulted mid-flight).
    fault_recover: Vec<SlabHandle>,
    /// Consecutive degraded step errors per engine: a live engine that
    /// errors every step (a deterministic failure rather than a transient
    /// collective timeout) is escalated to fail-stop after a bounded
    /// streak instead of being retried forever.
    step_err_streak: Vec<u32>,
    /// Communicator timeout this cluster was booted with — kept so
    /// [`Self::set_watchdog_checked`] can validate the watchdog's ordering
    /// invariants against it.
    comm_timeout: Duration,
    /// Per-engine scripted fault plan of the *current incarnation* (stub
    /// clusters; `FaultPlan::none()` elsewhere).  Consulted at rejoin time:
    /// [`FaultPlan::revivable`] gates revive, [`FaultPlan::revive_plan`]
    /// scripts the next incarnation.  Revive only targets plans whose death
    /// is a worker *exit* (`die_at`), so replacing the handle never joins a
    /// still-running thread.
    plans: Vec<FaultPlan>,
    /// Incarnation counter per engine, bumped on every respawn (mirrors
    /// `EngineHandle::generation`).
    engine_generation: Vec<u32>,
    /// Fail-recover state machine per engine (ISSUE 8).
    rejoin: Vec<RejoinState>,
    /// Elastic binds admitted through the backfill predicate (for the
    /// `backfill_margin` sweep in `sched_hotpath`).
    backfill_binds: usize,
    /// Cumulative tokens carried across layout changes by KV migration.
    recompute_tokens_avoided: usize,
    /// Cross-request prefix cache (ISSUE 10).  Off by default: admission
    /// never probes the adaptors' radix trees and behavior is
    /// byte-identical to pre-PR-10.  Armed by [`Self::set_prefix_cache`].
    prefix_cache: bool,
    /// Cumulative prompt tokens adopted by reference at admission under
    /// `--prefix-cache` (never prefilled).
    prefill_tokens_avoided: usize,
    /// Cost model backing the shared migrate-vs-recompute rule
    /// (`CostModel::migrate_wins`) — the identical rule the simulator event
    /// core applies, so decisions stay byte-comparable across paths.
    /// Defaults to the paper-scale Llama-70B model; [`Self::calibrate`]
    /// replaces it with a testbed-scale fit measured from the live
    /// engines' step times, which also arms the wall-clock backfill
    /// predicate and the `CostModelController` behind `--policy adaptive`.
    migrate_cm: CostModel,

    /// Flight recorder (ISSUE 7).  `Journal::off()` unless `set_trace(true)`
    /// armed it: recording is then O(1)/allocation-free (fixed ring), and
    /// disabled it is a branch-and-return — either way the zero-alloc
    /// steady-state gate holds and scheduling decisions are untouched.
    journal: crate::obs::Journal,
    /// Last control-tick `seq` journaled (adaptive policy only), so polling
    /// `Policy::last_tick` once per scheduling round records each tick once.
    journal_tick_seq: usize,

    /// Step-pipeline overlap configuration (ISSUE 9).  Off by default: the
    /// coordinator then builds, issues, and collects exactly as before —
    /// differential tests pin the off path byte-identical per scenario.
    overlap_cfg: OverlapConfig,
    /// Tagged in-flight KV-migration transfers (`OverlapConfig::
    /// async_migrate` only): the scatter was issued but its replies not yet
    /// collected; the member engines keep running it while *other* engines
    /// take decode steps.  Drained at the next safe point.
    async_migrations: Vec<AsyncMigration>,
    /// Bitmask of engines with an async transfer in flight — masked out of
    /// step issue (their single in-flight command slot is the transfer;
    /// `CHANNEL_DEPTH` is 2, so a second command plus its reply could
    /// deadlock the lockstep against a third).
    async_busy: u64,

    // hot-path arenas
    engine_scratch: Vec<EngineScratch>,
    scratch: StepScratch,
}

/// A KV-migration scatter issued without collecting its replies (ISSUE 9):
/// everything the deferred completion needs to finish the bookkeeping the
/// inline path does synchronously.  Generational handles make late
/// completion stale-tolerant — if the request is recovered or finished by
/// drain time, `fault_recover` simply resolves to a no-op.
#[derive(Clone, Copy, Debug)]
struct AsyncMigration {
    h: SlabHandle,
    rid: u64,
    start: usize,
    p: usize,
    kv_pos: usize,
    issued_at: f64,
}

impl Cluster {
    /// Boot `n_engines` engine workers for `model` over the real PJRT
    /// execution core (weights loaded once, artifacts compiled eagerly,
    /// communicator pool pre-initialized).
    #[cfg(feature = "pjrt")]
    pub fn start(
        manifest: &Arc<crate::runtime::Manifest>,
        model: &str,
        n_engines: usize,
    ) -> Result<Cluster> {
        use anyhow::Context;
        let mm = manifest.model(model)?;
        let cfg = mm.cfg.clone();
        let ws = Arc::new(mm.load_weights()?);
        let mut degrees: Vec<usize> = manifest
            .tp_degrees
            .iter()
            .copied()
            .filter(|&p| cfg.supports_tp(p) && p <= n_engines)
            .collect();
        if !degrees.contains(&1) {
            degrees.push(1);
        }
        let comm = Arc::new(CommunicatorPool::new(
            n_engines,
            &degrees,
            Duration::from_secs(30),
        ));
        let mut engines = Vec::new();
        for id in 0..n_engines {
            engines.push(
                EngineHandle::spawn(id, manifest.clone(), model.to_string(), ws.clone(), comm.clone())
                    .with_context(|| format!("starting engine {id}"))?,
            );
        }
        Self::assemble(cfg, engines, comm, degrees, manifest.shapes, Duration::from_secs(30), Vec::new())
    }

    /// Boot `n_engines` workers over the deterministic stub backend — the
    /// full scheduler/adaptor/collective path with no PJRT dependency.
    /// Used by CI integration tests and the scheduler benches.
    pub fn start_stub(cfg: ModelCfg, shapes: StaticShapes, n_engines: usize) -> Result<Cluster> {
        Self::start_stub_with(cfg, shapes, n_engines, Duration::from_secs(30), &[])
    }

    /// [`Self::start_stub`] with an explicit collective watchdog timeout
    /// and per-engine fault plans (ISSUE 6).  `plans` is indexed by engine
    /// id; missing entries inject nothing.  The communicator timeout must
    /// stay *below* the lockstep watchdog's total reply budget so a group
    /// stranded by a dead peer errors out of its collective (and replies)
    /// before the coordinator escalates the surviving members.
    pub fn start_stub_with(
        cfg: ModelCfg,
        shapes: StaticShapes,
        n_engines: usize,
        comm_timeout: Duration,
        plans: &[FaultPlan],
    ) -> Result<Cluster> {
        let mut degrees = Vec::new();
        let mut p = 1usize;
        while p <= n_engines {
            if cfg.supports_tp(p) {
                degrees.push(p);
            }
            p *= 2;
        }
        if !degrees.contains(&1) {
            degrees.push(1);
        }
        let comm = Arc::new(CommunicatorPool::new(n_engines, &degrees, comm_timeout));
        let mut engines = Vec::new();
        let mut all_plans = Vec::with_capacity(n_engines);
        for id in 0..n_engines {
            let plan = plans.get(id).cloned().unwrap_or_default();
            if plan.is_none() {
                engines.push(EngineHandle::spawn_stub(id, cfg.clone(), shapes, comm.clone())?);
            } else {
                engines.push(EngineHandle::spawn_stub_faulty(
                    id,
                    cfg.clone(),
                    shapes,
                    comm.clone(),
                    plan.clone(),
                )?);
            }
            all_plans.push(plan);
        }
        Self::assemble(cfg, engines, comm, degrees, shapes, comm_timeout, all_plans)
    }

    fn assemble(
        cfg: ModelCfg,
        engines: Vec<EngineHandle>,
        comm: Arc<CommunicatorPool>,
        degrees: Vec<usize>,
        shapes: StaticShapes,
        comm_timeout: Duration,
        mut plans: Vec<FaultPlan>,
    ) -> Result<Cluster> {
        let n_engines = engines.len();
        if n_engines > 64 {
            bail!("engine-state bitmasks support at most 64 engines (got {n_engines})");
        }
        let max_tp = degrees.iter().copied().max().unwrap_or(1);
        let adaptors = (0..n_engines).map(|_| KvCacheAdaptor::new(cfg.clone())).collect();
        let mut c = Cluster {
            cfg,
            engines,
            adaptors,
            comm,
            max_tp,
            b_dec: shapes.b_dec,
            c_prefill: shapes.c_prefill,
            kernel: Kernel::new(),
            active: Slab::new(),
            by_id: BTreeMap::new(),
            engine_active: vec![Vec::new(); n_engines],
            engine_mode: vec![1; n_engines],
            engine_committed: vec![0; n_engines],
            groups: BTreeMap::new(),
            outputs: Vec::new(),
            rejected: Vec::new(),
            switches: Vec::new(),
            t0: Instant::now(),
            n_steps: 0,
            switch_cfg: SwitchConfig::default(),
            watchdog: WatchdogConfig::default(),
            fault_stats: FaultStats::default(),
            pending_faults: Vec::new(),
            fault_recover: Vec::new(),
            step_err_streak: vec![0; n_engines],
            comm_timeout,
            plans: {
                plans.resize(n_engines, FaultPlan::none());
                plans
            },
            engine_generation: vec![0; n_engines],
            rejoin: vec![RejoinState::default(); n_engines],
            backfill_binds: 0,
            recompute_tokens_avoided: 0,
            prefix_cache: false,
            prefill_tokens_avoided: 0,
            migrate_cm: CostModel::new(HwSpec::default(), PaperModel::llama70b()),
            journal: crate::obs::Journal::off(),
            journal_tick_seq: 0,
            overlap_cfg: OverlapConfig::default(),
            async_migrations: Vec::new(),
            async_busy: 0,
            engine_scratch: (0..n_engines).map(|_| EngineScratch::default()).collect(),
            scratch: StepScratch::default(),
        };
        for e in 0..n_engines {
            c.refresh_engine(e);
        }
        Ok(c)
    }

    pub fn n_engines(&self) -> usize {
        self.engines.len()
    }

    pub fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Switch-transition tuning (drain backfill + incremental settle).
    /// Off by default; set before submitting work.
    pub fn set_switch_config(&mut self, cfg: SwitchConfig) {
        self.switch_cfg = cfg;
    }

    pub fn switch_config(&self) -> SwitchConfig {
        self.switch_cfg
    }

    /// Lockstep watchdog + graceful-degradation tuning (ISSUE 6).  Off by
    /// default: the coordinator then blocks on replies exactly as before —
    /// a fault-free run is byte-identical to the pre-watchdog path.
    pub fn set_watchdog(&mut self, cfg: WatchdogConfig) {
        self.watchdog = cfg;
    }

    /// [`Self::set_watchdog`] with [`WatchdogConfig::validate`] run against
    /// this cluster's actual communicator timeout first — the CLI path, so
    /// a budget ordering that would misclassify collective survivors as
    /// failed is rejected at startup instead of discovered mid-trace.
    pub fn set_watchdog_checked(&mut self, cfg: WatchdogConfig) -> Result<()> {
        cfg.validate(self.comm_timeout)?;
        self.watchdog = cfg;
        Ok(())
    }

    pub fn watchdog(&self) -> WatchdogConfig {
        self.watchdog
    }

    /// Step-pipeline overlap tuning (ISSUE 9).  Off by default: building,
    /// issuing, and collecting then run exactly the pre-overlap lockstep —
    /// the differential suite pins the off path byte-identical.
    pub fn set_overlap_config(&mut self, cfg: OverlapConfig) {
        self.overlap_cfg = cfg;
    }

    pub fn overlap_config(&self) -> OverlapConfig {
        self.overlap_cfg
    }

    /// Arm the cross-request prefix cache (ISSUE 10) on every engine's KV
    /// adaptor.  One-way per adaptor lifetime (`enable_prefix_cache` has no
    /// disarm — refcounts would be ambiguous), but safe at any safe point:
    /// arming seeds the refcount ledger from live requests and changes no
    /// block assignment.  Off by default; admission then never probes the
    /// trees and the coordinator is byte-identical to pre-PR-10.
    pub fn set_prefix_cache(&mut self, on: bool) {
        self.prefix_cache = on;
        if on {
            for ad in self.adaptors.iter_mut() {
                ad.enable_prefix_cache();
            }
        }
    }

    pub fn prefix_cache(&self) -> bool {
        self.prefix_cache
    }

    /// Prompt tokens adopted by reference at admission since the last
    /// `run_trace` reset (`--prefix-cache` only).
    pub fn prefill_tokens_avoided(&self) -> usize {
        self.prefill_tokens_avoided
    }

    /// Idle serving capacity as the kernel index counts it (excludes
    /// failed and quarantined engines) — the healing witness the chaos
    /// harness asserts returns to `n_engines` after rejoins quiesce.
    pub fn idle_count(&self) -> usize {
        self.kernel.index.idle_count()
    }

    /// Incarnation counter of engine `e` (0 = original spawn; bumped on
    /// every fail-recover respawn).
    pub fn engine_generation(&self, e: usize) -> u32 {
        self.engine_generation[e]
    }

    /// Bitmask of respawned-but-unprobed engines.
    pub fn quarantined_mask(&self) -> u64 {
        self.kernel.index.quarantined_mask()
    }

    /// Fault/recovery counters accumulated since the last `run_trace`
    /// reset (for `step_once`-driven harnesses).
    pub fn fault_stats(&self) -> FaultStats {
        self.fault_stats
    }

    /// Bitmask of fail-stopped engines.
    pub fn failed_mask(&self) -> u64 {
        self.kernel.index.failed_mask()
    }

    /// Elastic binds admitted through the backfill predicate (for the
    /// `backfill_margin` sweep in `sched_hotpath`).
    pub fn backfill_binds(&self) -> usize {
        self.backfill_binds
    }

    /// Arm (or disarm) the flight recorder (ISSUE 7).  Arming preallocates
    /// the ring once; recording is then O(1)/allocation-free and observes
    /// decisions without steering them.  Off by default — the journal is a
    /// disabled stub and every record call is a branch-and-return.
    pub fn set_trace(&mut self, on: bool) {
        if on && !self.journal.is_enabled() {
            self.journal = crate::obs::Journal::new(crate::obs::DEFAULT_JOURNAL_CAP);
        } else if !on && self.journal.is_enabled() {
            self.journal = crate::obs::Journal::off();
        }
        self.journal_tick_seq = 0;
    }

    /// The flight-recorder journal (empty and disabled unless `set_trace`
    /// armed it).
    pub fn journal(&self) -> &crate::obs::Journal {
        &self.journal
    }

    /// Structural invariants that must hold at every safe point, fault or
    /// no fault: every adaptor's internal block accounting balances, and
    /// the per-engine committed-block counters equal the sum over live
    /// requests' commitments.  The chaos harness calls this after every
    /// trace.
    pub fn check_invariants(&self) -> Result<()> {
        for (e, ad) in self.adaptors.iter().enumerate() {
            ad.check_invariants()
                .map_err(|err| anyhow::anyhow!("adaptor {e}: {err:#}"))?;
        }
        let mut per_engine = vec![0usize; self.engines.len()];
        for (_, a) in self.active.iter() {
            for &(e, blocks) in &a.committed {
                per_engine[e] += blocks;
            }
        }
        for e in 0..self.engines.len() {
            anyhow::ensure!(
                per_engine[e] == self.engine_committed[e],
                "engine {e}: committed counter {} != sum over live requests {}",
                self.engine_committed[e],
                per_engine[e]
            );
        }
        // Rejoin invariants (ISSUE 8): a quarantined engine was re-admitted
        // with an empty pool and must host nothing until its probe clears;
        // no live request may hold a KV registration on a failed or
        // quarantined engine (degradation reclaims them, and the rejoin
        // path installs a fresh adaptor).
        let excluded = self.kernel.index.failed_mask() | self.kernel.index.quarantined_mask();
        for e in 0..self.engines.len() {
            if excluded & (1u64 << e) != 0 {
                anyhow::ensure!(
                    self.engine_active[e].is_empty(),
                    "engine {e} is failed/quarantined but hosts resident requests"
                );
            }
        }
        for (_, a) in self.active.iter() {
            for &(e, _) in &a.kvh {
                anyhow::ensure!(
                    excluded & (1u64 << e) == 0,
                    "request {} holds a kv registration on failed/quarantined engine {e}",
                    a.sr.id
                );
            }
        }
        Ok(())
    }

    /// Override the cost model behind the migrate-vs-recompute rule.  The
    /// default is the paper-scale Llama-70B model (always-migrate at any
    /// realistic context); deployments serving a different model — or a
    /// future testbed-calibrated fit (ROADMAP open item) — install the
    /// matching model here so the real path and the simulator keep applying
    /// the same rule to the same hardware story.
    pub fn set_migration_cost_model(&mut self, cm: CostModel) {
        self.migrate_cm = cm;
    }

    /// The cost model currently backing the migrate gate, the wall-clock
    /// backfill predicate, and (after [`Self::calibrate`]) the
    /// `CostModelController` behind `--policy adaptive`.
    pub fn migration_cost_model(&self) -> &CostModel {
        &self.migrate_cm
    }

    /// Fit a testbed-scale [`CostModel`] from measured engine step times
    /// (ROADMAP open item, resolved in PR 5).  Runs a short solo probe
    /// request through the live engines — a few chunked-prefill steps and a
    /// few dozen decode steps — and solves the analytic model's two
    /// operating points against the medians: effective FLOP/s from the
    /// prefill chunk time (compute-bound) and effective memory bandwidth
    /// from the decode step time (weight-read-bound), with the model's
    /// KV capacity pinned to the adaptor's real block pool.  A coarse
    /// two-point fit, but denominated in this testbed's actual seconds,
    /// which is what the wall-clock backfill predicate and the
    /// migrate-vs-recompute gate need to compare like with like.
    ///
    /// Installs the fitted model as this cluster's scheduling cost model
    /// (`migrate_cm`) and returns a clone for the caller — `--policy
    /// adaptive` feeds it to a `CostModelController` so the control plane's
    /// layout scoring finally runs on the real path.  Must be called on an
    /// idle cluster (before serving traffic); the probe leaves no residue.
    pub fn calibrate(&mut self) -> Result<CostModel> {
        anyhow::ensure!(
            self.active.is_empty() && self.kernel.rings.is_empty(),
            "calibrate: cluster must be idle"
        );
        const PROBE_ID: u64 = u64::MAX - 7;
        let mut recorder = Recorder::new();
        let mut policy = crate::baselines::StaticDpPolicy;
        // Size the probe to this cluster: a few prefill chunks and a decode
        // tail, but never past a single engine's KV capacity (tiny testbed
        // configs have pools of only a few dozen tokens).
        let cap = self.cfg.dp_token_capacity();
        let prompt_len = (4 * self.c_prefill).min(cap / 2).max(2);
        let max_new = 32usize.min(cap.saturating_sub(prompt_len).max(4) / 2).max(4);
        self.submit(
            ServeRequest {
                id: PROBE_ID,
                prompt: (0..prompt_len).map(|i| (i % 250) as i32).collect(),
                max_new,
                priority: Priority::Normal,
                tp_demand: None,
                arrival: 0.0,
            },
            &mut recorder,
        );
        let mut prefill_samples: Vec<f64> = Vec::new();
        let mut decode_samples: Vec<f64> = Vec::new();
        for _ in 0..(prompt_len / self.c_prefill.max(1) + max_new + 64) {
            let in_prefill = match self
                .by_id
                .get(&PROBE_ID)
                .copied()
                .and_then(|h| self.active.get(h))
            {
                Some(a) => a.phase == Phase::Prefill,
                None => break, // probe finished
            };
            let t0 = Instant::now();
            let stepped = self.step_once(&mut policy, Strategy::Sequential, &mut recorder)?;
            let dt = t0.elapsed().as_secs_f64();
            if !stepped {
                break;
            }
            if in_prefill {
                prefill_samples.push(dt);
            } else {
                decode_samples.push(dt);
            }
        }
        // Drain defensively, then scrub the probe from the outcome buffers
        // so a later `run_trace` on this cluster reports only its own trace.
        while self.by_id.contains_key(&PROBE_ID) {
            if !self.step_once(&mut policy, Strategy::Sequential, &mut recorder)? {
                break;
            }
        }
        self.outputs.retain(|(id, _)| *id != PROBE_ID);
        self.rejected.retain(|id| *id != PROBE_ID);
        anyhow::ensure!(
            !prefill_samples.is_empty() && !decode_samples.is_empty(),
            "calibrate: probe produced no timed steps (prefill {}, decode {})",
            prefill_samples.len(),
            decode_samples.len()
        );
        prefill_samples.sort_by(f64::total_cmp);
        decode_samples.sort_by(f64::total_cmp);
        let pre_s = prefill_samples[prefill_samples.len() / 2].max(1e-9);
        let dec_s = decode_samples[decode_samples.len() / 2].max(1e-9);
        let cm = self.fit_cost_model(pre_s, dec_s);
        self.migrate_cm = cm.clone();
        Ok(cm)
    }

    /// Solve the analytic cost model against the two measured operating
    /// points (one prefill chunk of `c_prefill` tokens, one batch-1 decode
    /// step), with the model description taken from this cluster's real
    /// `ModelCfg`.
    fn fit_cost_model(&self, prefill_chunk_s: f64, decode_step_s: f64) -> CostModel {
        let cfg = &self.cfg;
        let d = cfg.d_model as f64;
        let qo = 2.0 * d * (cfg.n_heads * cfg.d_head) as f64;
        let kv = 2.0 * d * (cfg.n_kv_heads * cfg.d_head) as f64;
        let ffn = 3.0 * d * cfg.ffn_hidden as f64;
        let experts = cfg.n_experts.max(1) as f64;
        let active_experts = if cfg.n_experts == 0 { 1.0 } else { cfg.top_k.max(1) as f64 };
        let per_layer = qo + kv + experts * ffn;
        let per_layer_active = qo + kv + active_experts * ffn;
        let embed = d * cfg.vocab as f64;
        let model = PaperModel {
            name: "testbed-calibrated",
            params_b: (cfg.n_layers as f64 * per_layer + embed) / 1e9,
            active_params_b: (cfg.n_layers as f64 * per_layer_active + embed) / 1e9,
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            n_kv_heads: cfg.n_kv_heads,
            d_head: cfg.d_head,
            min_gpus: 1,
            max_model_ctx: cfg.max_ctx,
            bytes_per_param: 4.0,   // testbed weights are f32
            kv_bytes_per_elem: 4.0, // testbed KV pools are f32 too
        };
        // Effective FLOP/s so prefill_s(c_prefill, 1) reproduces the
        // measured chunk time (mfu folded in), and effective bandwidth so
        // decode_step_s(1, ·, 1) reproduces the measured weight-read-bound
        // step; launch overheads fold into the measurements themselves.
        let flops = (2.0 * model.active_params_b * 1e9 * self.c_prefill.max(1) as f64
            / prefill_chunk_s)
            .max(1.0);
        let weight_bytes = model.weight_bytes();
        // Bandwidth from the bytes a b=1 decode step actually reads: for
        // MoE shapes that is the *active* expert slice, not the full
        // checkpoint — dividing total weight bytes by the measured step
        // would under-predict decode time by the active/total ratio.
        let touched_bytes =
            model.active_params_b.min(model.params_b) * 1e9 * model.bytes_per_param;
        let hbm_bw = (touched_bytes / decode_step_s).max(1.0);
        // KV capacity pinned to the adaptor's real block pool so capacity
        // reasoning on the fitted model matches admission control.
        let kv_tokens = cfg.dp_token_capacity() as f64;
        let hbm_gb = (weight_bytes + kv_tokens * model.kv_bytes_per_token()) / 1e9;
        let hw = HwSpec {
            n_gpus: self.engines.len(),
            hbm_gb,
            hbm_bw,
            // The testbed "interconnect" is the same memory fabric the
            // engines share; migrations move bytes at memory speed.
            nvlink_bw: hbm_bw,
            flops_bf16: flops,
            mfu_prefill: 1.0,
            mfu_decode: 1.0,
            kernel_launch_s: 0.0,
            overhead_gb_per_gpu: 0.0,
            cold_base_s: 1.0,
            cold_s_per_gb: 0.0,
        };
        CostModel::new(hw, model)
    }

    fn members(&self, start: usize, p: usize) -> std::ops::Range<usize> {
        start..start + p
    }

    fn member_mask(&self, start: usize, p: usize) -> u64 {
        let mut m = 0u64;
        for e in self.members(start, p) {
            m |= 1u64 << e;
        }
        m
    }

    /// Recompute the kernel index's unit/idle bits for engine `e`.  Must be
    /// called after any mutation of `engine_mode[e]` or `engine_active[e]`.
    /// (An empty draining unit engine counts as idle until its switch lands
    /// — the policy sees it — which is this path's pre-kernel semantics,
    /// encoded in maintenance as `sched::index` documents.)
    fn refresh_engine(&mut self, e: usize) {
        let unit = self.engine_mode[e] == 1;
        let idle = unit && self.engine_active[e].is_empty();
        self.kernel.index.refresh_engine(e, unit, idle);
    }

    /// Recompute the kernel index's draining mask.  Must be called after
    /// any mutation of a group's `tp_pending`.
    fn refresh_draining(&mut self) {
        let mut mask = 0u64;
        for (&start, g) in &self.groups {
            if !g.tp_pending.is_empty() {
                for e in start..(start + g.p).min(self.engines.len()) {
                    mask |= 1u64 << e;
                }
            }
        }
        self.kernel.index.set_draining_mask(mask);
    }

    /// Whether the whole member set already runs at mode `p`.  With
    /// incremental settle a *subset* of members can be at `p` mid-drain, so
    /// `engine_mode[start]` alone is no longer a valid group-liveness
    /// witness.
    fn group_live(&self, start: usize, p: usize) -> bool {
        self.members(start, p).all(|e| self.engine_mode[e] == p)
    }

    /// Live mode switch over `width` members: SetMode RPC to every member +
    /// communicator fetch.  Returns the measured latency (the Table-2
    /// "live" number).
    fn switch_group(&mut self, start: usize, width: usize, p_to: usize) -> Result<f64> {
        // The logged from-mode is the first member mode that still differs
        // from the target — under incremental settle `engine_mode[start]`
        // can already equal `p_to` while siblings lag, which would log a
        // meaningless p→p (or 1→1) transition in the Table-2 event stream.
        let scan_width = width.max(p_to);
        let p_from = self
            .members(start, scan_width)
            .filter(|&e| e < self.engines.len())
            .map(|e| self.engine_mode[e])
            .find(|&m| m != p_to)
            .unwrap_or(self.engine_mode[start]);
        let t_start = Instant::now();
        // Communicator activation: O(1) pool lookup (pre-initialized).
        if p_to > 1 {
            let _ = self.comm.group_of(start, p_to)?;
        }
        let width = scan_width.max(p_from);
        for e in self.members(start, width) {
            // Members already at the target mode (incrementally settled, or
            // SetMode is otherwise redundant) are skipped: the final
            // promotion pays only the stragglers' mode RPCs.  Failed
            // members are skipped too (`set_mode_watched` returns false) —
            // a fault here surfaces through the group-health checks at the
            // call sites, not as a blocked RPC.
            if e < self.engines.len()
                && self.engine_mode[e] != p_to
                && self.set_mode_watched(e, p_to)?
            {
                self.engine_mode[e] = p_to;
                self.refresh_engine(e);
            }
        }
        let dt = t_start.elapsed().as_secs_f64();
        let t_now = self.now();
        self.switches.push(SwitchEvent {
            t: t_now,
            group_start: start,
            p_from,
            p_to,
            latency_s: dt,
        });
        let members = self
            .members(start, width)
            .filter(|&e| e < self.engines.len())
            .fold(0u64, |acc, e| acc | (1u64 << e));
        self.journal.record(
            t_now,
            crate::obs::Event::Promote {
                group: start as u32,
                p_from: p_from as u32,
                p_to: p_to as u32,
                members,
                latency_s: dt,
            },
        );
        Ok(dt)
    }

    // ------------------------------------------------------------------
    // Lockstep watchdog + graceful degradation (ISSUE 6)
    // ------------------------------------------------------------------

    /// Watched receive on engine `e`'s persistent reply channel: wait up
    /// to `reply_timeout`, then retry with the deadline extended by
    /// `backoff` up to `retries` times (a stall ridden out this way is
    /// counted, not escalated), then escalate to a typed fault.  The
    /// total budget must exceed the communicator timeout so a survivor
    /// stuck in a collective against a dead peer gets to reply `Err`
    /// before being declared failed itself.  Known-failed engines
    /// short-circuit — fail-stop means never draining their channel again.
    fn recv_reply_watched(&mut self, e: usize) -> std::result::Result<EngineReply, FaultKind> {
        if self.kernel.index.is_failed(e) {
            return Err(FaultKind::Disconnected);
        }
        let mut attempt = 0u32;
        let mut deadline = self.watchdog.reply_timeout;
        loop {
            match self.engines[e].recv_timeout(deadline) {
                Ok(r) => {
                    if attempt > 0 {
                        self.fault_stats.stalls_ridden_out += 1;
                        let t_now = self.now();
                        self.journal.record(
                            t_now,
                            crate::obs::Event::WatchdogRetry { engine: e as u32, attempt },
                        );
                    }
                    return Ok(r);
                }
                Err(RecvTimeoutError::Timeout) => {
                    attempt += 1;
                    if attempt > self.watchdog.retries {
                        self.fault_stats.reply_timeouts += 1;
                        let t_now = self.now();
                        self.journal.record(
                            t_now,
                            crate::obs::Event::WatchdogTimeout { engine: e as u32 },
                        );
                        return Err(FaultKind::Timeout);
                    }
                    deadline += self.watchdog.backoff;
                }
                Err(RecvTimeoutError::Disconnected) => return Err(FaultKind::Disconnected),
            }
        }
    }

    /// Record a detected engine fault: fail-stop in the kernel index (the
    /// engine leaves every candidate set immediately, and is never sent
    /// to or received from again) and queue graceful degradation for the
    /// next safe point — dissolving groups mid-`settle_groups` would
    /// invalidate its iteration state.
    fn note_engine_fault(&mut self, e: usize, kind: FaultKind) {
        if self.kernel.index.is_failed(e) {
            return;
        }
        crate::info!("engine {e} failed: {kind}");
        self.kernel.index.mark_failed(e);
        self.pending_faults.push(e);
        // Re-arm the rejoin backoff clock for this fault: the next
        // `process_rejoins` pass schedules the revive attempt at
        // `rejoin_backoff · 2^attempts` from then (attempts are cumulative,
        // so each crash-loop lap waits longer).
        self.rejoin[e].next_try = None;
        self.fault_stats.engine_faults += 1;
        let t_now = self.now();
        self.journal.record(t_now, crate::obs::Event::EngineFault { engine: e as u32 });
    }

    /// Fault-aware SetMode on engine `e`; returns whether the mode RPC
    /// actually completed (false: the engine is failed, already or
    /// newly).  With the watchdog off this is the exact blocking call
    /// the pre-watchdog coordinator made — byte-identical fault-free.
    fn set_mode_watched(&mut self, e: usize, p: usize) -> Result<bool> {
        if self.kernel.index.is_failed(e) {
            return Ok(false);
        }
        if !self.watchdog.enabled {
            self.engines[e].call(EngineCmd::SetMode { p })?;
            return Ok(true);
        }
        self.engines[e].send(EngineCmd::SetMode { p });
        match self.recv_reply_watched(e) {
            Ok(EngineReply::Err(msg)) => bail!("engine {e}: set_mode: {msg}"),
            Ok(_) => Ok(true),
            Err(kind) => {
                self.note_engine_fault(e, kind);
                Ok(false)
            }
        }
    }

    /// Drain the queue of detected faults at a safe point (no step in
    /// flight, no group iteration borrowed): dissolve every group the
    /// failed engines belonged to back to the surviving members, then
    /// recover (requeue for recompute) or reject every request that was
    /// resident on a failed engine or aborted mid-transition.
    fn process_faults(&mut self, recorder: &mut Recorder) -> Result<()> {
        if self.pending_faults.is_empty() && self.fault_recover.is_empty() {
            return Ok(());
        }
        // Degrading a group whose members still run an async transfer would
        // interleave `SetMode` replies with the scatter's — complete every
        // in-flight transfer first (ISSUE 9; no-op with `--overlap` off).
        self.drain_async_migrations()?;
        while let Some(e) = self.pending_faults.pop() {
            self.degrade_engine(e, recorder)?;
        }
        let mut rec_hs = std::mem::take(&mut self.fault_recover);
        for h in rec_hs.drain(..) {
            self.recover_request(h, true, recorder)?;
        }
        self.fault_recover = rec_hs;
        self.refresh_draining();
        Ok(())
    }

    /// Graceful degradation for one failed engine.
    fn degrade_engine(&mut self, e: usize, recorder: &mut Recorder) -> Result<()> {
        let recover_before = self.fault_recover.len();
        // Groups overlapping the failed engine dissolve back to their
        // surviving units.  `settled_mask`/`group_live` invariants hold
        // trivially afterwards: the group row is gone, and survivors are
        // switched to unit mode through the failed-skipping RPC path.
        let mut starts = std::mem::take(&mut self.scratch.starts);
        starts.clear();
        starts.extend(
            self.groups
                .iter()
                .filter(|&(&s, g)| s <= e && e < s + g.p)
                .map(|(&s, _)| s),
        );
        for &start in &starts {
            let g = self.groups.remove(&start).expect("listed start");
            // TP-active requests lost a shard of their KV: recover them.
            for &h in &g.tp_active {
                if self.active.get(h).is_some() && !self.fault_recover.contains(&h) {
                    self.fault_recover.push(h);
                }
            }
            for &h in &g.tp_pending {
                let Some(a) = self.active.get(h) else { continue };
                if a.speculative && a.home != e && !self.kernel.index.is_failed(a.home) {
                    // Its speculative DP run on a surviving member is
                    // intact: demote to a plain DP request and let it
                    // finish there instead of recomputing.
                    self.active.get_mut(h).expect("live").speculative = false;
                } else if a.speculative {
                    // The speculative home died too; it sits in that
                    // engine's resident list and is recovered below.
                } else {
                    // Never bound anywhere: requeue uncharged.
                    self.recover_request(h, false, recorder)?;
                }
            }
            // Survivors return to unit mode (the failed member is
            // skipped by `set_mode_watched`) and resume paused work.
            self.switch_group(start, g.p, 1)?;
            for m in self.members(start, g.p) {
                if m >= self.engines.len() || self.kernel.index.is_failed(m) {
                    continue;
                }
                let mut resumed = std::mem::take(&mut self.scratch.ids);
                resumed.clear();
                for &x in &self.engine_active[m] {
                    if self.active.get(x).map(|a| a.paused).unwrap_or(false) {
                        resumed.push(x);
                    }
                }
                for &x in resumed.iter() {
                    let rid = self.active.get(x).expect("live").sr.id;
                    let _ = self.adaptors[m].resume(rid);
                    self.active.get_mut(x).expect("live").paused = false;
                }
                self.scratch.ids = resumed;
                self.refresh_engine(m);
            }
        }
        self.scratch.starts = starts;
        // The failed engine's resident DP requests (incl. paused and
        // speculative ones homed there) are recovered with a retry
        // charge.  Its adaptor is coordinator-owned metadata, so the
        // reclaim is safe even though the worker is gone.
        let resident = std::mem::take(&mut self.engine_active[e]);
        for &h in &resident {
            if self.active.get(h).is_some() && !self.fault_recover.contains(&h) {
                self.fault_recover.push(h);
            }
        }
        self.engine_active[e] = resident;
        self.engine_active[e].clear();
        self.refresh_engine(e);
        let t_now = self.now();
        let requeued = (self.fault_recover.len() - recover_before) as u32;
        self.journal.record(
            t_now,
            crate::obs::Event::EngineDegraded { engine: e as u32, requeued },
        );
        Ok(())
    }

    /// Rescue one request off a failed engine (or an aborted transition):
    /// reclaim its blocks and registrations everywhere (stale handles are
    /// skipped, never a panic), strip it from every placement list, and
    /// requeue it for a from-scratch recompute (`pos = 0`; already-
    /// emitted tokens are kept and re-fed, exactly the soft-preempt
    /// recompute discipline).  Past the retry budget it is rejected.
    fn recover_request(
        &mut self,
        h: SlabHandle,
        charge: bool,
        recorder: &mut Recorder,
    ) -> Result<()> {
        if self.active.get(h).is_none() {
            return Ok(()); // stale handle: finished or already recovered
        }
        self.uncommit_all(h);
        let kvh = std::mem::take(&mut self.active.get_mut(h).expect("live").kvh);
        for (e, kh) in kvh {
            let _ = self.adaptors[e].release_if_live_h(kh);
        }
        for e in 0..self.engines.len() {
            if self.engine_active[e].contains(&h) {
                self.engine_active[e].retain(|&x| x != h);
                self.refresh_engine(e);
            }
        }
        for g in self.groups.values_mut() {
            g.tp_active.retain(|&x| x != h);
            g.tp_pending.retain(|&x| x != h);
        }
        let (pri, over_budget, rec, rid, retries) = {
            let a = self.active.get_mut(h).expect("live");
            a.mode_p = 0;
            a.home = 0;
            a.phase = Phase::Prefill;
            a.pos = 0;
            a.paused = false;
            a.speculative = false;
            a.backfill = false;
            if charge {
                a.retries += 1;
            }
            (
                a.sr.priority,
                a.retries > self.watchdog.max_request_retries,
                a.rec,
                a.sr.id,
                a.retries,
            )
        };
        if over_budget {
            let now = self.now();
            let a = self.active.remove(h).expect("live");
            self.by_id.remove(&a.sr.id);
            self.rejected.push(a.sr.id);
            recorder.on_finish_at(rec, now);
            self.fault_stats.requests_aborted += 1;
            self.journal.record(now, crate::obs::Event::RequestAborted { rid });
        } else {
            self.kernel.on_event(SchedEvent::Arrival { h, priority: pri });
            self.fault_stats.requests_recovered += 1;
            let t_now = self.now();
            self.journal.record(
                t_now,
                crate::obs::Event::RequestRecovered { rid, retry: retries },
            );
        }
        Ok(())
    }

    /// Degraded-cell backstop: reject every request still waiting in the
    /// kernel rings.  Invoked by `run_trace` when a degraded cluster has
    /// made no progress for many iterations — the surviving engines
    /// cannot host the remaining waiters (e.g. a TP demand wider than
    /// what is left), so conservation is settled by rejection rather
    /// than a hang.
    fn reject_stranded(&mut self, recorder: &mut Recorder) {
        let now = self.now();
        while let Some(h) = self.kernel.rings.pop_any() {
            let Some(a) = self.active.remove(h) else { continue };
            self.by_id.remove(&a.sr.id);
            self.rejected.push(a.sr.id);
            recorder.on_finish_at(a.rec, now);
            self.fault_stats.requests_aborted += 1;
            self.journal.record(now, crate::obs::Event::RequestAborted { rid: a.sr.id });
        }
    }

    // ------------------------------------------------------------------
    // Engine fail-recover (ISSUE 8, `--recover`)
    // ------------------------------------------------------------------

    /// Whether engine `e` is a revive candidate *right now*: recovery is
    /// armed, the engine is fail-stopped but not abandoned, and its death
    /// was a transient worker exit (`FaultPlan::revivable` — a stalled
    /// thread is never revived, only a dead one, so replacing the handle
    /// can never join a still-running worker).
    fn rejoinable(&self, e: usize) -> bool {
        self.watchdog.enabled
            && self.watchdog.recover
            && !self.rejoin[e].abandoned
            && self.kernel.index.is_failed(e)
            && self.plans[e].revivable()
    }

    /// Whether any engine still has a pending (non-abandoned) revive.
    /// `run_trace` holds the stranded sweep and the stall bail while this
    /// is true — the idle window is a legitimate backoff wait, not a wedge
    /// — and the chaos harness drives rejoins to quiescence through it.
    pub fn rejoin_pending(&self) -> bool {
        (0..self.engines.len()).any(|e| self.rejoinable(e))
    }

    /// Safe-point pass of the recovery state machine: arm backoff clocks
    /// for freshly-detected faults, abandon engines whose cumulative
    /// attempt budget is spent, and run the revive sequence for engines
    /// whose backoff window has elapsed.  A no-op (single branch) unless
    /// `--recover` armed it.
    fn process_rejoins(&mut self, recorder: &mut Recorder) -> Result<()> {
        if !(self.watchdog.enabled && self.watchdog.recover) {
            return Ok(());
        }
        for e in 0..self.engines.len() {
            // Never revive ahead of the engine's own degradation pass:
            // `degrade_engine` must reclaim its residents first.
            if !self.rejoinable(e) || self.pending_faults.contains(&e) {
                continue;
            }
            if self.rejoin[e].attempts >= self.watchdog.max_rejoin_attempts {
                self.rejoin[e].abandoned = true;
                self.fault_stats.rejoins_abandoned += 1;
                let t_now = self.now();
                self.journal
                    .record(t_now, crate::obs::Event::RejoinAbandoned { engine: e as u32 });
                crate::info!(
                    "engine {e}: rejoin abandoned after {} attempts (permanent fail-stop)",
                    self.rejoin[e].attempts
                );
                continue;
            }
            match self.rejoin[e].next_try {
                None => {
                    // Fresh fault: schedule the attempt one exponential-
                    // backoff window out (2^attempts, capped well below
                    // overflow).
                    let shift = self.rejoin[e].attempts.min(16);
                    let delay = self.watchdog.rejoin_backoff * (1u32 << shift);
                    self.rejoin[e].next_try = Some(Instant::now() + delay);
                }
                Some(t) if Instant::now() < t => {}
                Some(_) => self.try_rejoin(e, recorder)?,
            }
        }
        Ok(())
    }

    /// One revive attempt for engine `e`: respawn (fresh backend, fresh
    /// channels, generation-bumped identity), communicator rejoin, KV
    /// re-warm, then quarantine + probe.  Candidate sets stay closed until
    /// a real command round-trips on the new incarnation; a failed probe
    /// re-escalates through the ordinary fault path (each incarnation's
    /// death is one `engine_faults` count).
    fn try_rejoin(&mut self, e: usize, recorder: &mut Recorder) -> Result<()> {
        self.rejoin[e].attempts += 1;
        let attempt = self.rejoin[e].attempts;
        // Next-incarnation script: healthy for `revive_after == 0`, dies
        // again at command k for a crash loop (`revive_after == k > 0`).
        let plan = self.plans[e].revive_plan();
        self.plans[e] = plan.clone();
        self.engine_generation[e] += 1;
        let gen = self.engine_generation[e];
        let t_now = self.now();
        self.fault_stats.engine_revives += 1;
        self.journal.record(t_now, crate::obs::Event::EngineRevive { engine: e as u32 });
        crate::info!("engine {e}: revive attempt {attempt} (incarnation {gen})");
        // Degradation must have left nothing of the old incarnation behind.
        debug_assert!(self.engine_active[e].is_empty(), "revive with residents");
        // 1. Communicator rejoin: tear any round the dead incarnation
        //    stranded (survivors normally already timed out — the watchdog
        //    budget exceeds the comm timeout — so this is usually the
        //    generation-bump no-op) and free the member slot for reuse.
        self.comm.rejoin_member(e);
        // 2. Engine restart.  The old handle's Drop tolerates the dead
        //    worker (send fails silently, join returns immediately); the
        //    fresh channel pair makes stale replies structurally impossible.
        let shapes = StaticShapes { b_dec: self.b_dec, c_prefill: self.c_prefill };
        let handle = EngineHandle::respawn_stub_faulty(
            e,
            gen,
            self.cfg.clone(),
            shapes,
            self.comm.clone(),
            plan,
        )?;
        drop(std::mem::replace(&mut self.engines[e], handle));
        // 3. KV re-warm: the engine restarted empty, so re-admit its block
        //    pool empty too — a fresh adaptor makes that structural.  All
        //    old registrations were reclaimed at degradation time, so no
        //    live request can hold a handle into the replaced slab
        //    (`check_invariants` asserts exactly this).
        self.adaptors[e] = KvCacheAdaptor::new(self.cfg.clone());
        if self.prefix_cache {
            // The fresh adaptor boots with an empty tree; re-arm so the
            // revived engine participates in prefix sharing again.
            self.adaptors[e].enable_prefix_cache();
        }
        self.engine_mode[e] = 1; // fresh backend boots in unit mode
        self.step_err_streak[e] = 0;
        // 4. Quarantine + probe: the engine leaves the failed set but joins
        //    no candidate set until a real command round-trips.
        self.kernel.index.clear_failed(e);
        self.fault_stats.rejoin_probes += 1;
        self.journal
            .record(t_now, crate::obs::Event::RejoinProbe { engine: e as u32, attempt });
        if self.set_mode_watched(e, 1)? {
            self.kernel.index.clear_quarantine(e);
            self.refresh_engine(e);
            self.kernel.on_event(SchedEvent::EngineRejoin { engine: e });
            self.fault_stats.rejoins_ok += 1;
            let t_ok = self.now();
            self.journal.record(t_ok, crate::obs::Event::RejoinOk { engine: e as u32 });
            crate::info!("engine {e}: rejoined (incarnation {gen})");
            self.rejoin[e].next_try = None;
        } else {
            // Probe failed: `note_engine_fault` already re-failed and
            // re-armed the backoff; run degradation now (trivially — the
            // incarnation never hosted anything).
            self.process_faults(recorder)?;
        }
        Ok(())
    }

    /// Drive the recovery state machine to quiescence: process rejoins
    /// (sleeping through backoff windows) until every transiently-dead
    /// engine is either back in service or abandoned.  Terminates because
    /// the cumulative per-engine attempt budget is finite.  Used by the
    /// chaos harness to assert capacity healing after a trace ends (a trace
    /// can complete all its work while a revive is still waiting out its
    /// backoff).
    pub fn drive_rejoins_to_quiescence(&mut self, recorder: &mut Recorder) -> Result<()> {
        while self.rejoin_pending() {
            self.process_rejoins(recorder)?;
            self.process_faults(recorder)?;
            std::thread::sleep(Duration::from_millis(1));
        }
        self.process_faults(recorder)?;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Trace replay driver: submit all requests with arrival offsets, run
    // Algorithm 1 until everything finishes.
    // ------------------------------------------------------------------

    pub fn run_trace(
        &mut self,
        mut trace: Vec<ServeRequest>,
        policy: &mut dyn Policy,
        strategy: Strategy,
    ) -> Result<ClusterOutcome> {
        trace.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        let mut recorder = Recorder::new();
        self.t0 = Instant::now();
        self.n_steps = 0;
        self.recompute_tokens_avoided = 0;
        self.prefill_tokens_avoided = 0;
        self.fault_stats = FaultStats::default();
        self.backfill_binds = 0;
        self.journal.clear();
        self.journal_tick_seq = 0;
        let mut next_arrival = 0usize;
        let mut idle_iters = 0usize;

        loop {
            let now = self.now();

            // Complete any KV-migration transfer still in flight from the
            // previous iteration (ISSUE 9): the loop top is a safe point —
            // no step outstanding anywhere — and the transfer has had a full
            // execute-step round of the other engines to overlap with.
            // No-op with `--overlap` off.
            self.drain_async_migrations()?;

            // Dissolve/settle groups first so freshly-freed engines are
            // visible to this iteration's mode decisions, then run the
            // recovery and graceful-degradation passes for any fault the
            // settle detected (no-ops while the fault queues are empty and
            // `--recover` is off).
            self.settle_groups(&mut recorder)?;
            self.process_rejoins(&mut recorder)?;
            self.process_faults(&mut recorder)?;

            // ① Input processing: admit due arrivals into the task pool.
            while next_arrival < trace.len() && trace[next_arrival].arrival <= now {
                let sr = trace[next_arrival].clone();
                let rec = recorder.on_arrival(sr.id, sr.arrival, sr.priority, sr.prompt.len());
                self.admit(sr, rec);
                next_arrival += 1;
            }

            // ② The globally-agreed waiting order (priority desc, arrival
            // asc) is maintained structurally by the per-priority rings:
            // arrivals are admitted in time order and requeues keep their
            // relative order, so no per-iteration sort is needed.

            // ③+④+⑤ Mode determination, KV parameterization, binding.
            self.assign_waiting(policy, strategy, &mut recorder)?;

            // Journal any fresh control tick the adaptive policy ran during
            // the walk (deduped on `seq`; non-adaptive policies return None
            // and the disabled journal makes this a branch either way).
            if self.journal.is_enabled() {
                if let Some(info) = policy.last_tick() {
                    if info.seq > self.journal_tick_seq {
                        self.journal_tick_seq = info.seq;
                        let t_now = self.now();
                        self.journal.record(t_now, crate::obs::Event::CtrlTick { info });
                    }
                }
            }

            // ⑥ Execute one step on every engine/group with work.
            let stepped = self.execute_step(&mut recorder)?;
            self.process_faults(&mut recorder)?;
            self.drain_prefix_evictions();
            if stepped {
                self.n_steps += 1;
            }

            // Exit/idle handling.  Finished requests leave the slab, so
            // emptiness == everything reached a terminal state.
            if self.active.is_empty() && next_arrival >= trace.len() {
                debug_assert!(self.kernel.rings.is_empty());
                break;
            }
            if !stepped {
                idle_iters += 1;
                // Nothing runnable: sleep until the next arrival.
                if next_arrival < trace.len() {
                    let dt = trace[next_arrival].arrival - self.now();
                    if dt > 0.0 {
                        std::thread::sleep(Duration::from_secs_f64(dt.min(0.05)));
                    }
                } else if self.rejoin_pending() {
                    // A transiently-dead engine is waiting out its rejoin
                    // backoff: this idle window is legitimate, so hold the
                    // stranded sweep and the stall bail (both would
                    // mis-fire) and let the clock advance.  Bounded — the
                    // cumulative attempt budget abandons a crash loop, after
                    // which `rejoin_pending` turns false for good.
                    idle_iters = idle_iters.saturating_sub(1);
                    std::thread::sleep(Duration::from_millis(1));
                } else if self.watchdog.enabled
                    && self.kernel.index.failed_mask() != 0
                    && idle_iters > self.watchdog.stranded_sweep_iters
                {
                    // Degraded cell wedged: the surviving engines cannot
                    // host the remaining waiters (e.g. a TP demand wider
                    // than what is left).  Settle conservation by
                    // rejection instead of spinning into the stall bail.
                    self.reject_stranded(&mut recorder);
                } else if idle_iters > 10_000 {
                    // Requests exist but nothing has run for many
                    // iterations: genuine scheduling bug, fail loudly
                    // instead of hanging.
                    let stuck: Vec<u64> = self
                        .kernel
                        .rings
                        .iter()
                        .filter_map(|&h| self.active.get(h).map(|a| a.sr.id))
                        .collect();
                    bail!("scheduler stall: waiting={stuck:?}");
                }
            } else {
                idle_iters = 0;
            }
        }

        Ok(ClusterOutcome {
            recorder,
            outputs: std::mem::take(&mut self.outputs).into_iter().collect(),
            rejected: std::mem::take(&mut self.rejected),
            switches: std::mem::take(&mut self.switches),
            n_steps: self.n_steps,
            recompute_tokens_avoided: self.recompute_tokens_avoided,
            prefill_tokens_avoided: self.prefill_tokens_avoided,
            fault_stats: self.fault_stats,
        })
    }

    /// Aggregate and journal prefix-cache evictions since the last drain
    /// (ISSUE 10).  Called once per scheduling iteration at the post-step
    /// safe point; a branch-and-return with the flag off, and allocation-
    /// free either way (one fixed sweep over the adaptors).
    fn drain_prefix_evictions(&mut self) {
        if !self.prefix_cache {
            return;
        }
        let mut blocks = 0u32;
        for ad in self.adaptors.iter_mut() {
            blocks = blocks.saturating_add(ad.take_prefix_evicted());
        }
        if blocks > 0 && self.journal.is_enabled() {
            let t_now = self.now();
            self.journal.record(t_now, crate::obs::Event::PrefixEvict { blocks });
        }
    }

    /// Cumulative tokens carried across DP→TP layout changes by KV
    /// migration instead of recompute (for `step_once`-driven harnesses;
    /// [`Self::run_trace`] reports the same figure in its outcome).
    pub fn recompute_tokens_avoided(&self) -> usize {
        self.recompute_tokens_avoided
    }

    /// Submit a request straight into the task pool (schedulable from the
    /// next iteration).  Fine-grained alternative to [`Self::run_trace`]
    /// for streaming drivers and the scheduler benches.
    pub fn submit(&mut self, sr: ServeRequest, recorder: &mut Recorder) {
        let rec = recorder.on_arrival(sr.id, sr.arrival, sr.priority, sr.prompt.len());
        self.admit(sr, rec);
    }

    /// Run one full scheduling iteration (settle → sync → assign →
    /// execute); returns whether any engine stepped.  [`Self::run_trace`]
    /// is this in a loop plus arrival replay.
    pub fn step_once(
        &mut self,
        policy: &mut dyn Policy,
        strategy: Strategy,
        recorder: &mut Recorder,
    ) -> Result<bool> {
        self.drain_async_migrations()?;
        self.settle_groups(recorder)?;
        self.process_rejoins(recorder)?;
        self.process_faults(recorder)?;
        self.assign_waiting(policy, strategy, recorder)?;
        let stepped = self.execute_step(recorder)?;
        self.process_faults(recorder)?;
        self.drain_prefix_evictions();
        if stepped {
            self.n_steps += 1;
        }
        Ok(stepped)
    }

    fn admit(&mut self, sr: ServeRequest, rec: RecSlot) {
        let id = sr.id;
        let pri = sr.priority;
        let emitted = Vec::with_capacity(sr.max_new + 1);
        let h = self.active.insert(Active {
            sr,
            mode_p: 0,
            home: 0,
            phase: Phase::Prefill,
            pos: 0,
            emitted,
            paused: false,
            speculative: false,
            committed: Vec::new(),
            rec,
            kvh: Vec::new(),
            backfill: false,
            retries: 0,
        });
        self.by_id.insert(id, h);
        self.kernel.on_event(SchedEvent::Arrival { h, priority: pri });
    }

    /// Policy snapshot for one walk position; `queue_len` is the kernel
    /// walk's `backlog_now` (requeued-so-far plus not-yet-processed), so
    /// the burst signal sees the true queue depth.
    fn snapshot(&self, queue_len: usize) -> Snapshot {
        let committed: usize = self.engine_committed.iter().sum();
        let capacity = self.engines.len() * (self.cfg.n_blocks - 1);
        Snapshot {
            now: self.now(),
            queue_len,
            idle_engines: self.kernel.index.idle_count(),
            n_engines: self.engines.len(),
            dp_capacity_tokens: self.cfg.dp_token_capacity(),
            max_tp: self.max_tp,
            kv_frac: if capacity == 0 {
                0.0
            } else {
                committed as f64 / capacity as f64
            },
        }
    }

    /// Steps ③–⑤ for every waiting request, as one kernel admission walk:
    /// the kernel owns ring order, backlog accounting, and defer/requeue
    /// semantics; this driver supplies the per-request placement (policy
    /// decision + binding mechanics).
    fn assign_waiting(
        &mut self,
        policy: &mut dyn Policy,
        strategy: Strategy,
        recorder: &mut Recorder,
    ) -> Result<()> {
        // The real path never event-gates its walks: decisions are wall-
        // clock-time-varying (an `AdaptivePolicy` control tick can flip a
        // decision with no kernel event at all), so every iteration dirties
        // unconditionally.  Do NOT replace this with `SchedEvent`-driven
        // dirtying (completions/settles/plan changes): it would be sound
        // for stateless policies but silently starve adaptive re-walks.
        self.kernel.note_dirty();
        if !self.kernel.should_walk() {
            return Ok(());
        }
        if self.switch_cfg.backfill {
            self.refresh_drain_horizons();
        }
        let mut walk = self.kernel.begin_walk();
        let mut result = Ok(());
        while let Some((h, high)) = walk.next() {
            let backlog_now = walk.backlog_now();
            match self.place_waiting(h, backlog_now, policy, strategy, recorder) {
                Ok((rid, placement)) => walk.settle(h, high, rid, placement),
                Err(e) => {
                    // The request may be partially bound (blocks committed,
                    // adaptor registrations issued) when a placement errors:
                    // do NOT requeue it — a re-walk could double-bind it.
                    // Consuming the entry without settling matches the
                    // pre-kernel error path; the undrained remainder is
                    // restored in order by end_walk.
                    result = Err(e);
                    break;
                }
            }
        }
        self.kernel.end_walk(walk);
        result
    }

    /// Decide and bind one waiting request (the driver half of the walk).
    fn place_waiting(
        &mut self,
        h: SlabHandle,
        backlog_now: usize,
        policy: &mut dyn Policy,
        strategy: Strategy,
        recorder: &mut Recorder,
    ) -> Result<(u64, Placement)> {
        let snap = self.snapshot(backlog_now);
        let (rid, plen, hint, pri, demand) = {
            let a = self.active.get(h).expect("waiting handle must be live");
            (
                a.sr.id,
                a.sr.prompt.len(),
                a.sr.max_new,
                a.sr.priority,
                a.sr.tp_demand,
            )
        };
        let placement = match policy.decide_for(rid, plen, hint, pri, demand, &snap) {
            ModeDecision::Reject => {
                let now = self.now();
                let a = self.active.remove(h).expect("live");
                self.by_id.remove(&a.sr.id);
                self.rejected.push(a.sr.id);
                recorder.on_finish_at(a.rec, now);
                Placement::Reject
            }
            ModeDecision::Dp => self.try_bind_dp(h, recorder)?,
            ModeDecision::Tp(p) => {
                let p = self.clamp_tp(p);
                if p == 1 {
                    // Degenerate TP (single engine / unsupported width).
                    self.try_bind_dp(h, recorder)?
                } else {
                    self.bind_tp(h, p, strategy, recorder)?
                }
            }
        };
        Ok((rid, placement))
    }

    /// Worst-case block demand under layout `p` (admission unit).
    fn block_need(&self, h: SlabHandle, p: usize) -> usize {
        let a = self.active.get(h).expect("live");
        let total = a.sr.prompt.len() + a.sr.max_new;
        total.div_ceil(self.cfg.block_tokens(p))
    }

    fn commit(&mut self, h: SlabHandle, e: usize, blocks: usize) {
        self.engine_committed[e] += blocks;
        self.active.get_mut(h).expect("live").committed.push((e, blocks));
    }

    fn uncommit_all(&mut self, h: SlabHandle) {
        let committed = std::mem::take(&mut self.active.get_mut(h).expect("live").committed);
        for (e, blocks) in committed {
            self.engine_committed[e] -= blocks;
        }
    }

    /// Bind to the least-loaded unbound engine with KV headroom, or defer.
    /// Candidates come from the kernel's unit/draining bitmask index —
    /// O(set bits) instead of a predicate scan over every engine.  In
    /// backfill mode a draining engine is a second-choice candidate when
    /// the kernel's horizon predicate admits the request.
    fn try_bind_dp(&mut self, h: SlabHandle, recorder: &mut Recorder) -> Result<Placement> {
        let need = self.block_need(h, 1);
        let mut candidates = self.kernel.index.dp_candidates();
        let mut ll = LeastLoaded::new();
        while candidates != 0 {
            let e = candidates.trailing_zeros() as usize;
            candidates &= candidates - 1;
            if self.engine_committed[e] + need > self.cfg.n_blocks - 1 {
                continue;
            }
            ll.offer(e, self.engine_active[e].len());
        }
        let mut pick = ll.pick();
        let mut backfill = false;
        if pick.is_none() && self.switch_cfg.backfill {
            if let Some((e, fit_s)) = self.pick_backfill_engine(h, need) {
                pick = Some(e);
                self.active.get_mut(h).expect("live").backfill = true;
                backfill = true;
                self.backfill_binds += 1;
                let rid = self.active.get(h).expect("live").sr.id;
                let horizon_s =
                    *self.scratch.horizon_s_by_engine.get(e).unwrap_or(&0.0);
                let t_now = self.now();
                self.journal.record(
                    t_now,
                    crate::obs::Event::BackfillAdmit {
                        rid,
                        engine: e as u32,
                        fit_s,
                        horizon_s,
                    },
                );
            }
        }
        match pick {
            Some(e) => {
                self.commit(h, e, need);
                self.bind_dp(h, e, recorder)?;
                Ok(Placement::Dp { unit: e as u32, backfill })
            }
            None => Ok(Placement::Defer),
        }
    }

    /// Wall-clock seconds of work a resident request still owes its engine
    /// under the calibrated cost model (remaining chunked prefill + decode
    /// tail) — the per-resident term of the drain horizon, computed by the
    /// kernel so it is denominated identically to the predicate's request
    /// side.
    fn remaining_work_s(&self, a: &Active) -> f64 {
        let total = a.sr.prompt.len() + a.emitted.len().saturating_sub(1);
        let pre_left = total.saturating_sub(a.pos);
        let dec_left = a.sr.max_new.saturating_sub(a.emitted.len());
        let g = self.migrate_cm.model.min_gpus;
        crate::sched::remaining_work_s(
            &self.migrate_cm,
            pre_left,
            dec_left,
            a.pos,
            g,
            self.c_prefill,
            0.0,
        )
    }

    /// Recompute every draining engine's drain horizon — the largest
    /// predicted remaining work (calibrated wall-clock seconds) among
    /// resident (non-paused, non-speculative, non-backfill) requests on any
    /// member of its group — into the per-pass scratch cache.  One
    /// group/member scan serves the whole `assign_waiting` walk: horizons
    /// only change when engines step, never mid-walk (backfill admissions
    /// are excluded from the horizon).  Formerly denominated in scheduler
    /// steps; the calibrated `CostModel` (see [`Self::calibrate`]) lets the
    /// real path run the simulator's exact wall-clock predicate instead.
    fn refresh_drain_horizons(&mut self) {
        let mut horizons = std::mem::take(&mut self.scratch.horizon_s_by_engine);
        horizons.clear();
        horizons.resize(self.engines.len(), 0.0);
        for (&start, g) in &self.groups {
            if g.tp_pending.is_empty() {
                continue;
            }
            let mut horizon = 0.0f64;
            for m in self.members(start, g.p) {
                for &x in &self.engine_active[m] {
                    if let Some(a) = self.active.get(x) {
                        if !a.paused && !a.speculative && !a.backfill {
                            horizon = horizon.max(self.remaining_work_s(a));
                        }
                    }
                }
            }
            if horizon > 0.0 {
                for m in self.members(start, g.p) {
                    if m < horizons.len() {
                        horizons[m] = horizon;
                    }
                }
            }
        }
        self.scratch.horizon_s_by_engine = horizons;
    }

    /// Backfill candidate among draining unit engines: block headroom, a
    /// free backfill slot, and the kernel's horizon predicate — the
    /// request's predicted solo completion (prefill charged twice: engines
    /// issue prefill-first, so each backfill prefill chunk also displaces a
    /// resident decode step and extends the drain) must land inside
    /// `backfill_margin ×` the drain window.  Returns the engine and the
    /// request's predicted solo completion (the flight recorder logs the
    /// fit against the drain horizon it was admitted under).
    fn pick_backfill_engine(&self, h: SlabHandle, need: usize) -> Option<(usize, f64)> {
        let (prompt, max_new) = {
            let a = self.active.get(h)?;
            (a.sr.prompt.len(), a.sr.max_new)
        };
        let g = self.migrate_cm.model.min_gpus;
        // The request's predicted completion is engine-independent (start
        // 0, fixed width/chunk), so run the kernel predicate once against
        // the largest candidate window — the budget short-circuits the walk
        // past it — and compare the returned finish per engine, instead of
        // re-walking the chunk/decode schedule per candidate.
        let margin = self.switch_cfg.backfill_margin;
        let mut max_deadline = 0.0f64;
        let mut candidates = self.kernel.index.backfill_candidates();
        while candidates != 0 {
            let e = candidates.trailing_zeros() as usize;
            candidates &= candidates - 1;
            let horizon_s = *self.scratch.horizon_s_by_engine.get(e).unwrap_or(&0.0);
            max_deadline = max_deadline.max(margin * horizon_s);
        }
        if max_deadline <= 0.0 {
            return None;
        }
        let fin = crate::sched::backfill_fit(
            &self.migrate_cm,
            0.0,
            prompt,
            max_new,
            g,
            self.c_prefill,
            0.0,
            true,
            max_deadline,
        )?;
        let mut candidates = self.kernel.index.backfill_candidates();
        let mut ll = LeastLoaded::new();
        while candidates != 0 {
            let e = candidates.trailing_zeros() as usize;
            candidates &= candidates - 1;
            if self.engine_committed[e] + need > self.cfg.n_blocks - 1 {
                continue;
            }
            let n_bf = self
                .engine_active[e]
                .iter()
                .filter(|&&x| self.active.get(x).map(|a| a.backfill).unwrap_or(false))
                .count();
            if n_bf >= self.switch_cfg.max_backfill_per_engine {
                continue;
            }
            let horizon_s = *self.scratch.horizon_s_by_engine.get(e).unwrap_or(&0.0);
            if horizon_s <= 0.0 || fin > margin * horizon_s {
                continue;
            }
            ll.offer(e, self.engine_active[e].len());
        }
        ll.pick().map(|e| (e, fin))
    }

    fn clamp_tp(&self, p: usize) -> usize {
        let mut q = 1;
        while q * 2 <= p && q * 2 <= self.engines.len() && self.cfg.supports_tp(q * 2) {
            q *= 2;
        }
        q
    }

    fn bind_dp(&mut self, h: SlabHandle, e: usize, recorder: &mut Recorder) -> Result<()> {
        let rid = self.active.get(h).expect("live").sr.id;
        let kh = self.adaptors[e].register(rid, 1)?;
        // Prefix-cache admission (ISSUE 10, `--prefix-cache` only): probe
        // the engine's radix tree with the prompt and adopt the matched
        // whole-block chain by reference — those tokens are never prefilled
        // (`pos` starts past them).  The hit length comes from the shared
        // kernel predicate (`sched::prefix_hit`), which floors to block
        // granularity and always leaves at least the prompt's last token to
        // prefill, so the first chunk is non-empty and decode still seeds
        // from a freshly-computed forward pass.
        let mut hit = 0usize;
        if self.prefix_cache {
            let a = self.active.get(h).expect("live");
            let matched = self.adaptors[e].prefix_probe(&a.sr.prompt);
            hit = crate::sched::prefix_hit(
                matched,
                a.sr.prompt.len(),
                self.cfg.block_tokens(1),
            );
            if hit > 0 {
                self.adaptors[e].prefix_adopt(kh, &a.sr.prompt, hit)?;
                self.prefill_tokens_avoided += hit;
                let t_now = self.now();
                self.journal.record(
                    t_now,
                    crate::obs::Event::PrefixHit { rid, tokens: hit as u64 },
                );
            }
        }
        let now = self.now();
        let a = self.active.get_mut(h).expect("live");
        a.mode_p = 1;
        a.home = e;
        a.pos = hit;
        a.kvh.push((e, kh));
        let rec = a.rec;
        self.engine_active[e].push(h);
        self.refresh_engine(e);
        recorder.on_first_sched_at(rec, now);
        Ok(())
    }

    /// Bind (or make pending) a TP request onto an aligned group of width
    /// p; `Placement::Defer` when no compatible group is formable now.
    fn bind_tp(
        &mut self,
        h: SlabHandle,
        p: usize,
        strategy: Strategy,
        recorder: &mut Recorder,
    ) -> Result<Placement> {
        // Prefer an already-bound group at this width with batch room, else
        // the group whose members have the least DP work.  Starts whose
        // members belong to a live group of a *different* width are excluded
        // (a group can only be re-bound after it dissolves).
        let conflict = |s: usize| {
            self.groups.iter().any(|(&gs, g)| {
                let overlap = gs < s + p && s < gs + g.p;
                overlap
                    && (gs != s || g.p != p)
                    && (!g.tp_active.is_empty() || !g.tp_pending.is_empty())
            })
        };
        let failed = self.kernel.index.failed_mask();
        let mut bound: Option<usize> = None;
        let mut best: Option<(usize, usize)> = None; // (load, start)
        let mut any_start = false;
        let mut s = 0usize;
        while s + p <= self.engines.len() {
            // A span containing a fail-stopped engine can never form a
            // group (no-op while the failed mask is zero).
            let span = (((1u128 << p) - 1) as u64) << s;
            if failed & span == 0 && !conflict(s) {
                any_start = true;
                if self
                    .groups
                    .get(&s)
                    .map(|g| g.p == p && g.tp_active.len() < self.b_dec)
                    .unwrap_or(false)
                {
                    if bound.is_none() {
                        bound = Some(s);
                    }
                } else if bound.is_none() {
                    let load: usize = self
                        .members(s, p)
                        .map(|e| {
                            self.engine_active[e].len()
                                + 100 * (self.engine_mode[e] > 1) as usize
                        })
                        .sum();
                    if best.map(|(l, _)| load < l).unwrap_or(true) {
                        best = Some((load, s));
                    }
                }
            }
            s += p;
        }
        if !any_start {
            // No compatible group right now; retry next iteration.
            return Ok(Placement::Defer);
        }
        let start = bound.unwrap_or_else(|| best.map(|(_, s)| s).unwrap());

        // Admission control: all members must have block headroom for the
        // request's worst case under layout p.
        let need_p = self.block_need(h, p);
        let room = self
            .members(start, p)
            .all(|e| self.engine_committed[e] + need_p <= self.cfg.n_blocks - 1);
        if !room {
            return Ok(Placement::Defer);
        }

        let mut busy = std::mem::take(&mut self.scratch.busy);
        busy.clear();
        for e in self.members(start, p) {
            for &x in &self.engine_active[e] {
                if self
                    .active
                    .get(x)
                    .map(|a| !a.paused)
                    .unwrap_or(false)
                {
                    busy.push(x);
                }
            }
        }

        let g = self.groups.entry(start).or_insert_with(|| Group { p, ..Default::default() });
        g.p = p;

        if busy.is_empty() && !self.group_live(start, p) {
            // Immediate bind at a safe point.
            self.switch_group(start, p, p)?;
        }

        if self.group_live(start, p) {
            // Register in every member adaptor (identical logical content,
            // per-member physical block ids).
            let rid = self.active.get(h).expect("live").sr.id;
            for e in self.members(start, p) {
                self.commit(h, e, need_p);
                let kh = self.adaptors[e].register(rid, p)?;
                self.active.get_mut(h).expect("live").kvh.push((e, kh));
            }
            let a = self.active.get_mut(h).expect("live");
            a.mode_p = p;
            a.home = start;
            let rec = a.rec;
            self.groups.get_mut(&start).unwrap().tp_active.push(h);
            recorder.on_first_sched_at(rec, self.now());
            self.scratch.busy = busy;
            return Ok(Placement::Tp { width: p as u32 });
        }

        // Members still busy: strategy decides.  The first pending request
        // opens the group's transition window — journal it (later joins
        // extend the same drain, not a new one).
        let member_bits = self
            .members(start, p)
            .filter(|&e| e < self.engines.len())
            .fold(0u64, |acc, e| acc | (1u64 << e));
        let opening = self.groups[&start].tp_pending.is_empty();
        if opening && self.prefix_cache {
            // A fresh transition window (ISSUE 10): re-arm the members'
            // scatter-once epoch so sharers promoted inside this window pay
            // the data-plane cost of their shared leading blocks exactly
            // once (the first sharer's scatter covers the chain; later
            // co-migrating sharers are discounted in `plan_migration`).
            for e in self.members(start, p) {
                self.adaptors[e].begin_switch_epoch();
            }
        }
        if opening && matches!(strategy, Strategy::Sequential | Strategy::SoftPreempt) {
            let t_now = self.now();
            self.journal.record(
                t_now,
                crate::obs::Event::DrainBegin {
                    group: start as u32,
                    width: p as u32,
                    members: member_bits,
                    // The real path predicts drain horizons per assign pass
                    // (see `refresh_drain_horizons`); none exists yet when
                    // the window opens, so the span's horizon is unknown.
                    horizon_s: 0.0,
                },
            );
        }
        match strategy {
            Strategy::Sequential => {
                self.groups.get_mut(&start).unwrap().tp_pending.push(h);
                self.refresh_draining();
                let a = self.active.get_mut(h).expect("live");
                a.mode_p = p;
                a.home = start;
            }
            Strategy::SoftPreempt => {
                self.groups.get_mut(&start).unwrap().tp_pending.push(h);
                self.refresh_draining();
                {
                    let a = self.active.get_mut(h).expect("live");
                    a.mode_p = p;
                    a.home = start;
                }
                // Speculatively run in DP on the least-loaded member (only
                // if a member has DP-layout headroom).
                let need_dp = self.block_need(h, 1);
                let e = self
                    .members(start, p)
                    .filter(|&e| self.engine_committed[e] + need_dp <= self.cfg.n_blocks - 1)
                    .min_by_key(|&e| self.engine_active[e].len());
                if let Some(e) = e {
                    self.commit(h, e, need_dp);
                    let rid = self.active.get(h).expect("live").sr.id;
                    let kh = self.adaptors[e].register(rid, 1)?;
                    let a = self.active.get_mut(h).expect("live");
                    a.speculative = true;
                    a.mode_p = 1; // runs as DP for now
                    a.home = e;
                    a.kvh.push((e, kh));
                    let rec = a.rec;
                    self.engine_active[e].push(h);
                    self.refresh_engine(e);
                    recorder.on_first_sched_at(rec, self.now());
                }
            }
            Strategy::HardPreempt => {
                // Pause members' DP requests in place (KV stays resident).
                for &x in busy.iter() {
                    let info = self.active.get_mut(x).map(|a| {
                        a.paused = true;
                        (a.home, a.sr.id)
                    });
                    if let Some((home, rid)) = info {
                        self.adaptors[home].pause(rid)?;
                    }
                }
                self.switch_group(start, p, p)?;
                let rid = self.active.get(h).expect("live").sr.id;
                for e in self.members(start, p) {
                    self.commit(h, e, need_p);
                    let kh = self.adaptors[e].register(rid, p)?;
                    self.active.get_mut(h).expect("live").kvh.push((e, kh));
                }
                let a = self.active.get_mut(h).expect("live");
                a.mode_p = p;
                a.home = start;
                let rec = a.rec;
                self.groups.get_mut(&start).unwrap().tp_active.push(h);
                recorder.on_first_sched_at(rec, self.now());
            }
        }
        self.scratch.busy = busy;
        Ok(Placement::Tp { width: p as u32 })
    }

    /// Promote pending TP requests whose group has finished draining, and
    /// dissolve groups whose TP work is done.  In backfill mode, members
    /// settle incrementally: each is switched into the target mode as soon
    /// as its own work drains.
    fn settle_groups(&mut self, recorder: &mut Recorder) -> Result<()> {
        if self.groups.is_empty() {
            return Ok(());
        }
        let mut starts = std::mem::take(&mut self.scratch.starts);
        starts.clear();
        starts.extend(self.groups.keys().copied());
        let mut held = std::mem::take(&mut self.scratch.held_by_engine);
        let mut plan = std::mem::take(&mut self.scratch.migration_plan);
        let mut dirty_draining = false;
        for si in 0..starts.len() {
            let start = starts[si];
            let (p, pending_empty, active_empty) = {
                let g = &self.groups[&start];
                (g.p, g.tp_pending.is_empty(), g.tp_active.is_empty())
            };

            // Dissolve: TP work done -> back to DP, resume paused requests.
            // (`any mode != 1` rather than `mode[start] == p`: incremental
            // settle can leave a proper subset of members switched.)
            if pending_empty && active_empty {
                if p > 1 && self.members(start, p).any(|e| self.engine_mode[e] != 1) {
                    self.switch_group(start, p, 1)?;
                    let mut resumed = std::mem::take(&mut self.scratch.ids);
                    for e in self.members(start, p) {
                        resumed.clear();
                        for &x in &self.engine_active[e] {
                            if self.active.get(x).map(|a| a.paused).unwrap_or(false) {
                                resumed.push(x);
                            }
                        }
                        for &x in resumed.iter() {
                            let rid = self.active.get(x).expect("live").sr.id;
                            self.adaptors[e].resume(rid)?;
                            self.active.get_mut(x).expect("live").paused = false;
                        }
                    }
                    self.scratch.ids = resumed;
                }
                self.groups.remove(&start);
                dirty_draining = true;
                continue;
            }

            if !pending_empty {
                // A group that lost a member cannot settle or promote —
                // leave it untouched for the fault pass to dissolve (a
                // no-op scan while the failed mask is zero).
                if self
                    .members(start, p)
                    .any(|e| self.kernel.index.is_failed(e))
                {
                    continue;
                }
                // Incremental settle: members whose own work has drained
                // merge into the target mode now instead of idling behind
                // the slowest straggler (backfill mode only — off keeps the
                // one-shot switch, byte-identical to PR 1/2).
                if self.switch_cfg.backfill {
                    for e in self.members(start, p) {
                        let bit = 1u64 << e;
                        // The kernel's settle rule: a member flips as soon
                        // as its own work drains, once.  Check the cheap
                        // flags first so the O(|engine_active|) busy scan
                        // only runs for members the rule could still pass
                        // (already-settled members are the steady state
                        // late in a drain).
                        if !lifecycle::member_settle_due(
                            self.groups[&start].settled_mask & bit != 0,
                            self.engine_mode[e] == 1,
                            false,
                        ) {
                            continue;
                        }
                        let member_busy = self.engine_active[e].iter().any(|&x| {
                            self.active
                                .get(x)
                                .map(|a| !a.paused)
                                .unwrap_or(false)
                        });
                        if member_busy {
                            continue;
                        }
                        if !self.set_mode_watched(e, p)? {
                            continue;
                        }
                        self.engine_mode[e] = p;
                        self.refresh_engine(e);
                        self.groups.get_mut(&start).unwrap().settled_mask |= bit;
                        let t_now = self.now();
                        self.journal.record(
                            t_now,
                            crate::obs::Event::MemberSettle {
                                group: start as u32,
                                members: bit,
                            },
                        );
                    }
                }

                // Drained? (no unpaused DP work on members; the speculative
                // request IS the pending one — it yields now.)
                let busy = self
                    .members(start, p)
                    .flat_map(|e| self.engine_active[e].iter())
                    .any(|&x| {
                        self.active
                            .get(x)
                            .map(|a| !a.paused && !a.speculative)
                            .unwrap_or(false)
                    });
                if !busy {
                    // Every pending request may have finished speculatively
                    // during the drain (stale handles): then there is
                    // nothing to promote — drop the list without the p→p
                    // mode round-trip and let the next settle pass dissolve
                    // the group (resetting any incrementally-settled
                    // members), instead of logging a spurious switch.
                    let any_live_pending = self.groups[&start]
                        .tp_pending
                        .iter()
                        .any(|&x| self.active.get(x).is_some());
                    if !any_live_pending {
                        let g = self.groups.get_mut(&start).unwrap();
                        g.tp_pending.clear();
                        g.settled_mask = 0;
                        dirty_draining = true;
                        continue;
                    }
                    if !self.group_live(start, p) {
                        self.switch_group(start, p, p)?;
                        // The switch itself can detect a member fault:
                        // abort the promotion, the fault pass dissolves.
                        if self
                            .members(start, p)
                            .any(|e| self.kernel.index.is_failed(e))
                        {
                            continue;
                        }
                    } else if self.groups[&start].settled_mask != 0 {
                        // Every member settled incrementally: the final hop
                        // is free — log it so Table-2 switch counts stay
                        // comparable across modes.
                        let t = self.now();
                        self.switches.push(SwitchEvent {
                            t,
                            group_start: start,
                            p_from: 1,
                            p_to: p,
                            latency_s: 0.0,
                        });
                    }
                    self.groups.get_mut(&start).unwrap().settled_mask = 0;
                    let pending =
                        std::mem::take(&mut self.groups.get_mut(&start).unwrap().tp_pending);
                    dirty_draining = true;
                    for h in pending {
                        // A soft-preempted speculative request can finish
                        // during the drain; its handle has gone stale
                        // (generation check) and is skipped, not promoted.
                        if self.active.get(h).is_none() {
                            continue;
                        }
                        // Admission: TP-layout headroom on every member
                        // (the request's own held commitment is discounted).
                        // Held-per-engine is filled once per request —
                        // O(|committed|) total — instead of re-filtering the
                        // committed list for every group member.
                        let need_p = self.block_need(h, p);
                        held.clear();
                        held.resize(self.engines.len(), 0);
                        for &(ce, b) in &self.active.get(h).expect("live").committed {
                            held[ce] += b;
                        }
                        let room = self.members(start, p).all(|e| {
                            self.engine_committed[e] - held[e] + need_p
                                <= self.cfg.n_blocks - 1
                        });
                        if !room {
                            self.groups.get_mut(&start).unwrap().tp_pending.push(h);
                            continue;
                        }
                        let (was_spec, spec_home, rid, kv_pos) = {
                            let a = self.active.get(h).expect("live");
                            (a.speculative, a.home, a.sr.id, a.pos)
                        };
                        // Migrate-vs-recompute (ISSUE 4/5): the kernel's
                        // carry gate — the identical rule the sim event
                        // core applies — decides whether the speculative
                        // request's KV bytes are carried across the layout
                        // change or re-prefilled.
                        let migrate_kv = lifecycle::carry_wins(
                            &self.migrate_cm,
                            self.switch_cfg.migrate,
                            was_spec,
                            kv_pos,
                            p * self.migrate_cm.model.min_gpus,
                        );
                        if migrate_kv
                            && self.overlap_cfg.async_migrate_on()
                            && self.async_busy & self.member_mask(start, p) != 0
                        {
                            // One tagged transfer per member set (ISSUE 9):
                            // `CHANNEL_DEPTH` is 2, so stacking a second
                            // scatter on engines still running one could
                            // deadlock the lockstep — complete the in-flight
                            // transfer first, then re-check the members.
                            self.drain_async_migrations()?;
                            if self.members(start, p).any(|e| self.kernel.index.is_failed(e)) {
                                self.groups.get_mut(&start).unwrap().tp_pending.push(h);
                                continue;
                            }
                        }
                        if migrate_kv {
                            // Home side: pin seq_len to the cached position
                            // (prefill never advances it), then re-tag the
                            // DP blocks in place as TP shard views through
                            // the reusable scratch plan — zero copy, zero
                            // steady-state allocation.
                            let kh_home = self
                                .active
                                .get(h)
                                .expect("live")
                                .kvh
                                .iter()
                                .find(|&&(ke, _)| ke == spec_home)
                                .map(|&(_, kh)| kh)
                                .ok_or_else(|| {
                                    anyhow::anyhow!(
                                        "speculative request {rid} has no kv registration on engine {spec_home}"
                                    )
                                })?;
                            self.adaptors[spec_home].set_seq_len_h(kh_home, kv_pos)?;
                            self.adaptors[spec_home].plan_migration(kh_home, p, &mut plan)?;
                            let t_now = self.now();
                            self.journal.record(
                                t_now,
                                crate::obs::Event::MigratePlan {
                                    rid,
                                    tokens: kv_pos as u64,
                                    elems: plan.elems_per_member as u64,
                                },
                            );
                            self.adaptors[spec_home].apply_migration(kh_home, &plan)?;
                            self.engine_active[spec_home].retain(|&x| x != h);
                            self.refresh_engine(spec_home);
                            self.active.get_mut(h).expect("live").speculative = false;
                            self.uncommit_all(h);
                            // The other members allocate fresh blocks for
                            // their shard slices; the home registration (and
                            // its handle) survives as-is.
                            for e in self.members(start, p) {
                                self.commit(h, e, need_p);
                                if e != spec_home {
                                    let kh = self.adaptors[e].register(rid, p)?;
                                    self.adaptors[e].ensure_capacity_h(kh, kv_pos)?;
                                    self.adaptors[e].set_seq_len_h(kh, kv_pos)?;
                                    self.active.get_mut(h).expect("live").kvh.push((e, kh));
                                }
                            }
                            // Data plane: the whole group meets the scatter
                            // at this safe point (lockstep guarantees no
                            // step is in flight), moving only the other
                            // members' head slices over the interconnect.
                            for e in self.members(start, p) {
                                self.engines[e].send(EngineCmd::KvMigrate {
                                    p,
                                    root: spec_home,
                                    n_elems: plan.elems_per_member,
                                });
                            }
                            if self.overlap_cfg.async_migrate_on() {
                                // Overlap 2 (ISSUE 9): leave the scatter in
                                // flight as a tagged transfer instead of
                                // blocking here.  The member engines execute
                                // it concurrently with the next decode steps
                                // on every *other* engine; the replies (and
                                // the deferred `MigrateApply` bookkeeping)
                                // are collected at the next safe point by
                                // `drain_async_migrations`.  The metadata
                                // tail below still runs now — the adaptor
                                // state is already migrated, only the data-
                                // plane completion is outstanding, and the
                                // busy mask keeps the group unstepped until
                                // it lands.
                                self.async_busy |= self.member_mask(start, p);
                                let t_now = self.now();
                                self.async_migrations.push(AsyncMigration {
                                    h,
                                    rid,
                                    start,
                                    p,
                                    kv_pos,
                                    issued_at: t_now,
                                });
                                self.journal.record(
                                    t_now,
                                    crate::obs::Event::AsyncMigrateBegin {
                                        rid,
                                        tokens: kv_pos as u64,
                                        window_s: 0.0,
                                    },
                                );
                            } else {
                                // Collect every member's reply before
                                // surfacing an error: bailing mid-collection
                                // would leave replies queued on the
                                // persistent channels and mis-attribute them
                                // to the next command a `step_once`-driven
                                // host issues.
                                let mut first_err: Option<String> = None;
                                let mut faulted = false;
                                for e in self.members(start, p) {
                                    if self.watchdog.enabled {
                                        match self.recv_reply_watched(e) {
                                            Ok(EngineReply::Err(msg)) => {
                                                if first_err.is_none() {
                                                    first_err =
                                                        Some(format!("engine {e}: {msg}"));
                                                }
                                            }
                                            Ok(_) => {}
                                            Err(kind) => {
                                                self.note_engine_fault(e, kind);
                                                faulted = true;
                                            }
                                        }
                                    } else {
                                        match self.engines[e].recv() {
                                            Ok(EngineReply::Err(msg)) => {
                                                if first_err.is_none() {
                                                    first_err =
                                                        Some(format!("engine {e}: {msg}"));
                                                }
                                            }
                                            Ok(_) => {}
                                            Err(dead) => {
                                                if first_err.is_none() {
                                                    first_err = Some(dead.to_string());
                                                }
                                            }
                                        }
                                    }
                                }
                                if faulted || (self.watchdog.enabled && first_err.is_some()) {
                                    // Safe transition abort (ISSUE 6): the
                                    // adaptor metadata is self-consistent
                                    // after `apply_migration`, so recovery
                                    // can reclaim the re-tagged blocks and
                                    // requeue the request for recompute at
                                    // the next fault pass — no state
                                    // violates the group invariants in the
                                    // meantime.
                                    self.fault_stats.step_errors += usize::from(!faulted);
                                    if !faulted {
                                        let t_now = self.now();
                                        self.journal.record(
                                            t_now,
                                            crate::obs::Event::StepError {
                                                engine: start as u32,
                                                streak: 0,
                                            },
                                        );
                                    }
                                    self.fault_recover.push(h);
                                    continue;
                                }
                                if let Some(msg) = first_err {
                                    bail!("kv migration failed: {msg}");
                                }
                                self.recompute_tokens_avoided += kv_pos;
                                let t_now = self.now();
                                self.journal.record(
                                    t_now,
                                    crate::obs::Event::MigrateApply {
                                        rid,
                                        tokens: kv_pos as u64,
                                        cost_s: 0.0,
                                    },
                                );
                            }
                            // pos/phase stay untouched: decode (or the
                            // remaining prefill) resumes exactly where the
                            // speculative run left off — nothing recomputed.
                        } else {
                            if was_spec {
                                // Drop the speculative DP-layout KV and
                                // schedule the TP recompute (§5.2.2) — the
                                // PR-1/3 path, byte-identical with the
                                // migrate flag off.
                                self.adaptors[spec_home].release(rid)?;
                                self.engine_active[spec_home].retain(|&x| x != h);
                                self.refresh_engine(spec_home);
                                let a = self.active.get_mut(h).expect("live");
                                a.kvh.retain(|&(e, _)| e != spec_home);
                                a.speculative = false;
                                // Recompute prompt + already-fed output tokens;
                                // the emitted tail token is re-fed automatically
                                // (decode always feeds `emitted.last()`).
                                a.pos = 0;
                                a.phase = Phase::Prefill;
                            }
                            self.uncommit_all(h);
                            for e in self.members(start, p) {
                                self.commit(h, e, need_p);
                                let kh = self.adaptors[e].register(rid, p)?;
                                self.active.get_mut(h).expect("live").kvh.push((e, kh));
                            }
                        }
                        let a = self.active.get_mut(h).expect("live");
                        a.mode_p = p;
                        a.home = start;
                        a.backfill = false;
                        let rec = a.rec;
                        self.groups.get_mut(&start).unwrap().tp_active.push(h);
                        recorder.on_first_sched_at(rec, self.now());
                    }
                }
            }
        }
        self.scratch.starts = starts;
        self.scratch.held_by_engine = held;
        self.scratch.migration_plan = plan;
        if dirty_draining {
            self.refresh_draining();
        }
        Ok(())
    }

    /// Complete every tagged in-flight KV-migration transfer (ISSUE 9).
    /// Called only at safe points: the scheduling-loop top, `step_once`
    /// entry, `process_faults` entry (before any group touching the members
    /// could be degraded), before stacking a second transfer on the same
    /// member set, and best-effort at shutdown.  A no-op — one branch —
    /// unless `--overlap` issued a transfer, so the off path is untouched.
    ///
    /// Error semantics mirror the inline collection exactly: with the
    /// watchdog on, a member fault or step error marks the request for
    /// recovery at the next fault pass (the adaptor metadata is already
    /// self-consistent after `apply_migration`); with it off, a reply-level
    /// error is fatal after all members were collected.
    fn drain_async_migrations(&mut self) -> Result<()> {
        if self.async_migrations.is_empty() {
            return Ok(());
        }
        let mut transfers = std::mem::take(&mut self.async_migrations);
        self.async_busy = 0;
        for m in transfers.drain(..) {
            let mut first_err: Option<String> = None;
            let mut faulted = false;
            for e in self.members(m.start, m.p) {
                if self.kernel.index.is_failed(e) {
                    // Already fail-stopped by an earlier drain round: its
                    // channel is dead, nothing to collect.
                    faulted = true;
                    continue;
                }
                if self.watchdog.enabled {
                    match self.recv_reply_watched(e) {
                        Ok(EngineReply::Err(msg)) => {
                            if first_err.is_none() {
                                first_err = Some(format!("engine {e}: {msg}"));
                            }
                        }
                        Ok(_) => {}
                        Err(kind) => {
                            self.note_engine_fault(e, kind);
                            faulted = true;
                        }
                    }
                } else {
                    match self.engines[e].recv() {
                        Ok(EngineReply::Err(msg)) => {
                            if first_err.is_none() {
                                first_err = Some(format!("engine {e}: {msg}"));
                            }
                        }
                        Ok(_) => {}
                        Err(dead) => {
                            if first_err.is_none() {
                                first_err = Some(dead.to_string());
                            }
                        }
                    }
                }
            }
            if faulted || (self.watchdog.enabled && first_err.is_some()) {
                self.fault_stats.step_errors += usize::from(!faulted);
                if !faulted {
                    let t_now = self.now();
                    self.journal.record(
                        t_now,
                        crate::obs::Event::StepError {
                            engine: m.start as u32,
                            streak: 0,
                        },
                    );
                }
                // Generational handle: if the request already finished or
                // was recovered meanwhile, this resolves to a no-op.
                self.fault_recover.push(m.h);
                continue;
            }
            if let Some(msg) = first_err {
                bail!("kv migration failed: {msg}");
            }
            self.recompute_tokens_avoided += m.kv_pos;
            let t_now = self.now();
            self.journal.record(
                t_now,
                crate::obs::Event::MigrateApply {
                    rid: m.rid,
                    tokens: m.kv_pos as u64,
                    cost_s: 0.0,
                },
            );
            self.journal.record(
                t_now,
                crate::obs::Event::AsyncMigrateEnd {
                    rid: m.rid,
                    overlapped_s: (t_now - m.issued_at).max(0.0),
                },
            );
        }
        // Hand the (now empty) vec back so its capacity is reused — the
        // steady state stays allocation-free.
        self.async_migrations = transfers;
        Ok(())
    }

    /// Step ⑥: issue one step per engine/group, lockstep.  Allocation-free
    /// once warm: plans and batches live in recycled arenas.
    fn execute_step(&mut self, recorder: &mut Recorder) -> Result<bool> {
        self.settle_groups(recorder)?;

        let mut sc = std::mem::take(&mut self.scratch);
        let result = self.execute_step_inner(&mut sc, recorder);
        if result.is_err() {
            // Re-synchronize the persistent per-engine reply channels: any
            // reply still outstanding from this aborted step would otherwise
            // be mis-attributed to the next command on this cluster.
            // Failed engines are never drained (fail-stop); under the
            // watchdog the drain itself is deadline-bounded.
            let mut pending = sc.pending_mask;
            while pending != 0 {
                let e = pending.trailing_zeros() as usize;
                pending &= pending - 1;
                if self.kernel.index.is_failed(e) {
                    continue;
                }
                if self.watchdog.enabled {
                    if let Err(kind) = self.recv_reply_watched(e) {
                        self.note_engine_fault(e, kind);
                    }
                } else {
                    let _ = self.engines[e].recv();
                }
            }
        }
        sc.pending_mask = 0;
        self.scratch = sc;
        result
    }

    fn execute_step_inner(
        &mut self,
        sc: &mut StepScratch,
        recorder: &mut Recorder,
    ) -> Result<bool> {
        // ---- plan + issue -------------------------------------------------
        sc.issued.clear();
        sc.pending_mask = 0;
        sc.covered.clear();
        sc.covered.resize(self.engines.len(), false);
        sc.starts.clear();
        sc.starts.extend(self.groups.keys().copied());

        // TP groups first.
        for &start in sc.starts.iter() {
            let (p, has_active) = {
                let g = &self.groups[&start];
                (g.p, !g.tp_active.is_empty())
            };
            if !has_active {
                continue;
            }
            for e in self.members(start, p) {
                sc.covered[e] = true;
            }
            // A group that lost a member issues nothing this step — its
            // requests are recovered by the fault pass right after.  The
            // members stay covered so survivors (still in TP mode) are
            // not handed DP work.  No-op while the failed mask is zero.
            if self
                .members(start, p)
                .any(|e| self.kernel.index.is_failed(e))
            {
                continue;
            }
            // An async KV-migration transfer is still in flight on the
            // members (ISSUE 9): their single free command slot is the
            // scatter's, so the group sits this step out — that wait *is*
            // the overlap window the other engines fill.  Always zero with
            // `--overlap` off.
            if self.async_busy & self.member_mask(start, p) != 0 {
                continue;
            }
            // Prefill-first within the group (chunked prefill).
            let pre = {
                let g = &self.groups[&start];
                g.tp_active.iter().copied().find(|&x| {
                    self.active.get(x).map(|a| a.phase == Phase::Prefill).unwrap_or(false)
                })
            };
            if let Some(hh) = pre {
                for e in self.members(start, p) {
                    let chunk = self.make_prefill_chunk(hh, e)?;
                    self.engines[e].send(EngineCmd::TpPrefill { p, chunk });
                    sc.pending_mask |= 1u64 << e;
                }
                sc.issued.push(Issued { home: start, p, is_prefill: true, co: false });
            } else {
                sc.decode_hs.clear();
                {
                    let g = &self.groups[&start];
                    for &x in g.tp_active.iter() {
                        if self.active.get(x).map(|a| a.phase == Phase::Decode).unwrap_or(false)
                        {
                            if sc.decode_hs.len() == self.b_dec {
                                break;
                            }
                            sc.decode_hs.push(x);
                        }
                    }
                }
                if !sc.decode_hs.is_empty() {
                    for e in self.members(start, p) {
                        let batch = self.make_decode_batch(e, &sc.decode_hs)?;
                        self.engines[e].send(EngineCmd::TpDecode { p, batch });
                        sc.pending_mask |= 1u64 << e;
                    }
                    sc.issued.push(Issued { home: start, p, is_prefill: false, co: false });
                }
            }
        }

        // DP engines.
        for e in 0..self.engines.len() {
            if sc.covered[e]
                || self.kernel.index.is_failed(e)
                || (self.async_busy >> e) & 1 != 0
            {
                continue;
            }
            let mut pre: Option<SlabHandle> = None;
            sc.decode_hs.clear();
            for &x in &self.engine_active[e] {
                let Some(a) = self.active.get(x) else { continue };
                if a.paused {
                    continue;
                }
                if a.phase == Phase::Prefill {
                    if pre.is_none() {
                        pre = Some(x);
                    }
                } else if sc.decode_hs.len() < self.b_dec {
                    sc.decode_hs.push(x);
                }
            }
            if let Some(hh) = pre {
                if self.overlap_cfg.co_issue_on() && !sc.decode_hs.is_empty() {
                    // Overlap 3 (ISSUE 9): one command envelope carrying the
                    // prefill chunk *and* the decode batch, so admission of
                    // a new request no longer stalls the engine's resident
                    // decodes for a full step.  Chunk first — it stashes the
                    // prefill handle before the batch re-owns `issued_hs`.
                    let chunk = self.make_prefill_chunk(hh, e)?;
                    self.engine_scratch[e].co_prefill_h = Some(hh);
                    let batch = self.make_decode_batch(e, &sc.decode_hs)?;
                    self.engines[e].send(EngineCmd::CoIssue { chunk, batch });
                    sc.pending_mask |= 1u64 << e;
                    sc.issued.push(Issued { home: e, p: 1, is_prefill: false, co: true });
                    if self.overlap_cfg.double_buffer_on() {
                        let t_now = self.now();
                        let slot = self.engine_scratch[e].front as u32;
                        let batch_n = sc.decode_hs.len() as u32;
                        self.journal.record(
                            t_now,
                            crate::obs::Event::SlotIssue { engine: e as u32, slot, batch: batch_n },
                        );
                    }
                } else {
                    let chunk = self.make_prefill_chunk(hh, e)?;
                    self.engines[e].send(EngineCmd::DpPrefill { chunk });
                    sc.pending_mask |= 1u64 << e;
                    sc.issued.push(Issued { home: e, p: 1, is_prefill: true, co: false });
                }
            } else if !sc.decode_hs.is_empty() {
                let batch = self.make_decode_batch(e, &sc.decode_hs)?;
                self.engines[e].send(EngineCmd::DpDecode { batch });
                sc.pending_mask |= 1u64 << e;
                sc.issued.push(Issued { home: e, p: 1, is_prefill: false, co: false });
                if self.overlap_cfg.double_buffer_on() {
                    let t_now = self.now();
                    let slot = self.engine_scratch[e].front as u32;
                    let batch_n = sc.decode_hs.len() as u32;
                    self.journal.record(
                        t_now,
                        crate::obs::Event::SlotIssue { engine: e as u32, slot, batch: batch_n },
                    );
                }
            }
        }

        if sc.issued.is_empty() {
            return Ok(false);
        }

        // Overlap 1 (ISSUE 9): while batch N runs on the engines, pre-
        // materialize batch N+1's decode slots into each DP engine's back
        // arena.  Pure cached materialization — admission was snapshotted
        // at issue time, and the bounded-staleness stamp forces a full
        // rebuild at the next issue if the live state diverged at all.
        if self.overlap_cfg.double_buffer_on() {
            self.prebuild_next_batches(sc);
        }

        // ---- collect + publish (issue order; TP members meet in the
        // collectives, so all their commands are already in flight) --------
        if self.watchdog.enabled {
            // Deadline-bounded collection with per-group degradation
            // (ISSUE 6).  The blocking path below stays verbatim so runs
            // with the watchdog off are byte-identical to the
            // pre-watchdog coordinator.
            self.collect_watched(sc, recorder)?;
            return Ok(true);
        }
        for ii in 0..sc.issued.len() {
            let Issued { home, p, is_prefill, co } = sc.issued[ii];
            let mut first: Option<EngineReply> = None;
            for e in self.members(home, p) {
                let r = self.engines[e].recv();
                sc.pending_mask &= !(1u64 << e);
                let r = r?;
                if let EngineReply::Err(msg) = &r {
                    bail!("engine {e}: {msg}");
                }
                if first.is_none() {
                    first = Some(r);
                }
            }
            let now = self.now();
            if co {
                self.publish_co_step(sc, home, first.unwrap(), now, recorder)?;
                continue;
            }
            match (first.unwrap(), is_prefill) {
                (EngineReply::LastLogits(logits), true) => {
                    let hh = self.engine_scratch[home].issued_hs[0];
                    self.advance_prefill(hh, &logits, now, recorder)?;
                }
                (EngineReply::Logits(rows), false) => {
                    sc.publish_hs.clear();
                    sc.publish_hs.extend_from_slice(&self.engine_scratch[home].issued_hs);
                    for (hh, row) in sc.publish_hs.iter().zip(rows) {
                        self.advance_decode(*hh, &row, now, recorder)?;
                    }
                }
                (r, _) => bail!("unexpected engine reply {r:?}"),
            }
        }
        Ok(true)
    }

    /// Watched collect (ISSUE 6): the blocking collect with every reply
    /// bounded by the watchdog deadline.  A faulting or erroring member
    /// *degrades its own group's step* instead of aborting the trace:
    /// nothing is published for that group — the issued requests' state
    /// is untouched, so the work is simply reissued once the fault pass
    /// has recovered or dissolved whatever broke.  Survivors of a dead
    /// peer's collective surface here as `EngineReply::Err` (their
    /// communicator rendezvous times out) and are absorbed the same way.
    fn collect_watched(&mut self, sc: &mut StepScratch, recorder: &mut Recorder) -> Result<()> {
        for ii in 0..sc.issued.len() {
            let Issued { home, p, is_prefill, co } = sc.issued[ii];
            let mut first: Option<EngineReply> = None;
            let mut degraded = false;
            for e in self.members(home, p) {
                match self.recv_reply_watched(e) {
                    Ok(EngineReply::Err(msg)) => {
                        self.step_err_streak[e] += 1;
                        if self.step_err_streak[e] >= self.watchdog.max_step_err_streak {
                            crate::info!(
                                "engine {e} exceeded the consecutive step-error budget: {msg}"
                            );
                            self.note_engine_fault(e, FaultKind::Timeout);
                        } else {
                            crate::info!("engine {e} step error (degraded): {msg}");
                            self.fault_stats.step_errors += 1;
                            let t_now = self.now();
                            let streak = self.step_err_streak[e];
                            self.journal.record(
                                t_now,
                                crate::obs::Event::StepError { engine: e as u32, streak },
                            );
                        }
                        degraded = true;
                    }
                    Ok(r) => {
                        self.step_err_streak[e] = 0;
                        if first.is_none() {
                            first = Some(r);
                        }
                    }
                    Err(kind) => {
                        self.note_engine_fault(e, kind);
                        degraded = true;
                    }
                }
                sc.pending_mask &= !(1u64 << e);
            }
            if degraded {
                continue;
            }
            let now = self.now();
            if co {
                self.publish_co_step(sc, home, first.unwrap(), now, recorder)?;
                continue;
            }
            match (first.unwrap(), is_prefill) {
                (EngineReply::LastLogits(logits), true) => {
                    let hh = self.engine_scratch[home].issued_hs[0];
                    self.advance_prefill(hh, &logits, now, recorder)?;
                }
                (EngineReply::Logits(rows), false) => {
                    sc.publish_hs.clear();
                    sc.publish_hs.extend_from_slice(&self.engine_scratch[home].issued_hs);
                    for (hh, row) in sc.publish_hs.iter().zip(rows) {
                        self.advance_decode(*hh, &row, now, recorder)?;
                    }
                }
                (r, _) => bail!("unexpected engine reply {r:?}"),
            }
        }
        Ok(())
    }

    /// Build the next prefill chunk into engine `e`'s recycled arena
    /// (Algorithm 1 step 4: allocate + slot mapping).  No allocation once
    /// warm: tokens are indexed straight out of the request, the block-table
    /// row is copied from the adaptor's cached row via the KV handle
    /// resolved at bind time — every lookup here is O(1).
    fn make_prefill_chunk(&mut self, h: SlabHandle, e: usize) -> Result<Arc<PrefillChunk>> {
        let (start, end, plen, rid, kh) = {
            let a = self
                .active
                .get(h)
                .ok_or_else(|| anyhow::anyhow!("prefill for finished request"))?;
            let full_len = a.sr.prompt.len() + a.emitted.len().saturating_sub(1);
            let start = a.pos;
            let kh = a
                .kvh
                .iter()
                .find(|&&(ke, _)| ke == e)
                .map(|&(_, kh)| kh)
                .ok_or_else(|| {
                    anyhow::anyhow!("request {} has no kv registration on engine {e}", a.sr.id)
                })?;
            (
                start,
                (start + self.c_prefill).min(full_len),
                a.sr.prompt.len(),
                a.sr.id,
                kh,
            )
        };
        anyhow::ensure!(end > start, "empty prefill chunk for {rid}");
        self.adaptors[e].ensure_capacity_h(kh, end)?;
        {
            let a = self.active.get(h).expect("live");
            let scratch = &mut self.engine_scratch[e];
            scratch.issued_hs.clear();
            scratch.issued_hs.push(h);
            let ch = Arc::make_mut(&mut scratch.prefill_chunk);
            ch.rid = rid;
            ch.start = start;
            ch.tokens.clear();
            for i in start..end {
                ch.tokens.push(if i < plen {
                    a.sr.prompt[i]
                } else {
                    a.emitted[i - plen]
                });
            }
        }
        {
            // Slot mapping needs the adaptor immutably; fill in a second
            // pass to keep the borrows disjoint.
            let ch = Arc::make_mut(&mut self.engine_scratch[e].prefill_chunk);
            ch.slot_ids.clear();
            for i in start..end {
                ch.slot_ids.push(self.adaptors[e].slot_h(kh, i)?);
            }
            ch.table_row.clear();
            ch.table_row.extend_from_slice(self.adaptors[e].table_row_ref_h(kh)?);
        }
        Ok(self.engine_scratch[e].prefill_chunk.clone())
    }

    /// Build a decode batch for engine `e` into its recycled arena.
    fn make_decode_batch(&mut self, e: usize, hs: &[SlabHandle]) -> Result<Arc<Vec<DecodeSlot>>> {
        // A prebuilt batch N+1 is waiting in the back arena (ISSUE 9):
        // swap it in if — and only if — the live state still matches the
        // stamp it was built under; any divergence discards it and falls
        // through to the full rebuild below.
        if self.overlap_cfg.double_buffer_on() && !self.engine_scratch[e].next_stamp.is_empty() {
            if let Some(batch) = self.take_prebuilt(e, hs)? {
                return Ok(batch);
            }
        }
        // Grow/shrink the slot list, recycling retired slots (and their row
        // buffers) through the spare pool; remember the issue order for the
        // publish pass.
        {
            let scratch = &mut self.engine_scratch[e];
            let slots = Arc::make_mut(&mut scratch.decode_batch);
            while slots.len() > hs.len() {
                scratch.spare_slots.push(slots.pop().unwrap());
            }
            while slots.len() < hs.len() {
                slots.push(scratch.spare_slots.pop().unwrap_or_default());
            }
            scratch.issued_hs.clear();
            scratch.issued_hs.extend_from_slice(hs);
        }
        for (i, &hh) in hs.iter().enumerate() {
            let (rid, token, pos, kh) = {
                let a = self
                    .active
                    .get(hh)
                    .ok_or_else(|| anyhow::anyhow!("decode for finished request"))?;
                let token = *a
                    .emitted
                    .last()
                    .ok_or_else(|| anyhow::anyhow!("decode with no emitted token"))?;
                let kh = a
                    .kvh
                    .iter()
                    .find(|&&(ke, _)| ke == e)
                    .map(|&(_, kh)| kh)
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "request {} has no kv registration on engine {e}",
                            a.sr.id
                        )
                    })?;
                (a.sr.id, token, a.pos, kh)
            };
            self.adaptors[e].ensure_capacity_h(kh, pos + 1)?;
            self.adaptors[e].set_seq_len_h(kh, pos + 1)?;
            let slot_id = self.adaptors[e].slot_h(kh, pos)?;
            let row = self.adaptors[e].table_row_ref_h(kh)?;
            let slots = Arc::make_mut(&mut self.engine_scratch[e].decode_batch);
            let s = &mut slots[i];
            s.rid = rid;
            s.token = token;
            s.pos = pos;
            s.slot_id = slot_id;
            s.table_row.clear();
            s.table_row.extend_from_slice(row);
        }
        Ok(self.engine_scratch[e].decode_batch.clone())
    }

    /// Publish one `CoStep` reply (ISSUE 9): the stashed prefill handle
    /// advances first (the backend ran the chunk first), then the decode
    /// batch in `issued_hs` order — the same per-request transitions the
    /// two separate commands would have published.
    fn publish_co_step(
        &mut self,
        sc: &mut StepScratch,
        home: usize,
        reply: EngineReply,
        now: f64,
        recorder: &mut Recorder,
    ) -> Result<()> {
        let EngineReply::CoStep { last, rows } = reply else {
            bail!("unexpected engine reply {reply:?}");
        };
        let hh = self.engine_scratch[home]
            .co_prefill_h
            .take()
            .ok_or_else(|| anyhow::anyhow!("co-step reply without a stashed prefill handle"))?;
        self.advance_prefill(hh, &last, now, recorder)?;
        sc.publish_hs.clear();
        sc.publish_hs.extend_from_slice(&self.engine_scratch[home].issued_hs);
        for (dh, row) in sc.publish_hs.iter().zip(rows) {
            self.advance_decode(*dh, &row, now, recorder)?;
        }
        Ok(())
    }

    /// Try to issue the prebuilt batch N+1 from engine `e`'s back arena.
    /// The bounded-staleness rule (ISSUE 9): issueable iff the live batch
    /// is exactly the stamped `(handle, position)` sequence — a finish,
    /// recovery, pause, admission, or migration in between changes either
    /// and forces the full rebuild.  The swap itself is the only state
    /// change; the per-slot patch then fills in the one thing prebuild
    /// could not know (the token batch N emitted) and runs the externally-
    /// visible `set_seq_len_h` the off path would have run at build time.
    fn take_prebuilt(
        &mut self,
        e: usize,
        hs: &[SlabHandle],
    ) -> Result<Option<Arc<Vec<DecodeSlot>>>> {
        let fresh = {
            let stamp = &self.engine_scratch[e].next_stamp;
            // The `mode_p == 1 && home == e` pin matters: the slots were
            // materialized under engine `e`'s DP layout, and a request that
            // migrated into a TP group could otherwise stamp-match at the
            // same `(handle, position)` with different slot ids and rows.
            stamp.len() == hs.len()
                && (0..hs.len()).all(|i| {
                    let (sh, sp) = stamp.get(i);
                    sh == hs[i]
                        && self
                            .active
                            .get(hs[i])
                            .map(|a| a.pos == sp && a.mode_p == 1 && a.home == e)
                            .unwrap_or(false)
                })
        };
        let t_now = self.now();
        let retired_slot;
        {
            let scratch = &mut self.engine_scratch[e];
            scratch.next_stamp.clear();
            retired_slot = scratch.front ^ 1;
            if fresh {
                // Slot-swap barrier: the engine dropped its clone of the
                // front arena when it replied to batch N, so both arenas
                // are uniquely owned here and the swap is just a pointer
                // exchange.
                std::mem::swap(&mut scratch.decode_batch, &mut scratch.next_batch);
                scratch.front ^= 1;
                scratch.issued_hs.clear();
                scratch.issued_hs.extend_from_slice(hs);
            }
        }
        self.journal.record(
            t_now,
            crate::obs::Event::SlotRetire {
                engine: e as u32,
                slot: retired_slot as u32,
                reused: fresh,
            },
        );
        if !fresh {
            return Ok(None);
        }
        for (i, &hh) in hs.iter().enumerate() {
            let (token, pos, kh) = {
                let a = self.active.get(hh).expect("stamp-checked live");
                let token = *a
                    .emitted
                    .last()
                    .ok_or_else(|| anyhow::anyhow!("decode with no emitted token"))?;
                let kh = a
                    .kvh
                    .iter()
                    .find(|&&(ke, _)| ke == e)
                    .map(|&(_, kh)| kh)
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "request {} has no kv registration on engine {e}",
                            a.sr.id
                        )
                    })?;
                (token, a.pos, kh)
            };
            // Capacity was ensured at prebuild time; only the logical
            // length advance is deferred to issue so migration planning
            // never sees a speculative sequence length.
            self.adaptors[e].set_seq_len_h(kh, pos + 1)?;
            let slots = Arc::make_mut(&mut self.engine_scratch[e].decode_batch);
            let s = &mut slots[i];
            debug_assert_eq!(s.pos, pos, "prebuilt slot position diverged from stamp");
            s.token = token;
        }
        Ok(Some(self.engine_scratch[e].decode_batch.clone()))
    }

    /// Pre-materialize batch N+1 for every DP engine that just got a decode
    /// (or co-issue) envelope, while batch N executes (ISSUE 9).  Predicts
    /// the survivor set of the in-flight batch; the prediction is captured
    /// in the bounded-staleness stamp, so a wrong guess costs one discarded
    /// prebuild, never a wrong batch.  Errors discard the prebuild — they
    /// can only be resource races the issue-time rebuild resolves.
    fn prebuild_next_batches(&mut self, sc: &StepScratch) {
        for ii in 0..sc.issued.len() {
            let Issued { home, p, is_prefill, .. } = sc.issued[ii];
            if p != 1 || is_prefill {
                continue;
            }
            if self.prebuild_engine(home).is_err() {
                self.engine_scratch[home].next_stamp.clear();
            }
        }
    }

    fn prebuild_engine(&mut self, e: usize) -> Result<()> {
        // Pass 1: predicted next-step composition — the in-flight batch's
        // requests that will still be decoding after it publishes, at their
        // advanced positions.
        self.engine_scratch[e].next_stamp.clear();
        let n = self.engine_scratch[e].issued_hs.len();
        for i in 0..n {
            let hh = self.engine_scratch[e].issued_hs[i];
            let Some(a) = self.active.get(hh) else { continue };
            // Survivor filter: after this step the request has emitted one
            // more token; it continues only if that leaves headroom.  This
            // also keeps the speculative `ensure_capacity_h` below inside
            // the worst-case block commitment admission already charged.
            if a.emitted.len() + 1 < a.sr.max_new {
                self.engine_scratch[e].next_stamp.push(hh, a.pos + 1);
            }
        }
        let m = self.engine_scratch[e].next_stamp.len();
        if m == 0 {
            return Ok(());
        }
        // Pass 2: size the back arena through the spare pool, then fill
        // every slot except the fed token (unknown until batch N's reply).
        {
            let scratch = &mut self.engine_scratch[e];
            let slots = Arc::make_mut(&mut scratch.next_batch);
            while slots.len() > m {
                scratch.spare_slots.push(slots.pop().unwrap());
            }
            while slots.len() < m {
                slots.push(scratch.spare_slots.pop().unwrap_or_default());
            }
        }
        for i in 0..m {
            let (hh, pos_next) = self.engine_scratch[e].next_stamp.get(i);
            let (rid, kh) = {
                let a = self.active.get(hh).expect("stamped live");
                let kh = a
                    .kvh
                    .iter()
                    .find(|&&(ke, _)| ke == e)
                    .map(|&(_, kh)| kh)
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "request {} has no kv registration on engine {e}",
                            a.sr.id
                        )
                    })?;
                (a.sr.id, kh)
            };
            self.adaptors[e].ensure_capacity_h(kh, pos_next + 1)?;
            let slot_id = self.adaptors[e].slot_h(kh, pos_next)?;
            let row = self.adaptors[e].table_row_ref_h(kh)?;
            let slots = Arc::make_mut(&mut self.engine_scratch[e].next_batch);
            let s = &mut slots[i];
            s.rid = rid;
            s.token = 0;
            s.pos = pos_next;
            s.slot_id = slot_id;
            s.table_row.clear();
            s.table_row.extend_from_slice(row);
        }
        Ok(())
    }

    fn prefill_total_len(&self, h: SlabHandle) -> usize {
        let a = self.active.get(h).expect("live");
        a.sr.prompt.len() + a.emitted.len().saturating_sub(1)
    }

    fn advance_prefill(
        &mut self,
        h: SlabHandle,
        logits: &[f32],
        now: f64,
        recorder: &mut Recorder,
    ) -> Result<()> {
        let total = self.prefill_total_len(h);
        let c_prefill = self.c_prefill;
        let a = self.active.get_mut(h).expect("live");
        let chunk_len = (total - a.pos).min(c_prefill);
        a.pos += chunk_len;
        if a.pos < total {
            return Ok(()); // more chunks to go
        }
        // Prefill complete.
        a.phase = Phase::Decode;
        if a.emitted.is_empty() {
            let tok = argmax(logits);
            a.emitted.push(tok);
            let rec = a.rec;
            recorder.on_token_at(rec, now);
            self.maybe_finish(h, now, recorder)?;
        }
        // else: soft-preempt recompute — logits discarded; the already-
        // emitted tail token is the last element of `emitted`, which the
        // decode path feeds automatically.
        Ok(())
    }

    fn advance_decode(
        &mut self,
        h: SlabHandle,
        logits: &[f32],
        now: f64,
        recorder: &mut Recorder,
    ) -> Result<()> {
        let a = self.active.get_mut(h).expect("live");
        a.pos += 1; // the fed token's KV is now cached
        let tok = argmax(logits);
        a.emitted.push(tok);
        let rec = a.rec;
        recorder.on_token_at(rec, now);
        self.maybe_finish(h, now, recorder)
    }

    /// Terminal handling: publish the output, release every KV registration
    /// through the handles captured at bind time, and remove the slab entry
    /// — invalidating every outstanding copy of the handle (engine lists
    /// are cleaned here; a stale copy parked in `tp_pending` is skipped by
    /// the generation check at promotion).
    fn maybe_finish(&mut self, h: SlabHandle, now: f64, recorder: &mut Recorder) -> Result<()> {
        let (done, mode_p, home, rec) = {
            let a = self.active.get(h).expect("live");
            let done = a.emitted.len() >= a.sr.max_new || a.emitted.last() == Some(&EOS);
            (done, a.mode_p, a.home, a.rec)
        };
        if !done {
            return Ok(());
        }
        recorder.on_finish_at(rec, now);
        self.uncommit_all(h);
        // Prefix-cache donation (ISSUE 10, `--prefix-cache` only): before
        // the home DP registration is released, fork the prompt's whole-
        // block chain into the engine's radix tree copy-on-write so later
        // same-prefix admissions adopt it by reference.  `prefix_donate` is
        // a no-op (Ok(0)) for TP-layout or paused registrations — only
        // DP-layout bytes are admission-compatible.
        if self.prefix_cache && mode_p <= 1 {
            let a = self.active.get(h).expect("live");
            if let Some(&(e, kh)) = a.kvh.iter().find(|&&(e, _)| e == home) {
                let inserted = self.adaptors[e].prefix_donate(kh, &a.sr.prompt)?;
                if inserted > 0 {
                    let rid = a.sr.id;
                    self.journal.record(
                        now,
                        crate::obs::Event::PrefixFork { rid, blocks: inserted as u32 },
                    );
                }
            }
        }
        let kvh = std::mem::take(&mut self.active.get_mut(h).expect("live").kvh);
        for &(e, kh) in kvh.iter() {
            self.adaptors[e].release_h(kh)?;
        }
        if mode_p <= 1 {
            self.engine_active[home].retain(|&x| x != h);
            self.refresh_engine(home);
        } else if let Some(g) = self.groups.get_mut(&home) {
            g.tp_active.retain(|&x| x != h);
        }
        let a = self.active.remove(h).expect("live");
        self.by_id.remove(&a.sr.id);
        self.outputs.push((a.sr.id, a.emitted));
        Ok(())
    }

    pub fn shutdown(&mut self) {
        // Best-effort completion of any transfer still in flight (ISSUE 9)
        // so `stop` never races a scatter mid-collective.
        let _ = self.drain_async_migrations();
        for e in &mut self.engines {
            e.stop();
        }
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }
}
