//! The FLYING SERVING coordinator (paper §3, §5): a middleware layer between
//! the global task pool and the engine workers that binds subsets of DP
//! engines into TP groups and releases them — the single switching
//! primitive — under a workload-aware policy and a switching strategy.
//!
//! The scheduling loop is Algorithm 1:
//!   ① ProcessInputSocket  — drain arrivals into the task pool
//!   ② SyncWorkload        — a globally-agreed waiting queue (priority,
//!                            arrival) — single-coordinator equivalent of
//!                            the paper's heartbeat all-reduce
//!   ③ Mode determination  — `Policy::decide` per request
//!   ④ KV parameterization — `B_req = B_base · N_eng` via the adaptor's
//!                            layout registration + block allocation
//!   ⑤ Mode signaling      — `SetMode` collective RPC to group members at
//!                            the iteration safe point
//!   ⑥ execute_model       — step commands to engines/groups; publish
//!
//! Engines run lockstep per scheduling iteration (the coordinator waits for
//! every issued step before the next iteration); TP members execute
//! concurrently on their threads and meet in the Communicator Pool's
//! collectives.
//!
//! # Hot-path discipline
//!
//! The steady-state loop performs **zero heap allocations on the
//! coordinator thread once warm** (asserted by the counting allocator in
//! `benches/sched_hotpath.rs`):
//!
//!  * step inputs live in per-engine `Arc`'d arenas — by the lockstep
//!    protocol the engine has dropped its clone by reply time, so
//!    `Arc::make_mut` recycles the same allocation every step;
//!  * block-table rows are copied from the KV adaptor's incrementally
//!    maintained cache (`table_row_ref`), never rebuilt;
//!  * plan/collection bookkeeping uses `StepScratch` buffers swapped in
//!    and out of the cluster;
//!  * engine lookups (`idle`, unit-mode, draining) are O(1) bitmask reads
//!    maintained by `refresh_engine`/`refresh_draining` instead of linear
//!    scans per waiting request.

pub mod policy;
pub mod strategy;

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::comm::CommunicatorPool;
use crate::engine::{DecodeSlot, EngineCmd, EngineHandle, EngineReply, PrefillChunk};
use crate::kv::KvCacheAdaptor;
use crate::metrics::Recorder;
use crate::model::{ModelCfg, StaticShapes};
use crate::workload::Priority;
use policy::{ModeDecision, Policy, Snapshot};
use strategy::Strategy;

pub const EOS: i32 = 257;

/// A request as submitted to the cluster (the real serving path).
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub priority: Priority,
    pub tp_demand: Option<usize>,
    /// Arrival offset in seconds from cluster-clock zero (trace replay);
    /// requests become visible to the scheduler at this time.
    pub arrival: f64,
}

#[derive(Clone, Debug, PartialEq)]
enum Phase {
    Prefill,
    Decode,
    Done,
}

#[derive(Clone, Debug)]
struct Active {
    sr: ServeRequest,
    mode_p: usize,
    /// Engine id (DP) or group start (TP).
    home: usize,
    phase: Phase,
    /// Tokens whose KV is cached (prompt progress + fed output tokens).
    pos: usize,
    emitted: Vec<i32>,
    paused: bool,
    /// Soft-preempt: running speculatively in DP while its TP group drains.
    speculative: bool,
    /// Forced next inputs after a soft-preempt recompute (already emitted).
    forced: Vec<i32>,
    /// Worst-case block commitment per engine (admission control): the
    /// blocks this request may grow into, reserved at bind time so the pool
    /// can never be overcommitted mid-decode.
    committed: Vec<(usize, usize)>,
}

#[derive(Clone, Debug, Default)]
struct Group {
    p: usize,
    tp_active: Vec<u64>,
    /// TP requests waiting for this group to finish draining.
    tp_pending: Vec<u64>,
}

/// Mode-switch event log (feeds the Table-2 switching-latency measurement).
#[derive(Clone, Debug)]
pub struct SwitchEvent {
    pub t: f64,
    pub group_start: usize,
    pub p_from: usize,
    pub p_to: usize,
    pub latency_s: f64,
}

pub struct ClusterOutcome {
    pub recorder: Recorder,
    pub outputs: BTreeMap<u64, Vec<i32>>,
    pub rejected: Vec<u64>,
    pub switches: Vec<SwitchEvent>,
    /// Scheduling iterations that issued at least one engine step.
    pub n_steps: usize,
}

/// One work-issue record: enough to collect replies and publish results
/// without any per-step allocation (rids are read back from the engine
/// scratch arenas).
#[derive(Clone, Copy, Debug)]
struct Issued {
    home: usize,
    p: usize,
    is_prefill: bool,
}

/// Per-engine step-input arenas.  The `Arc`s are shared with the engine
/// worker for the duration of one step; `Arc::make_mut` on the next step
/// reuses the allocation (the worker has dropped its clone by reply time).
struct EngineScratch {
    decode_batch: Arc<Vec<DecodeSlot>>,
    prefill_chunk: Arc<PrefillChunk>,
    /// Retired `DecodeSlot`s (with their row buffers) for reuse.
    spare_slots: Vec<DecodeSlot>,
}

impl Default for EngineScratch {
    fn default() -> Self {
        EngineScratch {
            decode_batch: Arc::new(Vec::new()),
            prefill_chunk: Arc::new(PrefillChunk::default()),
            spare_slots: Vec::new(),
        }
    }
}

/// Reusable coordinator-side buffers (swapped out with `mem::take` for the
/// duration of a call, then restored, so the borrow checker sees disjoint
/// state).
#[derive(Default)]
struct StepScratch {
    covered: Vec<bool>,
    issued: Vec<Issued>,
    decode_rids: Vec<u64>,
    publish_rids: Vec<u64>,
    starts: Vec<usize>,
    busy: Vec<u64>,
    ids: Vec<u64>,
    waiting_buf: Vec<u64>,
    /// Engines with a command in flight whose reply has not been collected
    /// yet.  Used to re-synchronize the persistent per-engine reply
    /// channels if a step aborts mid-collection.
    pending_mask: u64,
}

/// The real serving cluster: N engine threads + adaptors + communicator
/// pool + the dynamic scheduler.
pub struct Cluster {
    pub cfg: ModelCfg,
    engines: Vec<EngineHandle>,
    adaptors: Vec<KvCacheAdaptor>,
    pub comm: Arc<CommunicatorPool>,
    max_tp: usize,
    b_dec: usize,
    c_prefill: usize,

    // scheduler state
    waiting: Vec<u64>,
    active: BTreeMap<u64, Active>,
    engine_active: Vec<Vec<u64>>, // DP requests per engine
    engine_mode: Vec<usize>,
    /// Blocks committed per engine by admission control.
    engine_committed: Vec<usize>,
    groups: BTreeMap<usize, Group>,
    outputs: BTreeMap<u64, Vec<i32>>,
    rejected: Vec<u64>,
    switches: Vec<SwitchEvent>,
    t0: Instant,
    n_steps: usize,

    // O(1) engine-state indexes (≤ 64 engines):
    /// Engines currently in unit (DP) mode.
    unit_mask: u64,
    /// Unit-mode engines with no bound requests (the policy's idle count).
    idle_mask: u64,
    /// Engines inside a group that is draining toward a pending TP bind.
    draining_mask: u64,

    // hot-path arenas
    engine_scratch: Vec<EngineScratch>,
    scratch: StepScratch,
}

impl Cluster {
    /// Boot `n_engines` engine workers for `model` over the real PJRT
    /// execution core (weights loaded once, artifacts compiled eagerly,
    /// communicator pool pre-initialized).
    #[cfg(feature = "pjrt")]
    pub fn start(
        manifest: &Arc<crate::runtime::Manifest>,
        model: &str,
        n_engines: usize,
    ) -> Result<Cluster> {
        use anyhow::Context;
        let mm = manifest.model(model)?;
        let cfg = mm.cfg.clone();
        let ws = Arc::new(mm.load_weights()?);
        let mut degrees: Vec<usize> = manifest
            .tp_degrees
            .iter()
            .copied()
            .filter(|&p| cfg.supports_tp(p) && p <= n_engines)
            .collect();
        if !degrees.contains(&1) {
            degrees.push(1);
        }
        let comm = Arc::new(CommunicatorPool::new(
            n_engines,
            &degrees,
            Duration::from_secs(30),
        ));
        let mut engines = Vec::new();
        for id in 0..n_engines {
            engines.push(
                EngineHandle::spawn(id, manifest.clone(), model.to_string(), ws.clone(), comm.clone())
                    .with_context(|| format!("starting engine {id}"))?,
            );
        }
        Self::assemble(cfg, engines, comm, degrees, manifest.shapes)
    }

    /// Boot `n_engines` workers over the deterministic stub backend — the
    /// full scheduler/adaptor/collective path with no PJRT dependency.
    /// Used by CI integration tests and the scheduler benches.
    pub fn start_stub(cfg: ModelCfg, shapes: StaticShapes, n_engines: usize) -> Result<Cluster> {
        let mut degrees = Vec::new();
        let mut p = 1usize;
        while p <= n_engines {
            if cfg.supports_tp(p) {
                degrees.push(p);
            }
            p *= 2;
        }
        if !degrees.contains(&1) {
            degrees.push(1);
        }
        let comm = Arc::new(CommunicatorPool::new(
            n_engines,
            &degrees,
            Duration::from_secs(30),
        ));
        let mut engines = Vec::new();
        for id in 0..n_engines {
            engines.push(EngineHandle::spawn_stub(id, cfg.clone(), shapes, comm.clone())?);
        }
        Self::assemble(cfg, engines, comm, degrees, shapes)
    }

    fn assemble(
        cfg: ModelCfg,
        engines: Vec<EngineHandle>,
        comm: Arc<CommunicatorPool>,
        degrees: Vec<usize>,
        shapes: StaticShapes,
    ) -> Result<Cluster> {
        let n_engines = engines.len();
        if n_engines > 64 {
            bail!("engine-state bitmasks support at most 64 engines (got {n_engines})");
        }
        let max_tp = degrees.iter().copied().max().unwrap_or(1);
        let adaptors = (0..n_engines).map(|_| KvCacheAdaptor::new(cfg.clone())).collect();
        let mut c = Cluster {
            cfg,
            engines,
            adaptors,
            comm,
            max_tp,
            b_dec: shapes.b_dec,
            c_prefill: shapes.c_prefill,
            waiting: Vec::new(),
            active: BTreeMap::new(),
            engine_active: vec![Vec::new(); n_engines],
            engine_mode: vec![1; n_engines],
            engine_committed: vec![0; n_engines],
            groups: BTreeMap::new(),
            outputs: BTreeMap::new(),
            rejected: Vec::new(),
            switches: Vec::new(),
            t0: Instant::now(),
            n_steps: 0,
            unit_mask: 0,
            idle_mask: 0,
            draining_mask: 0,
            engine_scratch: (0..n_engines).map(|_| EngineScratch::default()).collect(),
            scratch: StepScratch::default(),
        };
        for e in 0..n_engines {
            c.refresh_engine(e);
        }
        Ok(c)
    }

    pub fn n_engines(&self) -> usize {
        self.engines.len()
    }

    pub fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    fn members(&self, start: usize, p: usize) -> std::ops::Range<usize> {
        start..start + p
    }

    /// Recompute the unit/idle index bits for engine `e`.  Must be called
    /// after any mutation of `engine_mode[e]` or `engine_active[e]`.
    fn refresh_engine(&mut self, e: usize) {
        let bit = 1u64 << e;
        if self.engine_mode[e] == 1 {
            self.unit_mask |= bit;
            if self.engine_active[e].is_empty() {
                self.idle_mask |= bit;
            } else {
                self.idle_mask &= !bit;
            }
        } else {
            self.unit_mask &= !bit;
            self.idle_mask &= !bit;
        }
    }

    /// Recompute the draining mask.  Must be called after any mutation of a
    /// group's `tp_pending`.
    fn refresh_draining(&mut self) {
        let mut mask = 0u64;
        for (&start, g) in &self.groups {
            if !g.tp_pending.is_empty() {
                for e in start..(start + g.p).min(self.engines.len()) {
                    mask |= 1u64 << e;
                }
            }
        }
        self.draining_mask = mask;
    }

    /// Live mode switch: SetMode RPC to every member + communicator fetch.
    /// Returns the measured latency (the Table-2 "live" number).
    fn switch_group(&mut self, start: usize, p_to: usize) -> Result<f64> {
        let p_from = self.engine_mode[start];
        let t_start = Instant::now();
        // Communicator activation: O(1) pool lookup (pre-initialized).
        if p_to > 1 {
            let _ = self.comm.group_of(start, p_to)?;
        }
        let width = p_to.max(p_from);
        for e in self.members(start, width) {
            if e < self.engines.len() {
                self.engines[e].call(EngineCmd::SetMode { p: p_to })?;
                self.engine_mode[e] = p_to;
                self.refresh_engine(e);
            }
        }
        let dt = t_start.elapsed().as_secs_f64();
        self.switches.push(SwitchEvent {
            t: self.now(),
            group_start: start,
            p_from,
            p_to,
            latency_s: dt,
        });
        Ok(dt)
    }

    // ------------------------------------------------------------------
    // Trace replay driver: submit all requests with arrival offsets, run
    // Algorithm 1 until everything finishes.
    // ------------------------------------------------------------------

    pub fn run_trace(
        &mut self,
        mut trace: Vec<ServeRequest>,
        policy: &mut dyn Policy,
        strategy: Strategy,
    ) -> Result<ClusterOutcome> {
        trace.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        let mut recorder = Recorder::new();
        self.t0 = Instant::now();
        self.n_steps = 0;
        let mut next_arrival = 0usize;
        let mut idle_iters = 0usize;

        loop {
            let now = self.now();

            // Dissolve/settle groups first so freshly-freed engines are
            // visible to this iteration's mode decisions.
            self.settle_groups(&mut recorder)?;

            // ① Input processing: admit due arrivals into the task pool.
            while next_arrival < trace.len() && trace[next_arrival].arrival <= now {
                let sr = trace[next_arrival].clone();
                recorder.on_arrival(sr.id, sr.arrival, sr.priority, sr.prompt.len());
                self.admit(sr);
                next_arrival += 1;
            }

            // ② Globally-agreed waiting order: priority first, then arrival.
            self.waiting.sort_by(|a, b| {
                let ra = &self.active[a].sr;
                let rb = &self.active[b].sr;
                rb.priority
                    .cmp(&ra.priority)
                    .then(ra.arrival.total_cmp(&rb.arrival))
            });

            // ③+④+⑤ Mode determination, KV parameterization, binding.
            self.assign_waiting(policy, strategy, &mut recorder)?;

            // ⑥ Execute one step on every engine/group with work.
            let stepped = self.execute_step(&mut recorder)?;
            if stepped {
                self.n_steps += 1;
            }

            // Exit/idle handling.
            let done = self.active.values().all(|a| a.phase == Phase::Done)
                && next_arrival >= trace.len()
                && self.waiting.is_empty();
            if done {
                break;
            }
            if !stepped {
                idle_iters += 1;
                // Nothing runnable: sleep until the next arrival.
                if next_arrival < trace.len() {
                    let dt = trace[next_arrival].arrival - self.now();
                    if dt > 0.0 {
                        std::thread::sleep(Duration::from_secs_f64(dt.min(0.05)));
                    }
                } else if idle_iters > 10_000 {
                    // Requests exist but nothing has run for many
                    // iterations: genuine scheduling bug, fail loudly
                    // instead of hanging.
                    bail!("scheduler stall: waiting={:?}", self.waiting);
                }
            } else {
                idle_iters = 0;
            }
        }

        Ok(ClusterOutcome {
            recorder,
            outputs: std::mem::take(&mut self.outputs),
            rejected: std::mem::take(&mut self.rejected),
            switches: std::mem::take(&mut self.switches),
            n_steps: self.n_steps,
        })
    }

    /// Submit a request straight into the task pool (schedulable from the
    /// next iteration).  Fine-grained alternative to [`Self::run_trace`]
    /// for streaming drivers and the scheduler benches.
    pub fn submit(&mut self, sr: ServeRequest, recorder: &mut Recorder) {
        recorder.on_arrival(sr.id, sr.arrival, sr.priority, sr.prompt.len());
        self.admit(sr);
    }

    /// Run one full scheduling iteration (settle → sync → assign →
    /// execute); returns whether any engine stepped.  [`Self::run_trace`]
    /// is this in a loop plus arrival replay.
    pub fn step_once(
        &mut self,
        policy: &mut dyn Policy,
        strategy: Strategy,
        recorder: &mut Recorder,
    ) -> Result<bool> {
        self.settle_groups(recorder)?;
        self.waiting.sort_by(|a, b| {
            let ra = &self.active[a].sr;
            let rb = &self.active[b].sr;
            rb.priority
                .cmp(&ra.priority)
                .then(ra.arrival.total_cmp(&rb.arrival))
        });
        self.assign_waiting(policy, strategy, recorder)?;
        let stepped = self.execute_step(recorder)?;
        if stepped {
            self.n_steps += 1;
        }
        Ok(stepped)
    }

    fn admit(&mut self, sr: ServeRequest) {
        let id = sr.id;
        let emitted = Vec::with_capacity(sr.max_new + 1);
        self.active.insert(
            id,
            Active {
                sr,
                mode_p: 0,
                home: 0,
                phase: Phase::Prefill,
                pos: 0,
                emitted,
                paused: false,
                speculative: false,
                forced: Vec::new(),
                committed: Vec::new(),
            },
        );
        self.waiting.push(id);
    }

    fn snapshot(&self) -> Snapshot {
        let committed: usize = self.engine_committed.iter().sum();
        let capacity = self.engines.len() * (self.cfg.n_blocks - 1);
        Snapshot {
            now: self.now(),
            queue_len: self.waiting.len(),
            idle_engines: self.idle_mask.count_ones() as usize,
            n_engines: self.engines.len(),
            dp_capacity_tokens: self.cfg.dp_token_capacity(),
            max_tp: self.max_tp,
            kv_frac: if capacity == 0 {
                0.0
            } else {
                committed as f64 / capacity as f64
            },
        }
    }

    /// Steps ③–⑤ for every waiting request.
    fn assign_waiting(
        &mut self,
        policy: &mut dyn Policy,
        strategy: Strategy,
        recorder: &mut Recorder,
    ) -> Result<()> {
        // Ping-pong the waiting list through a warm scratch buffer so the
        // requeue path never allocates.
        std::mem::swap(&mut self.waiting, &mut self.scratch.waiting_buf);
        let backlog_total = self.scratch.waiting_buf.len();
        for qi in 0..backlog_total {
            let rid = self.scratch.waiting_buf[qi];
            let mut snap = self.snapshot();
            // Include requests later in this same drain in the backlog so
            // the burst signal sees the true queue depth.
            snap.queue_len += backlog_total - qi - 1;
            let (plen, hint, pri, demand) = {
                let a = &self.active[&rid];
                (
                    a.sr.prompt.len(),
                    a.sr.max_new,
                    a.sr.priority,
                    a.sr.tp_demand,
                )
            };
            match policy.decide(plen, hint, pri, demand, &snap) {
                ModeDecision::Reject => {
                    self.active.get_mut(&rid).unwrap().phase = Phase::Done;
                    self.rejected.push(rid);
                    recorder.on_finish(rid, self.now());
                }
                ModeDecision::Dp => self.try_bind_dp(rid, recorder)?,
                ModeDecision::Tp(p) => {
                    let p = self.clamp_tp(p);
                    if p == 1 {
                        // Degenerate TP (single engine / unsupported width).
                        self.try_bind_dp(rid, recorder)?;
                    } else {
                        self.bind_tp(rid, p, strategy, recorder)?;
                    }
                }
            }
        }
        self.scratch.waiting_buf.clear();
        Ok(())
    }

    /// Worst-case block demand of `rid` under layout `p` (admission unit).
    fn block_need(&self, rid: u64, p: usize) -> usize {
        let a = &self.active[&rid];
        let total = a.sr.prompt.len() + a.sr.max_new;
        total.div_ceil(self.cfg.block_tokens(p))
    }

    fn commit(&mut self, rid: u64, e: usize, blocks: usize) {
        self.engine_committed[e] += blocks;
        self.active.get_mut(&rid).unwrap().committed.push((e, blocks));
    }

    fn uncommit_all(&mut self, rid: u64) {
        let committed = std::mem::take(&mut self.active.get_mut(&rid).unwrap().committed);
        for (e, blocks) in committed {
            self.engine_committed[e] -= blocks;
        }
    }

    /// Bind to the least-loaded unbound engine with KV headroom, or queue.
    /// Candidates come from the unit/draining bitmask indexes — O(set bits)
    /// instead of a predicate scan over every engine.
    fn try_bind_dp(&mut self, rid: u64, recorder: &mut Recorder) -> Result<()> {
        let need = self.block_need(rid, 1);
        let mut candidates = self.unit_mask & !self.draining_mask;
        let mut pick: Option<usize> = None;
        while candidates != 0 {
            let e = candidates.trailing_zeros() as usize;
            candidates &= candidates - 1;
            if self.engine_committed[e] + need > self.cfg.n_blocks - 1 {
                continue;
            }
            match pick {
                None => pick = Some(e),
                Some(p) if self.engine_active[p].len() > self.engine_active[e].len() => {
                    pick = Some(e)
                }
                _ => {}
            }
        }
        match pick {
            Some(e) => {
                self.commit(rid, e, need);
                self.bind_dp(rid, e, recorder)
            }
            None => {
                self.waiting.push(rid);
                Ok(())
            }
        }
    }

    fn clamp_tp(&self, p: usize) -> usize {
        let mut q = 1;
        while q * 2 <= p && q * 2 <= self.engines.len() && self.cfg.supports_tp(q * 2) {
            q *= 2;
        }
        q
    }

    fn bind_dp(&mut self, rid: u64, e: usize, recorder: &mut Recorder) -> Result<()> {
        self.adaptors[e].register(rid, 1)?;
        let a = self.active.get_mut(&rid).unwrap();
        a.mode_p = 1;
        a.home = e;
        self.engine_active[e].push(rid);
        self.refresh_engine(e);
        recorder.on_first_sched(rid, self.now());
        Ok(())
    }

    /// Bind (or queue) a TP request onto an aligned group of width p.
    fn bind_tp(
        &mut self,
        rid: u64,
        p: usize,
        strategy: Strategy,
        recorder: &mut Recorder,
    ) -> Result<()> {
        // Prefer an already-bound group at this width with batch room, else
        // the group whose members have the least DP work.  Starts whose
        // members belong to a live group of a *different* width are excluded
        // (a group can only be re-bound after it dissolves).
        let conflict = |s: usize| {
            self.groups.iter().any(|(&gs, g)| {
                let overlap = gs < s + p && s < gs + g.p;
                overlap
                    && (gs != s || g.p != p)
                    && (!g.tp_active.is_empty() || !g.tp_pending.is_empty())
            })
        };
        let mut bound: Option<usize> = None;
        let mut best: Option<(usize, usize)> = None; // (load, start)
        let mut any_start = false;
        let mut s = 0usize;
        while s + p <= self.engines.len() {
            if !conflict(s) {
                any_start = true;
                if self
                    .groups
                    .get(&s)
                    .map(|g| g.p == p && g.tp_active.len() < self.b_dec)
                    .unwrap_or(false)
                {
                    if bound.is_none() {
                        bound = Some(s);
                    }
                } else if bound.is_none() {
                    let load: usize = self
                        .members(s, p)
                        .map(|e| {
                            self.engine_active[e].len()
                                + 100 * (self.engine_mode[e] > 1) as usize
                        })
                        .sum();
                    if best.map(|(l, _)| load < l).unwrap_or(true) {
                        best = Some((load, s));
                    }
                }
            }
            s += p;
        }
        if !any_start {
            // No compatible group right now; retry next iteration.
            self.waiting.push(rid);
            return Ok(());
        }
        let start = bound.unwrap_or_else(|| best.map(|(_, s)| s).unwrap());

        // Admission control: all members must have block headroom for the
        // request's worst case under layout p.
        let need_p = self.block_need(rid, p);
        let room = self
            .members(start, p)
            .all(|e| self.engine_committed[e] + need_p <= self.cfg.n_blocks - 1);
        if !room {
            self.waiting.push(rid);
            return Ok(());
        }

        let mut busy = std::mem::take(&mut self.scratch.busy);
        busy.clear();
        for e in self.members(start, p) {
            for &r in &self.engine_active[e] {
                if self
                    .active
                    .get(&r)
                    .map(|a| a.phase != Phase::Done && !a.paused)
                    .unwrap_or(false)
                {
                    busy.push(r);
                }
            }
        }

        let g = self.groups.entry(start).or_insert_with(|| Group { p, ..Default::default() });
        g.p = p;

        if busy.is_empty() && self.engine_mode[start] != p {
            // Immediate bind at a safe point.
            self.switch_group(start, p)?;
        }

        if self.engine_mode[start] == p {
            // Register in every member adaptor (identical logical content,
            // per-member physical block ids).
            for e in self.members(start, p) {
                self.commit(rid, e, need_p);
                self.adaptors[e].register(rid, p)?;
            }
            let a = self.active.get_mut(&rid).unwrap();
            a.mode_p = p;
            a.home = start;
            self.groups.get_mut(&start).unwrap().tp_active.push(rid);
            recorder.on_first_sched(rid, self.now());
            self.scratch.busy = busy;
            return Ok(());
        }

        // Members still busy: strategy decides.
        match strategy {
            Strategy::Sequential => {
                self.groups.get_mut(&start).unwrap().tp_pending.push(rid);
                self.refresh_draining();
                let a = self.active.get_mut(&rid).unwrap();
                a.mode_p = p;
                a.home = start;
            }
            Strategy::SoftPreempt => {
                self.groups.get_mut(&start).unwrap().tp_pending.push(rid);
                self.refresh_draining();
                let a = self.active.get_mut(&rid).unwrap();
                a.mode_p = p;
                a.home = start;
                // Speculatively run in DP on the least-loaded member (only
                // if a member has DP-layout headroom).
                let need_dp = self.block_need(rid, 1);
                let e = self
                    .members(start, p)
                    .filter(|&e| self.engine_committed[e] + need_dp <= self.cfg.n_blocks - 1)
                    .min_by_key(|&e| self.engine_active[e].len());
                if let Some(e) = e {
                    self.commit(rid, e, need_dp);
                    self.adaptors[e].register(rid, 1)?;
                    let a = self.active.get_mut(&rid).unwrap();
                    a.speculative = true;
                    a.mode_p = 1; // runs as DP for now
                    a.home = e;
                    self.engine_active[e].push(rid);
                    self.refresh_engine(e);
                    recorder.on_first_sched(rid, self.now());
                }
            }
            Strategy::HardPreempt => {
                // Pause members' DP requests in place (KV stays resident).
                for &other in busy.iter() {
                    if let Some(a) = self.active.get_mut(&other) {
                        a.paused = true;
                        self.adaptors[a.home].pause(other)?;
                    }
                }
                self.switch_group(start, p)?;
                for e in self.members(start, p) {
                    self.commit(rid, e, need_p);
                    self.adaptors[e].register(rid, p)?;
                }
                let a = self.active.get_mut(&rid).unwrap();
                a.mode_p = p;
                a.home = start;
                self.groups.get_mut(&start).unwrap().tp_active.push(rid);
                recorder.on_first_sched(rid, self.now());
            }
        }
        self.scratch.busy = busy;
        Ok(())
    }

    /// Promote pending TP requests whose group has finished draining, and
    /// dissolve groups whose TP work is done.
    fn settle_groups(&mut self, recorder: &mut Recorder) -> Result<()> {
        if self.groups.is_empty() {
            return Ok(());
        }
        let mut starts = std::mem::take(&mut self.scratch.starts);
        starts.clear();
        starts.extend(self.groups.keys().copied());
        let mut dirty_draining = false;
        for si in 0..starts.len() {
            let start = starts[si];
            let (p, pending_empty, active_empty) = {
                let g = &self.groups[&start];
                (g.p, g.tp_pending.is_empty(), g.tp_active.is_empty())
            };

            // Dissolve: TP work done -> back to DP, resume paused requests.
            if pending_empty && active_empty {
                if self.engine_mode[start] == p && p > 1 {
                    self.switch_group(start, 1)?;
                    let mut resumed = std::mem::take(&mut self.scratch.ids);
                    for e in self.members(start, p) {
                        resumed.clear();
                        for &r in &self.engine_active[e] {
                            if self.active.get(&r).map(|a| a.paused).unwrap_or(false) {
                                resumed.push(r);
                            }
                        }
                        for &r in resumed.iter() {
                            self.adaptors[e].resume(r)?;
                            self.active.get_mut(&r).unwrap().paused = false;
                        }
                    }
                    self.scratch.ids = resumed;
                }
                self.groups.remove(&start);
                dirty_draining = true;
                continue;
            }

            // Drained? (no unpaused DP work on members)
            if !pending_empty {
                let busy = self
                    .members(start, p)
                    .flat_map(|e| self.engine_active[e].iter())
                    .any(|r| {
                        self.active
                            .get(r)
                            .map(|a| a.phase != Phase::Done && !a.paused && !a.speculative)
                            .unwrap_or(false)
                    });
                // Speculative requests also block the bind until... no: the
                // speculative request IS the pending one; it yields now.
                if !busy {
                    if self.engine_mode[start] != p {
                        self.switch_group(start, p)?;
                    }
                    let pending = std::mem::take(&mut self.groups.get_mut(&start).unwrap().tp_pending);
                    dirty_draining = true;
                    for rid in pending {
                        // Admission: TP-layout headroom on every member
                        // (the request's own held commitment is discounted).
                        let need_p = self.block_need(rid, p);
                        let room = self.members(start, p).all(|e| {
                            let held = self.active[&rid]
                                .committed
                                .iter()
                                .filter(|&&(ce, _)| ce == e)
                                .map(|&(_, b)| b)
                                .sum::<usize>();
                            self.engine_committed[e] - held + need_p <= self.cfg.n_blocks - 1
                        });
                        if !room {
                            self.groups.get_mut(&start).unwrap().tp_pending.push(rid);
                            continue;
                        }
                        // If it ran speculatively, drop its DP-layout KV and
                        // schedule the TP recompute (§5.2.2).
                        let (was_spec, spec_home) = {
                            let a = &self.active[&rid];
                            (a.speculative, a.home)
                        };
                        if was_spec {
                            self.adaptors[spec_home].release(rid)?;
                            self.engine_active[spec_home].retain(|&r| r != rid);
                            self.refresh_engine(spec_home);
                            let a = self.active.get_mut(&rid).unwrap();
                            a.speculative = false;
                            // Recompute prompt + already-fed output tokens.
                            a.forced = if a.emitted.is_empty() {
                                vec![]
                            } else {
                                vec![*a.emitted.last().unwrap()]
                            };
                            a.pos = 0;
                            a.phase = Phase::Prefill;
                        }
                        self.uncommit_all(rid);
                        for e in self.members(start, p) {
                            self.commit(rid, e, need_p);
                            self.adaptors[e].register(rid, p)?;
                        }
                        let a = self.active.get_mut(&rid).unwrap();
                        a.mode_p = p;
                        a.home = start;
                        self.groups.get_mut(&start).unwrap().tp_active.push(rid);
                        recorder.on_first_sched(rid, self.now());
                    }
                }
            }
        }
        self.scratch.starts = starts;
        if dirty_draining {
            self.refresh_draining();
        }
        Ok(())
    }

    /// Step ⑥: issue one step per engine/group, lockstep.  Allocation-free
    /// once warm: plans and batches live in recycled arenas.
    fn execute_step(&mut self, recorder: &mut Recorder) -> Result<bool> {
        self.settle_groups(recorder)?;

        let mut sc = std::mem::take(&mut self.scratch);
        let result = self.execute_step_inner(&mut sc, recorder);
        if result.is_err() {
            // Re-synchronize the persistent per-engine reply channels: any
            // reply still outstanding from this aborted step would otherwise
            // be mis-attributed to the next command on this cluster.
            let mut pending = sc.pending_mask;
            while pending != 0 {
                let e = pending.trailing_zeros() as usize;
                pending &= pending - 1;
                let _ = self.engines[e].recv();
            }
        }
        sc.pending_mask = 0;
        self.scratch = sc;
        result
    }

    fn execute_step_inner(
        &mut self,
        sc: &mut StepScratch,
        recorder: &mut Recorder,
    ) -> Result<bool> {
        // ---- plan + issue -------------------------------------------------
        sc.issued.clear();
        sc.pending_mask = 0;
        sc.covered.clear();
        sc.covered.resize(self.engines.len(), false);
        sc.starts.clear();
        sc.starts.extend(self.groups.keys().copied());

        // TP groups first.
        for &start in sc.starts.iter() {
            let (p, has_active) = {
                let g = &self.groups[&start];
                (g.p, !g.tp_active.is_empty())
            };
            if !has_active {
                continue;
            }
            for e in self.members(start, p) {
                sc.covered[e] = true;
            }
            // Prefill-first within the group (chunked prefill).
            let pre = {
                let g = &self.groups[&start];
                g.tp_active.iter().copied().find(|r| {
                    self.active.get(r).map(|a| a.phase == Phase::Prefill).unwrap_or(false)
                })
            };
            if let Some(rid) = pre {
                for e in self.members(start, p) {
                    let chunk = self.make_prefill_chunk(rid, e)?;
                    self.engines[e].send(EngineCmd::TpPrefill { p, chunk });
                    sc.pending_mask |= 1u64 << e;
                }
                sc.issued.push(Issued { home: start, p, is_prefill: true });
            } else {
                sc.decode_rids.clear();
                {
                    let g = &self.groups[&start];
                    for &r in g.tp_active.iter() {
                        if self.active.get(&r).map(|a| a.phase == Phase::Decode).unwrap_or(false) {
                            if sc.decode_rids.len() == self.b_dec {
                                break;
                            }
                            sc.decode_rids.push(r);
                        }
                    }
                }
                if !sc.decode_rids.is_empty() {
                    for e in self.members(start, p) {
                        let batch = self.make_decode_batch(e, &sc.decode_rids)?;
                        self.engines[e].send(EngineCmd::TpDecode { p, batch });
                        sc.pending_mask |= 1u64 << e;
                    }
                    sc.issued.push(Issued { home: start, p, is_prefill: false });
                }
            }
        }

        // DP engines.
        for e in 0..self.engines.len() {
            if sc.covered[e] {
                continue;
            }
            let mut pre: Option<u64> = None;
            sc.decode_rids.clear();
            for &r in &self.engine_active[e] {
                let Some(a) = self.active.get(&r) else { continue };
                if a.paused || a.phase == Phase::Done {
                    continue;
                }
                if a.phase == Phase::Prefill {
                    if pre.is_none() {
                        pre = Some(r);
                    }
                } else if sc.decode_rids.len() < self.b_dec {
                    sc.decode_rids.push(r);
                }
            }
            if let Some(rid) = pre {
                let chunk = self.make_prefill_chunk(rid, e)?;
                self.engines[e].send(EngineCmd::DpPrefill { chunk });
                sc.pending_mask |= 1u64 << e;
                sc.issued.push(Issued { home: e, p: 1, is_prefill: true });
            } else if !sc.decode_rids.is_empty() {
                let batch = self.make_decode_batch(e, &sc.decode_rids)?;
                self.engines[e].send(EngineCmd::DpDecode { batch });
                sc.pending_mask |= 1u64 << e;
                sc.issued.push(Issued { home: e, p: 1, is_prefill: false });
            }
        }

        if sc.issued.is_empty() {
            return Ok(false);
        }

        // ---- collect + publish (issue order; TP members meet in the
        // collectives, so all their commands are already in flight) --------
        for ii in 0..sc.issued.len() {
            let Issued { home, p, is_prefill } = sc.issued[ii];
            let mut first: Option<EngineReply> = None;
            for e in self.members(home, p) {
                let r = self.engines[e].recv();
                sc.pending_mask &= !(1u64 << e);
                let r = r?;
                if let EngineReply::Err(msg) = &r {
                    bail!("engine {e}: {msg}");
                }
                if first.is_none() {
                    first = Some(r);
                }
            }
            let now = self.now();
            match (first.unwrap(), is_prefill) {
                (EngineReply::LastLogits(logits), true) => {
                    let rid = self.engine_scratch[home].prefill_chunk.rid;
                    self.advance_prefill(rid, &logits, now, recorder)?;
                }
                (EngineReply::Logits(rows), false) => {
                    sc.publish_rids.clear();
                    sc.publish_rids
                        .extend(self.engine_scratch[home].decode_batch.iter().map(|s| s.rid));
                    for (rid, row) in sc.publish_rids.iter().zip(rows) {
                        self.advance_decode(*rid, &row, now, recorder)?;
                    }
                }
                (r, _) => bail!("unexpected engine reply {r:?}"),
            }
        }
        Ok(true)
    }

    /// Build the next prefill chunk for `rid` into engine `e`'s recycled
    /// arena (Algorithm 1 step 4: allocate + slot mapping).  No allocation
    /// once warm: tokens are indexed straight out of the request, the
    /// block-table row is copied from the adaptor's cached row.
    fn make_prefill_chunk(&mut self, rid: u64, e: usize) -> Result<Arc<PrefillChunk>> {
        let (start, end, plen) = {
            let a = &self.active[&rid];
            let full_len = a.sr.prompt.len() + a.emitted.len().saturating_sub(1);
            let start = a.pos;
            (start, (start + self.c_prefill).min(full_len), a.sr.prompt.len())
        };
        anyhow::ensure!(end > start, "empty prefill chunk for {rid}");
        self.adaptors[e].ensure_capacity(rid, end)?;
        {
            let a = &self.active[&rid];
            let ch = Arc::make_mut(&mut self.engine_scratch[e].prefill_chunk);
            ch.rid = rid;
            ch.start = start;
            ch.tokens.clear();
            for i in start..end {
                ch.tokens.push(if i < plen {
                    a.sr.prompt[i]
                } else {
                    a.emitted[i - plen]
                });
            }
        }
        {
            // Slot mapping needs the adaptor immutably; fill in a second
            // pass to keep the borrows disjoint.
            let ch = Arc::make_mut(&mut self.engine_scratch[e].prefill_chunk);
            ch.slot_ids.clear();
            for i in start..end {
                ch.slot_ids.push(self.adaptors[e].slot(rid, i)?);
            }
            ch.table_row.clear();
            ch.table_row.extend_from_slice(self.adaptors[e].table_row_ref(rid)?);
        }
        Ok(self.engine_scratch[e].prefill_chunk.clone())
    }

    /// Build a decode batch for engine `e` into its recycled arena.
    fn make_decode_batch(&mut self, e: usize, rids: &[u64]) -> Result<Arc<Vec<DecodeSlot>>> {
        // Grow/shrink the slot list, recycling retired slots (and their row
        // buffers) through the spare pool.
        {
            let scratch = &mut self.engine_scratch[e];
            let slots = Arc::make_mut(&mut scratch.decode_batch);
            while slots.len() > rids.len() {
                scratch.spare_slots.push(slots.pop().unwrap());
            }
            while slots.len() < rids.len() {
                slots.push(scratch.spare_slots.pop().unwrap_or_default());
            }
        }
        for (i, &rid) in rids.iter().enumerate() {
            let (token, pos) = {
                let a = &self.active[&rid];
                let token = *a
                    .emitted
                    .last()
                    .ok_or_else(|| anyhow::anyhow!("decode with no emitted token"))?;
                (token, a.pos)
            };
            self.adaptors[e].ensure_capacity(rid, pos + 1)?;
            self.adaptors[e].set_seq_len(rid, pos + 1)?;
            let slot_id = self.adaptors[e].slot(rid, pos)?;
            let row = self.adaptors[e].table_row_ref(rid)?;
            let slots = Arc::make_mut(&mut self.engine_scratch[e].decode_batch);
            let s = &mut slots[i];
            s.rid = rid;
            s.token = token;
            s.pos = pos;
            s.slot_id = slot_id;
            s.table_row.clear();
            s.table_row.extend_from_slice(row);
        }
        Ok(self.engine_scratch[e].decode_batch.clone())
    }

    fn prefill_total_len(&self, rid: u64) -> usize {
        let a = &self.active[&rid];
        a.sr.prompt.len() + a.emitted.len().saturating_sub(1)
    }

    fn advance_prefill(
        &mut self,
        rid: u64,
        logits: &[f32],
        now: f64,
        recorder: &mut Recorder,
    ) -> Result<()> {
        let total = self.prefill_total_len(rid);
        let a = self.active.get_mut(&rid).unwrap();
        let chunk_len = (total - a.pos).min(self.c_prefill);
        a.pos += chunk_len;
        if a.pos < total {
            return Ok(()); // more chunks to go
        }
        // Prefill complete.
        a.phase = Phase::Decode;
        if a.emitted.is_empty() {
            let tok = argmax(logits);
            a.emitted.push(tok);
            recorder.on_token(rid, now);
            self.maybe_finish(rid, now, recorder)?;
        }
        // else: soft-preempt recompute — logits discarded, the already-
        // emitted tail token is fed next via `forced` semantics (it is the
        // last element of `emitted`, which decode feeds automatically).
        Ok(())
    }

    fn advance_decode(
        &mut self,
        rid: u64,
        logits: &[f32],
        now: f64,
        recorder: &mut Recorder,
    ) -> Result<()> {
        let a = self.active.get_mut(&rid).unwrap();
        a.pos += 1; // the fed token's KV is now cached
        let tok = argmax(logits);
        a.emitted.push(tok);
        recorder.on_token(rid, now);
        self.maybe_finish(rid, now, recorder)
    }

    fn maybe_finish(&mut self, rid: u64, now: f64, recorder: &mut Recorder) -> Result<()> {
        let (done, mode_p, home) = {
            let a = &self.active[&rid];
            let done = a.emitted.len() >= a.sr.max_new || a.emitted.last() == Some(&EOS);
            (done, a.mode_p, a.home)
        };
        if !done {
            return Ok(());
        }
        let a = self.active.get_mut(&rid).unwrap();
        a.phase = Phase::Done;
        let emitted = a.emitted.clone();
        recorder.on_finish(rid, now);
        self.outputs.insert(rid, emitted);
        self.uncommit_all(rid);
        if mode_p <= 1 {
            self.adaptors[home].release(rid)?;
            self.engine_active[home].retain(|&r| r != rid);
            self.refresh_engine(home);
        } else {
            for e in self.members(home, mode_p) {
                self.adaptors[e].release(rid)?;
            }
            if let Some(g) = self.groups.get_mut(&home) {
                g.tp_active.retain(|&r| r != rid);
            }
        }
        Ok(())
    }

    pub fn shutdown(&mut self) {
        for e in &mut self.engines {
            e.stop();
        }
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }
}
