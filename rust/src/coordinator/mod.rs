//! The FLYING SERVING coordinator (paper §3, §5): a middleware layer between
//! the global task pool and the engine workers that binds subsets of DP
//! engines into TP groups and releases them — the single switching
//! primitive — under a workload-aware policy and a switching strategy.
//!
//! The scheduling loop is Algorithm 1:
//!   ① ProcessInputSocket  — drain arrivals into the task pool
//!   ② SyncWorkload        — a globally-agreed waiting queue (priority,
//!                            arrival) — single-coordinator equivalent of
//!                            the paper's heartbeat all-reduce
//!   ③ Mode determination  — `Policy::decide` per request
//!   ④ KV parameterization — `B_req = B_base · N_eng` via the adaptor's
//!                            layout registration + block allocation
//!   ⑤ Mode signaling      — `SetMode` collective RPC to group members at
//!                            the iteration safe point
//!   ⑥ execute_model       — step commands to engines/groups; publish
//!
//! Engines run lockstep per scheduling iteration (the coordinator waits for
//! every issued step before the next iteration); TP members execute
//! concurrently on their threads and meet in the Communicator Pool's
//! collectives.

pub mod policy;
pub mod strategy;

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::comm::CommunicatorPool;
use crate::engine::{DecodeSlot, EngineCmd, EngineHandle, EngineReply, PrefillChunk};
use crate::kv::KvCacheAdaptor;
use crate::metrics::Recorder;
use crate::model::ModelCfg;
use crate::runtime::Manifest;
use crate::workload::Priority;
use policy::{ModeDecision, Policy, Snapshot};
use strategy::Strategy;

pub const EOS: i32 = 257;

/// A request as submitted to the cluster (the real serving path).
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub priority: Priority,
    pub tp_demand: Option<usize>,
    /// Arrival offset in seconds from cluster-clock zero (trace replay);
    /// requests become visible to the scheduler at this time.
    pub arrival: f64,
}

#[derive(Clone, Debug, PartialEq)]
enum Phase {
    Prefill,
    Decode,
    Done,
}

#[derive(Clone, Debug)]
struct Active {
    sr: ServeRequest,
    mode_p: usize,
    /// Engine id (DP) or group start (TP).
    home: usize,
    phase: Phase,
    /// Tokens whose KV is cached (prompt progress + fed output tokens).
    pos: usize,
    emitted: Vec<i32>,
    paused: bool,
    /// Soft-preempt: running speculatively in DP while its TP group drains.
    speculative: bool,
    /// Forced next inputs after a soft-preempt recompute (already emitted).
    forced: Vec<i32>,
    /// Worst-case block commitment per engine (admission control): the
    /// blocks this request may grow into, reserved at bind time so the pool
    /// can never be overcommitted mid-decode.
    committed: Vec<(usize, usize)>,
}

#[derive(Clone, Debug, Default)]
struct Group {
    p: usize,
    tp_active: Vec<u64>,
    /// TP requests waiting for this group to finish draining.
    tp_pending: Vec<u64>,
}

/// Mode-switch event log (feeds the Table-2 switching-latency measurement).
#[derive(Clone, Debug)]
pub struct SwitchEvent {
    pub t: f64,
    pub group_start: usize,
    pub p_from: usize,
    pub p_to: usize,
    pub latency_s: f64,
}

pub struct ClusterOutcome {
    pub recorder: Recorder,
    pub outputs: BTreeMap<u64, Vec<i32>>,
    pub rejected: Vec<u64>,
    pub switches: Vec<SwitchEvent>,
}

/// The real serving cluster: N engine threads + adaptors + communicator
/// pool + the dynamic scheduler.
pub struct Cluster {
    pub cfg: ModelCfg,
    engines: Vec<EngineHandle>,
    adaptors: Vec<KvCacheAdaptor>,
    pub comm: Arc<CommunicatorPool>,
    max_tp: usize,
    b_dec: usize,
    c_prefill: usize,

    // scheduler state
    waiting: Vec<u64>,
    active: BTreeMap<u64, Active>,
    engine_active: Vec<Vec<u64>>, // DP requests per engine
    engine_mode: Vec<usize>,
    /// Blocks committed per engine by admission control.
    engine_committed: Vec<usize>,
    groups: BTreeMap<usize, Group>,
    outputs: BTreeMap<u64, Vec<i32>>,
    rejected: Vec<u64>,
    switches: Vec<SwitchEvent>,
    t0: Instant,
}

impl Cluster {
    /// Boot `n_engines` engine workers for `model` (weights loaded once,
    /// artifacts compiled eagerly, communicator pool pre-initialized).
    pub fn start(manifest: &Arc<Manifest>, model: &str, n_engines: usize) -> Result<Cluster> {
        let mm = manifest.model(model)?;
        let cfg = mm.cfg.clone();
        let ws = Arc::new(mm.load_weights()?);
        let mut degrees: Vec<usize> = manifest
            .tp_degrees
            .iter()
            .copied()
            .filter(|&p| cfg.supports_tp(p) && p <= n_engines)
            .collect();
        if !degrees.contains(&1) {
            degrees.push(1);
        }
        let max_tp = degrees.iter().copied().max().unwrap_or(1);
        let comm = Arc::new(CommunicatorPool::new(
            n_engines,
            &degrees,
            Duration::from_secs(30),
        ));
        let mut engines = Vec::new();
        for id in 0..n_engines {
            engines.push(
                EngineHandle::spawn(id, manifest.clone(), model.to_string(), ws.clone(), comm.clone())
                    .with_context(|| format!("starting engine {id}"))?,
            );
        }
        let adaptors = (0..n_engines).map(|_| KvCacheAdaptor::new(cfg.clone())).collect();
        Ok(Cluster {
            cfg,
            engines,
            adaptors,
            comm,
            max_tp,
            b_dec: manifest.shapes.b_dec,
            c_prefill: manifest.shapes.c_prefill,
            waiting: Vec::new(),
            active: BTreeMap::new(),
            engine_active: vec![Vec::new(); n_engines],
            engine_mode: vec![1; n_engines],
            engine_committed: vec![0; n_engines],
            groups: BTreeMap::new(),
            outputs: BTreeMap::new(),
            rejected: Vec::new(),
            switches: Vec::new(),
            t0: Instant::now(),
        })
    }

    pub fn n_engines(&self) -> usize {
        self.engines.len()
    }

    pub fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    fn members(&self, start: usize, p: usize) -> std::ops::Range<usize> {
        start..start + p
    }

    /// Live mode switch: SetMode RPC to every member + communicator fetch.
    /// Returns the measured latency (the Table-2 "live" number).
    fn switch_group(&mut self, start: usize, p_to: usize) -> Result<f64> {
        let p_from = self.engine_mode[start];
        let t_start = Instant::now();
        // Communicator activation: O(1) pool lookup (pre-initialized).
        if p_to > 1 {
            let _ = self.comm.group_of(start, p_to)?;
        }
        let width = p_to.max(p_from);
        for e in self.members(start, width) {
            if e < self.engines.len() {
                self.engines[e].call(EngineCmd::SetMode { p: p_to })?;
                self.engine_mode[e] = p_to;
            }
        }
        let dt = t_start.elapsed().as_secs_f64();
        self.switches.push(SwitchEvent {
            t: self.now(),
            group_start: start,
            p_from,
            p_to,
            latency_s: dt,
        });
        Ok(dt)
    }

    // ------------------------------------------------------------------
    // Trace replay driver: submit all requests with arrival offsets, run
    // Algorithm 1 until everything finishes.
    // ------------------------------------------------------------------

    pub fn run_trace(
        &mut self,
        mut trace: Vec<ServeRequest>,
        policy: &mut dyn Policy,
        strategy: Strategy,
    ) -> Result<ClusterOutcome> {
        trace.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let mut recorder = Recorder::new();
        self.t0 = Instant::now();
        let mut next_arrival = 0usize;
        let mut idle_iters = 0usize;

        loop {
            let now = self.now();

            // Dissolve/settle groups first so freshly-freed engines are
            // visible to this iteration's mode decisions.
            self.settle_groups(&mut recorder)?;

            // ① Input processing: admit due arrivals into the task pool.
            while next_arrival < trace.len() && trace[next_arrival].arrival <= now {
                let sr = trace[next_arrival].clone();
                recorder.on_arrival(sr.id, sr.arrival, sr.priority, sr.prompt.len());
                self.admit(sr);
                next_arrival += 1;
            }

            // ② Globally-agreed waiting order: priority first, then arrival.
            self.waiting.sort_by(|a, b| {
                let ra = &self.active[a].sr;
                let rb = &self.active[b].sr;
                rb.priority
                    .cmp(&ra.priority)
                    .then(ra.arrival.partial_cmp(&rb.arrival).unwrap())
            });

            // ③+④+⑤ Mode determination, KV parameterization, binding.
            self.assign_waiting(policy, strategy, &mut recorder)?;

            // ⑥ Execute one step on every engine/group with work.
            let stepped = self.execute_step(&mut recorder)?;

            // Exit/idle handling.
            let done = self.active.values().all(|a| a.phase == Phase::Done)
                && next_arrival >= trace.len()
                && self.waiting.is_empty();
            if done {
                break;
            }
            if !stepped {
                idle_iters += 1;
                // Nothing runnable: sleep until the next arrival.
                if next_arrival < trace.len() {
                    let dt = trace[next_arrival].arrival - self.now();
                    if dt > 0.0 {
                        std::thread::sleep(Duration::from_secs_f64(dt.min(0.05)));
                    }
                } else if idle_iters > 10_000 {
                    // Requests exist but nothing has run for many
                    // iterations: genuine scheduling bug, fail loudly
                    // instead of hanging.
                    bail!("scheduler stall: waiting={:?}", self.waiting);
                }
            } else {
                idle_iters = 0;
            }
        }

        Ok(ClusterOutcome {
            recorder,
            outputs: std::mem::take(&mut self.outputs),
            rejected: std::mem::take(&mut self.rejected),
            switches: std::mem::take(&mut self.switches),
        })
    }

    fn admit(&mut self, sr: ServeRequest) {
        let id = sr.id;
        self.active.insert(
            id,
            Active {
                sr,
                mode_p: 0,
                home: 0,
                phase: Phase::Prefill,
                pos: 0,
                emitted: Vec::new(),
                paused: false,
                speculative: false,
                forced: Vec::new(),
                committed: Vec::new(),
            },
        );
        self.waiting.push(id);
    }

    fn snapshot(&self) -> Snapshot {
        let idle = (0..self.engines.len())
            .filter(|&e| self.engine_mode[e] == 1 && self.engine_active[e].is_empty())
            .count();
        Snapshot {
            queue_len: self.waiting.len(),
            idle_engines: idle,
            n_engines: self.engines.len(),
            dp_capacity_tokens: self.cfg.dp_token_capacity(),
            max_tp: self.max_tp,
        }
    }

    /// Steps ③–⑤ for every waiting request.
    fn assign_waiting(
        &mut self,
        policy: &mut dyn Policy,
        strategy: Strategy,
        recorder: &mut Recorder,
    ) -> Result<()> {
        let waiting = std::mem::take(&mut self.waiting);
        let backlog_total = waiting.len();
        for (qi, rid) in waiting.into_iter().enumerate() {
            let mut snap = self.snapshot();
            // Include requests later in this same drain in the backlog so
            // the burst signal sees the true queue depth.
            snap.queue_len += backlog_total - qi - 1;
            let (plen, hint, pri, demand) = {
                let a = &self.active[&rid];
                (
                    a.sr.prompt.len(),
                    a.sr.max_new,
                    a.sr.priority,
                    a.sr.tp_demand,
                )
            };
            match policy.decide(plen, hint, pri, demand, &snap) {
                ModeDecision::Reject => {
                    self.active.get_mut(&rid).unwrap().phase = Phase::Done;
                    self.rejected.push(rid);
                    recorder.on_finish(rid, self.now());
                }
                ModeDecision::Dp => self.try_bind_dp(rid, recorder)?,
                ModeDecision::Tp(p) => {
                    let p = self.clamp_tp(p);
                    if p == 1 {
                        // Degenerate TP (single engine / unsupported width).
                        self.try_bind_dp(rid, recorder)?;
                    } else {
                        self.bind_tp(rid, p, strategy, recorder)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Worst-case block demand of `rid` under layout `p` (admission unit).
    fn block_need(&self, rid: u64, p: usize) -> usize {
        let a = &self.active[&rid];
        let total = a.sr.prompt.len() + a.sr.max_new;
        total.div_ceil(self.cfg.block_tokens(p))
    }

    fn commit(&mut self, rid: u64, e: usize, blocks: usize) {
        self.engine_committed[e] += blocks;
        self.active.get_mut(&rid).unwrap().committed.push((e, blocks));
    }

    fn uncommit_all(&mut self, rid: u64) {
        let committed = std::mem::take(&mut self.active.get_mut(&rid).unwrap().committed);
        for (e, blocks) in committed {
            self.engine_committed[e] -= blocks;
        }
    }

    /// Bind to the least-loaded unbound engine with KV headroom, or queue.
    fn try_bind_dp(&mut self, rid: u64, recorder: &mut Recorder) -> Result<()> {
        let need = self.block_need(rid, 1);
        let pick = (0..self.engines.len())
            .filter(|&e| self.engine_mode[e] == 1 && !self.engine_draining(e))
            .filter(|&e| self.engine_committed[e] + need <= self.cfg.n_blocks - 1)
            .min_by_key(|&e| self.engine_active[e].len());
        match pick {
            Some(e) => {
                self.commit(rid, e, need);
                self.bind_dp(rid, e, recorder)
            }
            None => {
                self.waiting.push(rid);
                Ok(())
            }
        }
    }

    fn clamp_tp(&self, p: usize) -> usize {
        let mut q = 1;
        while q * 2 <= p && q * 2 <= self.engines.len() && self.cfg.supports_tp(q * 2) {
            q *= 2;
        }
        q
    }

    fn engine_draining(&self, e: usize) -> bool {
        self.groups
            .iter()
            .any(|(&start, g)| e >= start && e < start + g.p && !g.tp_pending.is_empty())
    }

    fn bind_dp(&mut self, rid: u64, e: usize, recorder: &mut Recorder) -> Result<()> {
        self.adaptors[e].register(rid, 1)?;
        let a = self.active.get_mut(&rid).unwrap();
        a.mode_p = 1;
        a.home = e;
        self.engine_active[e].push(rid);
        recorder.on_first_sched(rid, self.now());
        Ok(())
    }

    /// Bind (or queue) a TP request onto an aligned group of width p.
    fn bind_tp(
        &mut self,
        rid: u64,
        p: usize,
        strategy: Strategy,
        recorder: &mut Recorder,
    ) -> Result<()> {
        // Prefer an already-bound group at this width with batch room, else
        // the group whose members have the least DP work.  Starts whose
        // members belong to a live group of a *different* width are excluded
        // (a group can only be re-bound after it dissolves).
        let conflict = |s: usize| {
            self.groups.iter().any(|(&gs, g)| {
                let overlap = gs < s + p && s < gs + g.p;
                overlap
                    && (gs != s || g.p != p)
                    && (!g.tp_active.is_empty() || !g.tp_pending.is_empty())
            })
        };
        let starts: Vec<usize> = (0..self.engines.len())
            .step_by(p)
            .filter(|&s| s + p <= self.engines.len() && !conflict(s))
            .collect();
        if starts.is_empty() {
            // No compatible group right now; retry next iteration.
            self.waiting.push(rid);
            return Ok(());
        }
        let bound = starts.iter().copied().find(|s| {
            self.groups
                .get(s)
                .map(|g| g.p == p && g.tp_active.len() < self.b_dec)
                .unwrap_or(false)
        });
        let start = bound.unwrap_or_else(|| {
            *starts
                .iter()
                .min_by_key(|&&s| {
                    self.members(s, p)
                        .map(|e| self.engine_active[e].len() + 100 * (self.engine_mode[e] > 1) as usize)
                        .sum::<usize>()
                })
                .unwrap()
        });

        // Admission control: all members must have block headroom for the
        // request's worst case under layout p.
        let need_p = self.block_need(rid, p);
        let room = self
            .members(start, p)
            .all(|e| self.engine_committed[e] + need_p <= self.cfg.n_blocks - 1);
        if !room {
            self.waiting.push(rid);
            return Ok(());
        }

        let busy: Vec<u64> = self
            .members(start, p)
            .flat_map(|e| self.engine_active[e].clone())
            .filter(|r| {
                self.active
                    .get(r)
                    .map(|a| a.phase != Phase::Done && !a.paused)
                    .unwrap_or(false)
            })
            .collect();

        let g = self.groups.entry(start).or_insert_with(|| Group { p, ..Default::default() });
        g.p = p;

        if busy.is_empty() && self.engine_mode[start] != p {
            // Immediate bind at a safe point.
            self.switch_group(start, p)?;
        }

        if self.engine_mode[start] == p {
            // Register in every member adaptor (identical logical content,
            // per-member physical block ids).
            for e in self.members(start, p) {
                self.commit(rid, e, need_p);
                self.adaptors[e].register(rid, p)?;
            }
            let a = self.active.get_mut(&rid).unwrap();
            a.mode_p = p;
            a.home = start;
            self.groups.get_mut(&start).unwrap().tp_active.push(rid);
            recorder.on_first_sched(rid, self.now());
            return Ok(());
        }

        // Members still busy: strategy decides.
        match strategy {
            Strategy::Sequential => {
                self.groups.get_mut(&start).unwrap().tp_pending.push(rid);
                let a = self.active.get_mut(&rid).unwrap();
                a.mode_p = p;
                a.home = start;
            }
            Strategy::SoftPreempt => {
                self.groups.get_mut(&start).unwrap().tp_pending.push(rid);
                let a = self.active.get_mut(&rid).unwrap();
                a.mode_p = p;
                a.home = start;
                // Speculatively run in DP on the least-loaded member (only
                // if a member has DP-layout headroom).
                let need_dp = self.block_need(rid, 1);
                let e = self
                    .members(start, p)
                    .filter(|&e| self.engine_committed[e] + need_dp <= self.cfg.n_blocks - 1)
                    .min_by_key(|&e| self.engine_active[e].len());
                if let Some(e) = e {
                    self.commit(rid, e, need_dp);
                    self.adaptors[e].register(rid, 1)?;
                    let a = self.active.get_mut(&rid).unwrap();
                    a.speculative = true;
                    a.mode_p = 1; // runs as DP for now
                    a.home = e;
                    self.engine_active[e].push(rid);
                    recorder.on_first_sched(rid, self.now());
                }
            }
            Strategy::HardPreempt => {
                // Pause members' DP requests in place (KV stays resident).
                for other in busy {
                    if let Some(a) = self.active.get_mut(&other) {
                        a.paused = true;
                        self.adaptors[a.home].pause(other)?;
                    }
                }
                self.switch_group(start, p)?;
                for e in self.members(start, p) {
                    self.commit(rid, e, need_p);
                    self.adaptors[e].register(rid, p)?;
                }
                let a = self.active.get_mut(&rid).unwrap();
                a.mode_p = p;
                a.home = start;
                self.groups.get_mut(&start).unwrap().tp_active.push(rid);
                recorder.on_first_sched(rid, self.now());
            }
        }
        Ok(())
    }

    /// Promote pending TP requests whose group has finished draining, and
    /// dissolve groups whose TP work is done.
    fn settle_groups(&mut self, recorder: &mut Recorder) -> Result<()> {
        let starts: Vec<usize> = self.groups.keys().copied().collect();
        for start in starts {
            let (p, pending_empty, active_empty) = {
                let g = &self.groups[&start];
                (g.p, g.tp_pending.is_empty(), g.tp_active.is_empty())
            };

            // Dissolve: TP work done -> back to DP, resume paused requests.
            if pending_empty && active_empty {
                if self.engine_mode[start] == p && p > 1 {
                    self.switch_group(start, 1)?;
                    for e in self.members(start, p) {
                        let resumed: Vec<u64> = self.engine_active[e]
                            .iter()
                            .copied()
                            .filter(|r| self.active.get(r).map(|a| a.paused).unwrap_or(false))
                            .collect();
                        for r in resumed {
                            self.adaptors[e].resume(r)?;
                            self.active.get_mut(&r).unwrap().paused = false;
                        }
                    }
                }
                self.groups.remove(&start);
                continue;
            }

            // Drained? (no unpaused DP work on members)
            if !pending_empty {
                let busy = self
                    .members(start, p)
                    .flat_map(|e| self.engine_active[e].iter())
                    .any(|r| {
                        self.active
                            .get(r)
                            .map(|a| a.phase != Phase::Done && !a.paused && !a.speculative)
                            .unwrap_or(false)
                    });
                // Speculative requests also block the bind until... no: the
                // speculative request IS the pending one; it yields now.
                if !busy {
                    if self.engine_mode[start] != p {
                        self.switch_group(start, p)?;
                    }
                    let pending = std::mem::take(&mut self.groups.get_mut(&start).unwrap().tp_pending);
                    for rid in pending {
                        // Admission: TP-layout headroom on every member
                        // (speculative DP commitment is released first).
                        let need_p = self.block_need(rid, p);
                        let spec_blocks: usize = self.active[&rid]
                            .committed
                            .iter()
                            .map(|&(_, b)| b)
                            .sum();
                        let room = self.members(start, p).all(|e| {
                            let held = self.active[&rid]
                                .committed
                                .iter()
                                .filter(|&&(ce, _)| ce == e)
                                .map(|&(_, b)| b)
                                .sum::<usize>();
                            self.engine_committed[e] - held + need_p <= self.cfg.n_blocks - 1
                        });
                        let _ = spec_blocks;
                        if !room {
                            self.groups.get_mut(&start).unwrap().tp_pending.push(rid);
                            continue;
                        }
                        // If it ran speculatively, drop its DP-layout KV and
                        // schedule the TP recompute (§5.2.2).
                        let (was_spec, spec_home) = {
                            let a = &self.active[&rid];
                            (a.speculative, a.home)
                        };
                        if was_spec {
                            self.adaptors[spec_home].release(rid)?;
                            self.engine_active[spec_home].retain(|&r| r != rid);
                            let a = self.active.get_mut(&rid).unwrap();
                            a.speculative = false;
                            // Recompute prompt + already-fed output tokens.
                            let emitted = a.emitted.clone();
                            a.forced = if emitted.is_empty() { vec![] } else { vec![*emitted.last().unwrap()] };
                            a.pos = 0;
                            a.phase = Phase::Prefill;
                        }
                        self.uncommit_all(rid);
                        for e in self.members(start, p) {
                            self.commit(rid, e, need_p);
                            self.adaptors[e].register(rid, p)?;
                        }
                        let a = self.active.get_mut(&rid).unwrap();
                        a.mode_p = p;
                        a.home = start;
                        self.groups.get_mut(&start).unwrap().tp_active.push(rid);
                        recorder.on_first_sched(rid, self.now());
                    }
                }
            }
        }
        Ok(())
    }

    /// Step ⑥: issue one step per engine/group, lockstep.
    fn execute_step(&mut self, recorder: &mut Recorder) -> Result<bool> {
        self.settle_groups(recorder)?;

        // Build the step plan.
        enum Plan {
            DpPrefill { e: usize, rid: u64 },
            DpDecode { e: usize, rids: Vec<u64> },
            TpPrefill { start: usize, p: usize, rid: u64 },
            TpDecode { start: usize, p: usize, rids: Vec<u64> },
        }
        let mut plans: Vec<Plan> = Vec::new();
        let mut covered = vec![false; self.engines.len()];

        // TP groups first.
        for (&start, g) in &self.groups {
            if g.tp_active.is_empty() {
                continue;
            }
            for e in self.members(start, g.p) {
                covered[e] = true;
            }
            // Prefill-first within the group (chunked prefill).
            let pre = g.tp_active.iter().copied().find(|r| {
                self.active.get(r).map(|a| a.phase == Phase::Prefill).unwrap_or(false)
            });
            if let Some(rid) = pre {
                plans.push(Plan::TpPrefill { start, p: g.p, rid });
            } else {
                let rids: Vec<u64> = g
                    .tp_active
                    .iter()
                    .copied()
                    .filter(|r| self.active.get(r).map(|a| a.phase == Phase::Decode).unwrap_or(false))
                    .take(self.b_dec)
                    .collect();
                if !rids.is_empty() {
                    plans.push(Plan::TpDecode { start, p: g.p, rids });
                }
            }
        }

        // DP engines.
        for e in 0..self.engines.len() {
            if covered[e] {
                continue;
            }
            let runnable: Vec<u64> = self.engine_active[e]
                .iter()
                .copied()
                .filter(|r| {
                    self.active
                        .get(r)
                        .map(|a| !a.paused && a.phase != Phase::Done)
                        .unwrap_or(false)
                })
                .collect();
            let pre = runnable.iter().copied().find(|r| self.active[r].phase == Phase::Prefill);
            if let Some(rid) = pre {
                plans.push(Plan::DpPrefill { e, rid });
            } else {
                let rids: Vec<u64> = runnable
                    .into_iter()
                    .filter(|r| self.active[r].phase == Phase::Decode)
                    .take(self.b_dec)
                    .collect();
                if !rids.is_empty() {
                    plans.push(Plan::DpDecode { e, rids });
                }
            }
        }

        if plans.is_empty() {
            return Ok(false);
        }

        // Issue all commands, then collect replies (TP members meet in the
        // collectives, so their commands must all be in flight together).
        struct Pending {
            rxs: Vec<(usize, std::sync::mpsc::Receiver<EngineReply>)>,
            rids: Vec<u64>,
            is_prefill: bool,
        }
        let mut pendings: Vec<Pending> = Vec::new();

        for plan in &plans {
            match plan {
                Plan::DpPrefill { e, rid } => {
                    let chunk = self.make_prefill_chunk(*rid, *e, 1)?;
                    let rx = self.engines[*e].send(EngineCmd::DpPrefill { chunk });
                    pendings.push(Pending { rxs: vec![(*e, rx)], rids: vec![*rid], is_prefill: true });
                }
                Plan::DpDecode { e, rids } => {
                    let batch = self.make_decode_batch(rids, *e, 1)?;
                    let rx = self.engines[*e].send(EngineCmd::DpDecode { batch });
                    pendings.push(Pending { rxs: vec![(*e, rx)], rids: rids.clone(), is_prefill: false });
                }
                Plan::TpPrefill { start, p, rid } => {
                    let mut rxs = Vec::new();
                    for e in self.members(*start, *p) {
                        let chunk = self.make_prefill_chunk(*rid, e, *p)?;
                        rxs.push((e, self.engines[e].send(EngineCmd::TpPrefill { p: *p, chunk })));
                    }
                    pendings.push(Pending { rxs, rids: vec![*rid], is_prefill: true });
                }
                Plan::TpDecode { start, p, rids } => {
                    let mut rxs = Vec::new();
                    for e in self.members(*start, *p) {
                        let batch = self.make_decode_batch(rids, e, *p)?;
                        rxs.push((e, self.engines[e].send(EngineCmd::TpDecode { p: *p, batch })));
                    }
                    pendings.push(Pending { rxs, rids: rids.clone(), is_prefill: false });
                }
            }
        }

        // Collect and publish.
        for pend in pendings {
            let mut first: Option<EngineReply> = None;
            for (e, rx) in pend.rxs {
                let r = rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("engine {e} died mid-step"))?;
                if let EngineReply::Err(msg) = &r {
                    bail!("engine {e}: {msg}");
                }
                if first.is_none() {
                    first = Some(r);
                }
            }
            let now = self.now();
            match (first.unwrap(), pend.is_prefill) {
                (EngineReply::LastLogits(logits), true) => {
                    self.advance_prefill(pend.rids[0], &logits, now, recorder)?;
                }
                (EngineReply::Logits(rows), false) => {
                    for (rid, row) in pend.rids.iter().zip(rows) {
                        self.advance_decode(*rid, &row, now, recorder)?;
                    }
                }
                (r, _) => bail!("unexpected engine reply {r:?}"),
            }
        }
        Ok(true)
    }

    /// Build the next prefill chunk for `rid` using engine `e`'s adaptor
    /// under layout `p` (Algorithm 1 step 4: allocate + slot mapping).
    fn make_prefill_chunk(&mut self, rid: u64, e: usize, p: usize) -> Result<PrefillChunk> {
        let a = &self.active[&rid];
        let full: Vec<i32> = a
            .sr
            .prompt
            .iter()
            .copied()
            .chain(a.emitted.iter().copied().take(a.emitted.len().saturating_sub(1)))
            .collect();
        let start = a.pos;
        let tokens: Vec<i32> = full[start..(start + self.c_prefill).min(full.len())].to_vec();
        anyhow::ensure!(!tokens.is_empty(), "empty prefill chunk for {rid}");
        let _ = p;
        self.adaptors[e].ensure_capacity(rid, start + tokens.len())?;
        let slot_ids = (0..tokens.len())
            .map(|i| self.adaptors[e].slot(rid, start + i))
            .collect::<Result<Vec<u32>>>()?;
        Ok(PrefillChunk {
            rid,
            tokens,
            start,
            slot_ids,
            table_row: self.adaptors[e].table_row(rid)?,
        })
    }

    fn make_decode_batch(&mut self, rids: &[u64], e: usize, _p: usize) -> Result<Vec<DecodeSlot>> {
        let mut out = Vec::new();
        for &rid in rids {
            let a = &self.active[&rid];
            let token = *a
                .emitted
                .last()
                .ok_or_else(|| anyhow::anyhow!("decode with no emitted token"))?;
            let pos = a.pos;
            self.adaptors[e].ensure_capacity(rid, pos + 1)?;
            self.adaptors[e].set_seq_len(rid, pos + 1)?;
            out.push(DecodeSlot {
                rid,
                token,
                pos,
                slot_id: self.adaptors[e].slot(rid, pos)?,
                table_row: self.adaptors[e].table_row(rid)?,
            });
        }
        Ok(out)
    }

    fn prefill_total_len(&self, rid: u64) -> usize {
        let a = &self.active[&rid];
        a.sr.prompt.len() + a.emitted.len().saturating_sub(1)
    }

    fn advance_prefill(
        &mut self,
        rid: u64,
        logits: &[f32],
        now: f64,
        recorder: &mut Recorder,
    ) -> Result<()> {
        let total = self.prefill_total_len(rid);
        let a = self.active.get_mut(&rid).unwrap();
        let chunk_len = (total - a.pos).min(self.c_prefill);
        a.pos += chunk_len;
        if a.pos < total {
            return Ok(()); // more chunks to go
        }
        // Prefill complete.
        a.phase = Phase::Decode;
        if a.emitted.is_empty() {
            let tok = argmax(logits);
            a.emitted.push(tok);
            recorder.on_token(rid, now);
            self.maybe_finish(rid, now, recorder)?;
        }
        // else: soft-preempt recompute — logits discarded, the already-
        // emitted tail token is fed next via `forced` semantics (it is the
        // last element of `emitted`, which decode feeds automatically).
        Ok(())
    }

    fn advance_decode(
        &mut self,
        rid: u64,
        logits: &[f32],
        now: f64,
        recorder: &mut Recorder,
    ) -> Result<()> {
        let a = self.active.get_mut(&rid).unwrap();
        a.pos += 1; // the fed token's KV is now cached
        let tok = argmax(logits);
        a.emitted.push(tok);
        recorder.on_token(rid, now);
        self.maybe_finish(rid, now, recorder)
    }

    fn maybe_finish(&mut self, rid: u64, now: f64, recorder: &mut Recorder) -> Result<()> {
        let (done, mode_p, home) = {
            let a = &self.active[&rid];
            let done = a.emitted.len() >= a.sr.max_new || a.emitted.last() == Some(&EOS);
            (done, a.mode_p, a.home)
        };
        if !done {
            return Ok(());
        }
        let a = self.active.get_mut(&rid).unwrap();
        a.phase = Phase::Done;
        let emitted = a.emitted.clone();
        recorder.on_finish(rid, now);
        self.outputs.insert(rid, emitted);
        self.uncommit_all(rid);
        if mode_p <= 1 {
            self.adaptors[home].release(rid)?;
            self.engine_active[home].retain(|&r| r != rid);
        } else {
            for e in self.members(home, mode_p) {
                self.adaptors[e].release(rid)?;
            }
            if let Some(g) = self.groups.get_mut(&home) {
                g.tp_active.retain(|&r| r != rid);
            }
        }
        Ok(())
    }

    pub fn shutdown(&mut self) {
        for e in &mut self.engines {
            e.stop();
        }
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }
}
