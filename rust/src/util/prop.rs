//! Minimal property-testing harness (proptest is not in the offline crate
//! set).  Seeded, with linear input shrinking: on failure the harness
//! re-runs the property with progressively "smaller" generated cases (the
//! generator is re-driven with smaller size hints) and reports the smallest
//! failing seed so the case is reproducible.
//!
//! Usage:
//! ```ignore
//! prop_check("kv adaptor never double-allocates", 200, |g| {
//!     let n = g.usize(1, 64);
//!     ...;
//!     prop_assert!(cond, "message");
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

pub type PropResult = Result<(), String>;

/// Generation context handed to each property run.
pub struct Gen {
    rng: Rng,
    /// Size hint in [0, 1]; shrinking retries with smaller hints so ranges
    /// collapse toward their lower bounds.
    pub size: f64,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Gen {
            rng: Rng::new(seed),
            size,
            seed,
        }
    }

    /// Integer in [lo, hi], biased toward lo as `size` shrinks.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        self.rng.range_usize(lo, lo + span)
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        let span = ((hi - lo) as f64 * self.size).round() as u64;
        self.rng.range(lo, lo + span)
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, lo + (hi - lo) * self.size)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        self.rng.shuffle(xs)
    }

    /// Raw unbiased range (ignores the size hint).
    pub fn raw_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_usize(lo, hi)
    }
}

#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

/// Run `prop` for `cases` random cases.  Panics (test failure) with the
/// seed + shrunken reproduction on the first violated property.
pub fn prop_check(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> PropResult) {
    // Env-derived base seed keeps CI deterministic but overridable.
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xF1E57u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9e3779b97f4a7c15));
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // Shrink: retry the same seed with smaller size hints and report
            // the smallest size that still fails.
            let mut smallest = (1.0, msg.clone());
            for step in 1..=8 {
                let size = 1.0 - step as f64 / 8.0;
                let mut g = Gen::new(seed, size.max(0.01));
                if let Err(m) = prop(&mut g) {
                    smallest = (size, m);
                }
            }
            panic!(
                "property '{name}' failed (seed={seed}, case={case}, min size={:.2}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        prop_check("sum is commutative", 50, |g| {
            let a = g.u64(0, 1000);
            let b = g.u64(0, 1000);
            prop_assert!(a + b == b + a, "a={a} b={b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        prop_check("always fails", 10, |g| {
            let x = g.usize(0, 10);
            prop_assert!(x > 100, "x={x}");
            Ok(())
        });
    }

    #[test]
    fn gen_respects_bounds() {
        let mut g = Gen::new(1, 1.0);
        for _ in 0..1000 {
            let x = g.usize(2, 9);
            assert!((2..=9).contains(&x));
        }
    }

    #[test]
    fn shrunk_gen_collapses_to_lower_bound() {
        let mut g = Gen::new(1, 0.0);
        for _ in 0..100 {
            assert_eq!(g.usize(5, 500), 5);
        }
    }
}
