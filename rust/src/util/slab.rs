//! Generational dense slab: O(1) insert/lookup/remove over a `Vec`, with
//! handles that detect reuse.
//!
//! This is the request-state substrate for the serving hot path (ISSUE 3):
//! the coordinator's `Active` table and the KV adaptor's per-request state
//! used to live in `BTreeMap<u64, _>`, which put an O(log n) pointer-chase
//! on every `slot()` / `table_row_ref()` / `advance_*` call.  A slab handle
//! is resolved once at admission and is a plain array index afterwards.
//!
//! Handles are *generational*: removing an entry bumps the slot's
//! generation, so a stale handle held by some queue or group list resolves
//! to `None` instead of silently aliasing an unrelated request that reused
//! the slot.  That property is load-bearing — e.g. a soft-preempted
//! speculative request can finish (and be removed) while its handle is
//! still parked in a group's `tp_pending` list.
//!
//! Free slots are recycled LIFO, so a serving steady state with bounded
//! concurrency reaches a fixed footprint and inserts stop allocating.

/// Copyable, comparable handle into a [`Slab`].  `idx` is the dense slot
/// index; `gen` must match the slot's current generation for the handle to
/// resolve.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SlabHandle {
    idx: u32,
    gen: u32,
}

impl SlabHandle {
    /// Dense index — stable for the entry's lifetime.  Exposed so callers
    /// can maintain parallel per-entry arrays; resolving data through the
    /// slab itself (generation-checked) is the safe default.
    pub fn index(&self) -> usize {
        self.idx as usize
    }

    /// A handle that never resolves (useful as an initializer).
    pub fn dangling() -> Self {
        SlabHandle { idx: u32::MAX, gen: u32::MAX }
    }
}

struct Slot<T> {
    gen: u32,
    val: Option<T>,
}

/// Dense generational slab.  All operations are O(1); iteration is O(cap).
pub struct Slab<T> {
    slots: Vec<Slot<T>>,
    free: Vec<u32>,
    len: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Slab::new()
    }
}

impl<T> Slab<T> {
    pub fn new() -> Self {
        Slab { slots: Vec::new(), free: Vec::new(), len: 0 }
    }

    pub fn with_capacity(cap: usize) -> Self {
        Slab {
            slots: Vec::with_capacity(cap),
            free: Vec::with_capacity(cap),
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total slots ever allocated (live + free).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn insert(&mut self, val: T) -> SlabHandle {
        self.len += 1;
        if let Some(idx) = self.free.pop() {
            let slot = &mut self.slots[idx as usize];
            debug_assert!(slot.val.is_none());
            slot.val = Some(val);
            SlabHandle { idx, gen: slot.gen }
        } else {
            let idx = self.slots.len() as u32;
            assert!(idx < u32::MAX, "slab exhausted");
            self.slots.push(Slot { gen: 0, val: Some(val) });
            SlabHandle { idx, gen: 0 }
        }
    }

    #[inline]
    pub fn get(&self, h: SlabHandle) -> Option<&T> {
        match self.slots.get(h.idx as usize) {
            Some(s) if s.gen == h.gen => s.val.as_ref(),
            _ => None,
        }
    }

    #[inline]
    pub fn get_mut(&mut self, h: SlabHandle) -> Option<&mut T> {
        match self.slots.get_mut(h.idx as usize) {
            Some(s) if s.gen == h.gen => s.val.as_mut(),
            _ => None,
        }
    }

    pub fn contains(&self, h: SlabHandle) -> bool {
        self.get(h).is_some()
    }

    /// Remove the entry, invalidating `h` (and every copy of it).
    pub fn remove(&mut self, h: SlabHandle) -> Option<T> {
        let slot = self.slots.get_mut(h.idx as usize)?;
        if slot.gen != h.gen || slot.val.is_none() {
            return None;
        }
        let val = slot.val.take();
        // Bump the generation *at removal* so every outstanding copy of the
        // handle goes stale immediately, whether or not the slot is reused.
        slot.gen = slot.gen.wrapping_add(1);
        self.free.push(h.idx);
        self.len -= 1;
        val
    }

    /// Live entries, in slot order (not insertion order).
    pub fn iter(&self) -> impl Iterator<Item = (SlabHandle, &T)> {
        self.slots.iter().enumerate().filter_map(|(i, s)| {
            s.val
                .as_ref()
                .map(|v| (SlabHandle { idx: i as u32, gen: s.gen }, v))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use std::collections::BTreeMap;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut s: Slab<&'static str> = Slab::new();
        let a = s.insert("a");
        let b = s.insert("b");
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a), Some(&"a"));
        assert_eq!(s.get(b), Some(&"b"));
        assert_eq!(s.remove(a), Some("a"));
        assert_eq!(s.get(a), None);
        assert_eq!(s.len(), 1);
        // Double remove is a no-op.
        assert_eq!(s.remove(a), None);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn stale_handle_never_aliases_reused_slot() {
        let mut s: Slab<u32> = Slab::new();
        let a = s.insert(1);
        s.remove(a);
        let b = s.insert(2); // reuses slot 0 (LIFO free list)
        assert_eq!(b.index(), a.index());
        assert_eq!(s.get(a), None, "stale handle must not see the new entry");
        assert_eq!(s.get(b), Some(&2));
    }

    #[test]
    fn steady_state_reuses_slots() {
        let mut s: Slab<u64> = Slab::new();
        let mut hs: Vec<SlabHandle> = (0..8).map(|i| s.insert(i)).collect();
        for round in 0..100u64 {
            let h = hs.remove(0);
            s.remove(h);
            hs.push(s.insert(round));
        }
        assert_eq!(s.len(), 8);
        assert!(s.capacity() <= 9, "cap={} grew past working set", s.capacity());
    }

    #[test]
    fn dangling_never_resolves() {
        let mut s: Slab<u8> = Slab::new();
        s.insert(1);
        assert_eq!(s.get(SlabHandle::dangling()), None);
        assert!(!s.contains(SlabHandle::dangling()));
    }

    #[test]
    fn iter_yields_live_entries() {
        let mut s: Slab<u8> = Slab::new();
        let a = s.insert(10);
        let _b = s.insert(20);
        s.remove(a);
        let got: Vec<u8> = s.iter().map(|(_, &v)| v).collect();
        assert_eq!(got, vec![20]);
    }

    #[test]
    fn prop_slab_matches_btreemap_model() {
        // Random op sequence against a BTreeMap oracle keyed by an
        // ever-increasing id; handles map ids 1:1.
        prop_check("slab ≡ map model", 100, |g| {
            let mut slab: Slab<u64> = Slab::new();
            let mut model: BTreeMap<u64, (SlabHandle, u64)> = BTreeMap::new();
            let mut retired: Vec<SlabHandle> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize(1, 120) {
                match g.usize(0, 2) {
                    0 => {
                        next_id += 1;
                        let h = slab.insert(next_id * 1000);
                        model.insert(next_id, (h, next_id * 1000));
                    }
                    1 if !model.is_empty() => {
                        let keys: Vec<u64> = model.keys().copied().collect();
                        let k = *g.choose(&keys);
                        let (h, v) = model.remove(&k).unwrap();
                        crate::prop_assert!(
                            slab.remove(h) == Some(v),
                            "remove({k}) mismatched"
                        );
                        retired.push(h);
                    }
                    _ => {}
                }
                crate::prop_assert!(slab.len() == model.len(), "len mismatch");
                for (k, &(h, v)) in &model {
                    crate::prop_assert!(
                        slab.get(h) == Some(&v),
                        "live handle for {k} lost"
                    );
                }
                for &h in &retired {
                    crate::prop_assert!(slab.get(h).is_none(), "stale handle resolved");
                }
            }
            Ok(())
        });
    }
}
