//! Streaming statistics: percentile sketches and time series used by the
//! metrics layer and the benchmark harness.

/// Exact percentile estimator over a bounded sample (serving traces here are
/// at most a few hundred thousand points, so exact is affordable and removes
/// sketch-error caveats from paper-comparison tables).
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    samples: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.total_cmp(b));
            self.sorted = true;
        }
    }

    /// Linear-interpolated quantile, q in [0, 1].
    pub fn quantile(&mut self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        self.ensure_sorted();
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let n = self.samples.len();
        if n == 1 {
            return self.samples[0];
        }
        let pos = q * (n - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p90(&mut self) -> f64 {
        self.quantile(0.90)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.samples.last().copied().unwrap_or(f64::NAN)
    }
}

/// Fixed-interval time series: values bucketed by timestamp, used for the
/// Fig-8 style "metric over trace time" plots.
#[derive(Clone, Debug)]
pub struct TimeSeries {
    pub interval: f64,
    buckets: Vec<Vec<f64>>,
}

impl TimeSeries {
    pub fn new(interval: f64) -> Self {
        assert!(interval > 0.0);
        TimeSeries {
            interval,
            buckets: Vec::new(),
        }
    }

    pub fn add(&mut self, t: f64, value: f64) {
        let idx = (t / self.interval).floor().max(0.0) as usize;
        if idx >= self.buckets.len() {
            self.buckets.resize_with(idx + 1, Vec::new);
        }
        self.buckets[idx].push(value);
    }

    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// (bucket_start_time, mean) rows, NaN for empty buckets.
    pub fn means(&self) -> Vec<(f64, f64)> {
        self.row(|xs| xs.iter().sum::<f64>() / xs.len() as f64)
    }

    /// (bucket_start_time, p90) rows.
    pub fn p90s(&self) -> Vec<(f64, f64)> {
        self.row(|xs| {
            let mut p = Percentiles::new();
            xs.iter().for_each(|&x| p.add(x));
            p.p90()
        })
    }

    /// (bucket_start_time, count) rows.
    pub fn counts(&self) -> Vec<(f64, f64)> {
        self.row(|xs| xs.len() as f64)
    }

    /// (bucket_start_time, sum) rows.
    pub fn sums(&self) -> Vec<(f64, f64)> {
        self.row(|xs| xs.iter().sum())
    }

    fn row(&self, f: impl Fn(&[f64]) -> f64) -> Vec<(f64, f64)> {
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, xs)| {
                let t = i as f64 * self.interval;
                if xs.is_empty() {
                    (t, f64::NAN)
                } else {
                    (t, f(xs))
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_of_known_sequence() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.add(i as f64);
        }
        assert!((p.p50() - 50.5).abs() < 1e-9);
        assert!((p.quantile(0.0) - 1.0).abs() < 1e-9);
        assert!((p.quantile(1.0) - 100.0).abs() < 1e-9);
        assert!((p.p90() - 90.1).abs() < 1e-9);
    }

    #[test]
    fn single_sample_all_quantiles() {
        let mut p = Percentiles::new();
        p.add(3.5);
        assert_eq!(p.p50(), 3.5);
        assert_eq!(p.p99(), 3.5);
        assert_eq!(p.mean(), 3.5);
    }

    #[test]
    fn empty_is_nan() {
        let mut p = Percentiles::new();
        assert!(p.p50().is_nan());
        assert!(p.mean().is_nan());
    }

    #[test]
    fn add_after_query_resorts() {
        let mut p = Percentiles::new();
        p.add(10.0);
        assert_eq!(p.p50(), 10.0);
        p.add(0.0);
        assert_eq!(p.quantile(0.0), 0.0);
    }

    #[test]
    fn timeseries_bucketing() {
        let mut ts = TimeSeries::new(1.0);
        ts.add(0.1, 1.0);
        ts.add(0.9, 3.0);
        ts.add(2.5, 10.0);
        let m = ts.means();
        assert_eq!(m.len(), 3);
        assert!((m[0].1 - 2.0).abs() < 1e-9);
        assert!(m[1].1.is_nan());
        assert!((m[2].1 - 10.0).abs() < 1e-9);
        assert_eq!(ts.counts()[0].1, 2.0);
    }
}
