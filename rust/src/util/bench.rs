//! Minimal benchmark harness (criterion is not in the offline crate set).
//!
//! Provides warmup + repeated timed runs with mean/p50/p90 reporting, plus a
//! paper-style table printer and CSV writer used by every `rust/benches/*`
//! target to regenerate the paper's tables and figures.

use std::io::Write;
use std::time::Instant;

use super::stats::Percentiles;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p90_s: f64,
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut p = Percentiles::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        p.add(t0.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: p.mean(),
        p50_s: p.p50(),
        p90_s: p.p90(),
    };
    println!(
        "bench {:40} iters={:5} mean={} p50={} p90={}",
        r.name,
        r.iters,
        fmt_dur(r.mean_s),
        fmt_dur(r.p50_s),
        fmt_dur(r.p90_s)
    );
    r
}

pub fn fmt_dur(s: f64) -> String {
    if s.is_nan() {
        "   n/a  ".into()
    } else if s < 1e-6 {
        format!("{:7.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:7.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:7.2}ms", s * 1e3)
    } else {
        format!("{:7.2}s ", s)
    }
}

/// Paper-style table: header row + aligned data rows, also echoed to a CSV
/// in `bench_out/` so figures can be re-plotted.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        println!("\n=== {} ===", self.title);
        let widths: Vec<usize> = self
            .header
            .iter()
            .enumerate()
            .map(|(i, h)| {
                self.rows
                    .iter()
                    .map(|r| r[i].len())
                    .chain([h.len()])
                    .max()
                    .unwrap_or(0)
            })
            .collect();
        let line = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{:>w$}", c, w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.header));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for r in &self.rows {
            println!("{}", line(r));
        }
    }

    /// Write `bench_out/<slug>.csv`; returns the path.
    pub fn write_csv(&self, slug: &str) -> std::io::Result<String> {
        std::fs::create_dir_all("bench_out")?;
        let path = format!("bench_out/{slug}.csv");
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", self.header.join(","))?;
        for r in &self.rows {
            writeln!(f, "{}", r.join(","))?;
        }
        Ok(path)
    }
}

/// Write raw (t, value) series per system for figure regeneration.
pub fn write_series_csv(
    slug: &str,
    columns: &[(&str, &[(f64, f64)])],
) -> std::io::Result<String> {
    std::fs::create_dir_all("bench_out")?;
    let path = format!("bench_out/{slug}.csv");
    let mut f = std::fs::File::create(&path)?;
    let header: Vec<String> = std::iter::once("t".to_string())
        .chain(columns.iter().map(|(n, _)| n.to_string()))
        .collect();
    writeln!(f, "{}", header.join(","))?;
    let n = columns.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
    for i in 0..n {
        let t = columns
            .iter()
            .find_map(|(_, s)| s.get(i).map(|&(t, _)| t))
            .unwrap_or(f64::NAN);
        let mut row = vec![format!("{t:.3}")];
        for (_, s) in columns {
            row.push(
                s.get(i)
                    .map(|&(_, v)| format!("{v:.6}"))
                    .unwrap_or_default(),
            );
        }
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut n = 0u64;
        let r = bench("noop", 2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(r.iters, 10);
        assert!(r.mean_s >= 0.0);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print();
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(2.5e-9).contains("ns"));
        assert!(fmt_dur(2.5e-6).contains("µs"));
        assert!(fmt_dur(2.5e-3).contains("ms"));
        assert!(fmt_dur(2.5).contains('s'));
    }
}
