//! Deterministic PRNG (SplitMix64 seeding a xoshiro256**).
//!
//! The offline crate set has no `rand`; this is the standard public-domain
//! generator pair, sufficient for workload synthesis and property tests.
//! Determinism matters: every benchmark and every property-test failure is
//! reproducible from its seed.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        lo + self.next_u64() % (hi - lo + 1)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Uniform float in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Exponential with the given rate (used for Poisson arrival gaps).
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / rate
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i);
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(4);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let x = r.range(3, 6);
            assert!((3..=6).contains(&x));
            seen_lo |= x == 3;
            seen_hi |= x == 6;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn exp_mean_close_to_inverse_rate() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
