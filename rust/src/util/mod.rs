//! Small std-only substrates: PRNG, statistics, property-test and benchmark
//! harnesses, and a stderr logger.  These exist because the offline crate
//! set contains no rand/criterion/proptest (see Cargo.toml note).

pub mod bench;
pub mod prop;
pub mod rng;
pub mod slab;
pub mod stats;

use std::sync::atomic::{AtomicU8, Ordering};

static LOG_LEVEL: AtomicU8 = AtomicU8::new(2); // 0=off 1=error 2=info 3=debug

pub fn set_log_level(level: u8) {
    LOG_LEVEL.store(level, Ordering::Relaxed);
}

pub fn log_enabled(level: u8) -> bool {
    LOG_LEVEL.load(Ordering::Relaxed) >= level
}

#[macro_export]
macro_rules! info {
    ($($fmt:tt)*) => {
        if $crate::util::log_enabled(2) {
            eprintln!("[info] {}", format!($($fmt)*));
        }
    };
}

#[macro_export]
macro_rules! debug {
    ($($fmt:tt)*) => {
        if $crate::util::log_enabled(3) {
            eprintln!("[debug] {}", format!($($fmt)*));
        }
    };
}
