//! Model description + weight store: the Rust mirror of
//! `python/compile/configs.py`, loaded from `artifacts/manifest.json`, plus
//! the Model Weights Manager's host-side state (weights loaded exactly once
//! per engine; TP sharding never moves them — the shard *view* is activated
//! inside the AOT kernels via the `rank` argument).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::json::Value;

/// Static serving shapes shared by all artifacts (mirrors configs.py).
#[derive(Clone, Copy, Debug)]
pub struct StaticShapes {
    pub b_dec: usize,
    pub c_prefill: usize,
}

/// Architecture description (mirror of python ModelCfg).
#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub ffn_hidden: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub n_blocks: usize,
    pub block_base: usize,
    pub max_ctx: usize,
    pub vocab: usize,
    pub pool_elems: usize,
}

impl ModelCfg {
    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(ModelCfg {
            name: v.str_field("name")?.to_string(),
            d_model: v.usize_field("d_model")?,
            n_layers: v.usize_field("n_layers")?,
            n_heads: v.usize_field("n_heads")?,
            n_kv_heads: v.usize_field("n_kv_heads")?,
            d_head: v.usize_field("d_head")?,
            ffn_hidden: v.usize_field("ffn_hidden")?,
            n_experts: v.usize_field("n_experts")?,
            top_k: v.usize_field("top_k")?,
            n_blocks: v.usize_field("n_blocks")?,
            block_base: v.usize_field("block_base")?,
            max_ctx: v.usize_field("max_ctx")?,
            vocab: v.usize_field("vocab")?,
            pool_elems: v.usize_field("pool_elems")?,
        })
    }

    /// Token capacity per block under TP degree p: B(p) = p * B_base
    /// (paper Eq. 3).
    pub fn block_tokens(&self, p: usize) -> usize {
        p * self.block_base
    }

    /// Per-device KV width under degree p: D_local(p) (paper §4.2.1).
    pub fn kv_width(&self, p: usize) -> usize {
        (self.n_kv_heads / p) * self.d_head
    }

    /// Bytes of one physical KV block — invariant across modes (Eq. 2).
    pub fn block_bytes(&self, p: usize) -> usize {
        self.block_tokens(p) * self.kv_width(p) * 4
    }

    /// Max tokens a single request can cache on one DP engine.
    pub fn dp_token_capacity(&self) -> usize {
        // Block 0 is the reserved trash block.
        (self.n_blocks - 1) * self.block_base
    }

    /// Max tokens for one request on a p-way TP group (Use Case 3).
    pub fn tp_token_capacity(&self, p: usize) -> usize {
        (self.n_blocks - 1) * self.block_tokens(p)
    }

    pub fn supports_tp(&self, p: usize) -> bool {
        p > 0 && self.n_heads % p == 0 && self.n_kv_heads % p == 0
    }
}

/// One tensor entry in the weights bin.
#[derive(Clone, Debug)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_elems: usize,
    pub n_elems: usize,
}

/// Host-resident weights for one model, loaded exactly once.  Engines share
/// this immutably (`Arc<WeightStore>`); per-engine device buffers are
/// uploaded from it at engine startup and never touched again — mode
/// switches only change the `rank` scalar handed to the kernels.
pub struct WeightStore {
    pub cfg: ModelCfg,
    pub entries: Vec<WeightEntry>,
    data: Vec<f32>,
    index: BTreeMap<String, usize>,
}

impl WeightStore {
    pub fn load(cfg: ModelCfg, entries: Vec<WeightEntry>, bin_path: &Path) -> Result<Self> {
        let bytes = std::fs::read(bin_path)
            .with_context(|| format!("reading weights bin {}", bin_path.display()))?;
        if bytes.len() % 4 != 0 {
            bail!("weights bin not a multiple of 4 bytes");
        }
        let mut data = vec![0f32; bytes.len() / 4];
        for (i, ch) in bytes.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
        }
        let total: usize = entries.iter().map(|e| e.n_elems).sum();
        if total != data.len() {
            bail!("weights bin size {} != manifest total {}", data.len(), total);
        }
        let index = entries
            .iter()
            .enumerate()
            .map(|(i, e)| (e.name.clone(), i))
            .collect();
        Ok(WeightStore {
            cfg,
            entries,
            data,
            index,
        })
    }

    pub fn tensor(&self, name: &str) -> Result<&[f32]> {
        let &i = self
            .index
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown weight tensor '{name}'"))?;
        let e = &self.entries[i];
        Ok(&self.data[e.offset_elems..e.offset_elems + e.n_elems])
    }

    pub fn shape(&self, name: &str) -> Result<&[usize]> {
        let &i = self
            .index
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown weight tensor '{name}'"))?;
        Ok(&self.entries[i].shape)
    }

    /// Embedding-row gather — done host-side for the TP path (the fused DP
    /// artifacts embed in-kernel).
    pub fn embed(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let emb = self.tensor("emb")?;
        let d = self.cfg.d_model;
        let mut out = vec![0f32; tokens.len() * d];
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            if t >= self.cfg.vocab {
                bail!("token id {t} out of vocab {}", self.cfg.vocab);
            }
            out[i * d..(i + 1) * d].copy_from_slice(&emb[t * d..(t + 1) * d]);
        }
        Ok(out)
    }

    pub fn total_param_count(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            d_model: 32,
            n_layers: 2,
            n_heads: 8,
            n_kv_heads: 4,
            d_head: 8,
            ffn_hidden: 48,
            n_experts: 0,
            top_k: 0,
            n_blocks: 64,
            block_base: 4,
            max_ctx: 1024,
            vocab: 258,
            pool_elems: 64 * 4 * 4 * 8,
        }
    }

    #[test]
    fn block_bytes_invariant_across_modes() {
        let c = test_cfg();
        let b1 = c.block_bytes(1);
        for p in [2, 4] {
            assert_eq!(c.block_bytes(p), b1, "paper Eq. 2 violated at p={p}");
        }
    }

    #[test]
    fn capacity_scales_with_tp_degree() {
        let c = test_cfg();
        assert_eq!(c.tp_token_capacity(2), 2 * c.dp_token_capacity());
        assert_eq!(c.tp_token_capacity(4), 4 * c.dp_token_capacity());
    }

    #[test]
    fn supports_tp_respects_head_divisibility() {
        let c = test_cfg();
        assert!(c.supports_tp(1) && c.supports_tp(2) && c.supports_tp(4));
        assert!(!c.supports_tp(3));
        assert!(!c.supports_tp(8)); // only 4 kv heads
        assert!(!c.supports_tp(0));
    }

    #[test]
    fn weight_store_load_and_gather() {
        let c = test_cfg();
        let entries = vec![
            WeightEntry {
                name: "emb".into(),
                shape: vec![c.vocab, c.d_model],
                offset_elems: 0,
                n_elems: c.vocab * c.d_model,
            },
            WeightEntry {
                name: "final_norm".into(),
                shape: vec![c.d_model],
                offset_elems: c.vocab * c.d_model,
                n_elems: c.d_model,
            },
        ];
        let total = entries.iter().map(|e| e.n_elems).sum::<usize>();
        let data: Vec<f32> = (0..total).map(|i| i as f32).collect();
        let dir = std::env::temp_dir().join("fs_ws_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();

        let ws = WeightStore::load(c.clone(), entries, &path).unwrap();
        assert_eq!(ws.tensor("final_norm").unwrap()[0], (c.vocab * c.d_model) as f32);
        let e = ws.embed(&[2, 0]).unwrap();
        assert_eq!(e[0], (2 * c.d_model) as f32);
        assert_eq!(e[c.d_model], 0.0);
        assert!(ws.tensor("nope").is_err());
        assert!(ws.embed(&[999]).is_err());
    }
}
