//! # FLYING SERVING — on-the-fly DP↔TP parallelism switching for LLM serving
//!
//! Reproduction of "FLYING SERVING: On-the-Fly Parallelism Switching for
//! Large Language Model Serving" (Gao et al., CS.DC 2026) as a three-layer
//! Rust + JAX + Pallas stack (see DESIGN.md):
//!
//! * **L3 (this crate)** — the coordinator: global task pool, Algorithm-1
//!   dynamic scheduler with Sequential / Soft-Preempt / Hard-Preempt
//!   switching, the KV Cache Adaptor, the Communicator Pool, engine workers
//!   over PJRT, a TCP serving frontend, a discrete-event cluster simulator,
//!   and the static-DP / static-TP / Shift-Parallelism baselines.
//! * **L2** — `python/compile/model.py`: rank-parameterized sharded
//!   transformer forward, AOT-lowered to HLO text per (model, phase, TP).
//! * **L1** — `python/compile/kernels/`: Pallas paged-attention decode and
//!   shard-view matmul (the zero-copy Model Weights Manager at kernel
//!   level), verified against a pure-jnp oracle.
//!
//! Python never runs at serving time: `make artifacts` emits
//! `artifacts/*.hlo.txt` + weights + manifest once, and the Rust binary is
//! self-contained afterwards.

pub mod baselines;
pub mod comm;
pub mod config;
pub mod control;
pub mod coordinator;
pub mod engine;
pub mod error;
pub mod json;
pub mod kv;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod sched;
pub mod server;
pub mod sim;
pub mod util;
pub mod workload;
