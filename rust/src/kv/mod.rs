//! KV Cache Adaptor (paper §4.2): a single physical block pool whose blocks
//! never move or resize, plus a logical table that re-interprets block
//! *token capacity* per parallelism mode:
//!
//!   M_block = B * D_local * P_size  is held constant          (Eq. 2)
//!   B(p)    = p * B_base                                      (Eq. 3)
//!
//! Mode transitions are therefore constant-time metadata updates; KV bytes
//! are never migrated.  Requests carry a *layout tag* (the TP degree their
//! KV was written under), which is what lets DP-layout and TP-layout blocks
//! coexist in one pool — the enabler for Hard Preempt (§5.2.3).
//!
//! The adaptor manages metadata only; the actual pool contents live in
//! device-resident PJRT buffers owned by the engines.  `slot()` is the
//! "stride and capacity" information the worker hands the attention kernel
//! (§4.2.3) — here surfaced as flat slot ids and padded block-table rows.

use anyhow::{bail, Result};

use crate::model::ModelCfg;

/// Reserved physical block: padded batch slots write their (masked) tokens
/// here so kernels need no conditionals.  Never allocated to a request.
pub const TRASH_BLOCK: u32 = 0;

#[derive(Clone, Debug)]
pub struct RequestKv {
    pub layout_p: usize,  // TP degree the KV bytes were written under
    pub blocks: Vec<u32>, // physical block ids, logical order
    pub seq_len: usize,   // tokens currently cached
    pub paused: bool,     // hard-preempted (KV stays resident)
    /// Cached kernel-facing block-table row, padded to `n_blocks` with
    /// `TRASH_BLOCK`.  Maintained incrementally by `ensure_capacity` /
    /// `relayout_for_recompute` so the serving hot path never rebuilds it.
    row: Vec<i32>,
}

/// Pool + logical-table state for one engine (DP mode) or one TP group
/// (members share identical block ids; each stores its own head slice, so
/// one adaptor instance describes all of them).
pub struct KvCacheAdaptor {
    cfg: ModelCfg,
    free: Vec<u32>, // LIFO free list of physical block ids
    requests: std::collections::BTreeMap<u64, RequestKv>,
}

impl KvCacheAdaptor {
    pub fn new(cfg: ModelCfg) -> Self {
        // Block 0 reserved; free list LIFO over the rest.
        let free = (1..cfg.n_blocks as u32).rev().collect();
        KvCacheAdaptor {
            cfg,
            free,
            requests: Default::default(),
        }
    }

    pub fn cfg(&self) -> &ModelCfg {
        &self.cfg
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        (self.cfg.n_blocks - 1) - self.free.len()
    }

    pub fn request(&self, rid: u64) -> Option<&RequestKv> {
        self.requests.get(&rid)
    }

    pub fn active_requests(&self) -> impl Iterator<Item = (&u64, &RequestKv)> {
        self.requests.iter()
    }

    /// Register a request under layout `p` (no blocks yet).
    pub fn register(&mut self, rid: u64, p: usize) -> Result<()> {
        if !self.cfg.supports_tp(p) {
            bail!("unsupported TP degree {p}");
        }
        if self.requests.contains_key(&rid) {
            bail!("request {rid} already registered");
        }
        self.requests.insert(
            rid,
            RequestKv {
                layout_p: p,
                blocks: Vec::new(),
                seq_len: 0,
                paused: false,
                row: vec![TRASH_BLOCK as i32; self.cfg.n_blocks],
            },
        );
        Ok(())
    }

    /// Grow `rid`'s block list so it can hold `n_tokens` under its layout.
    /// Fails (leaving state unchanged) if the pool can't supply the blocks —
    /// the scheduler's OOM signal for Use Case 3 routing.
    pub fn ensure_capacity(&mut self, rid: u64, n_tokens: usize) -> Result<()> {
        let req = match self.requests.get(&rid) {
            Some(r) => r,
            None => bail!("request {rid} not registered"),
        };
        let bt = self.cfg.block_tokens(req.layout_p);
        let need = n_tokens.div_ceil(bt);
        if need > self.cfg.n_blocks - 1 {
            bail!(
                "request {rid} needs {need} blocks > pool capacity {} (max ctx at p={} is {})",
                self.cfg.n_blocks - 1,
                req.layout_p,
                self.cfg.tp_token_capacity(req.layout_p)
            );
        }
        let have = req.blocks.len();
        if need > have {
            let short = need - have;
            if short > self.free.len() {
                bail!(
                    "kv pool exhausted: request {rid} short {short} blocks, {} free",
                    self.free.len()
                );
            }
            let req = self.requests.get_mut(&rid).unwrap();
            for _ in 0..short {
                let b = self.free.pop().unwrap();
                // Incremental row maintenance: only the newly-granted
                // positions are touched.
                req.row[req.blocks.len()] = b as i32;
                req.blocks.push(b);
            }
        }
        Ok(())
    }

    /// Record that `rid` now caches `seq_len` tokens (post-append).
    pub fn set_seq_len(&mut self, rid: u64, seq_len: usize) -> Result<()> {
        let req = self
            .requests
            .get_mut(&rid)
            .ok_or_else(|| anyhow::anyhow!("request {rid} not registered"))?;
        let bt = self.cfg.block_tokens(req.layout_p);
        if seq_len.div_ceil(bt) > req.blocks.len() {
            bail!("seq_len {seq_len} exceeds allocated capacity");
        }
        req.seq_len = seq_len;
        Ok(())
    }

    /// Flat slot id for token position `pos` of `rid` — the kernel-facing
    /// "stride and capacity" mapping (§4.2.3).
    pub fn slot(&self, rid: u64, pos: usize) -> Result<u32> {
        let req = self
            .requests
            .get(&rid)
            .ok_or_else(|| anyhow::anyhow!("request {rid} not registered"))?;
        let bt = self.cfg.block_tokens(req.layout_p);
        let blk = *req
            .blocks
            .get(pos / bt)
            .ok_or_else(|| anyhow::anyhow!("position {pos} beyond allocated blocks"))?;
        Ok(blk * bt as u32 + (pos % bt) as u32)
    }

    /// Borrowed view of the block-table row, padded to the static artifact
    /// width (n_blocks).  This is the hot-path accessor: the row is cached
    /// and maintained incrementally, so this is a pointer handoff — callers
    /// copy it straight into their step buffers without any rebuild.
    pub fn table_row_ref(&self, rid: u64) -> Result<&[i32]> {
        self.requests
            .get(&rid)
            .map(|req| req.row.as_slice())
            .ok_or_else(|| anyhow::anyhow!("request {rid} not registered"))
    }

    /// Block-table row padded to the static artifact width (n_blocks).
    /// Allocating convenience form of [`Self::table_row_ref`].
    pub fn table_row(&self, rid: u64) -> Result<Vec<i32>> {
        Ok(self.table_row_ref(rid)?.to_vec())
    }

    /// Hard Preempt: pause a request in place.  Its blocks stay resident
    /// under their original layout tag; O(1), no data movement (§5.2.3).
    pub fn pause(&mut self, rid: u64) -> Result<()> {
        self.requests
            .get_mut(&rid)
            .map(|r| r.paused = true)
            .ok_or_else(|| anyhow::anyhow!("request {rid} not registered"))
    }

    pub fn resume(&mut self, rid: u64) -> Result<()> {
        self.requests
            .get_mut(&rid)
            .map(|r| r.paused = false)
            .ok_or_else(|| anyhow::anyhow!("request {rid} not registered"))
    }

    /// Soft Preempt bind: the request's speculative DP-layout KV is
    /// incompatible with the target TP layout; drop its blocks and re-tag so
    /// prefill re-runs under the new layout (§5.2.2).  Returns the number of
    /// tokens that must be recomputed.
    pub fn relayout_for_recompute(&mut self, rid: u64, new_p: usize) -> Result<usize> {
        if !self.cfg.supports_tp(new_p) {
            bail!("unsupported TP degree {new_p}");
        }
        let req = self
            .requests
            .get_mut(&rid)
            .ok_or_else(|| anyhow::anyhow!("request {rid} not registered"))?;
        let recompute = req.seq_len;
        let blocks = std::mem::take(&mut req.blocks);
        req.seq_len = 0;
        req.layout_p = new_p;
        req.row.fill(TRASH_BLOCK as i32);
        self.free.extend(blocks.into_iter().rev());
        Ok(recompute)
    }

    /// Finish/abort a request: return its blocks to the pool.
    pub fn release(&mut self, rid: u64) -> Result<()> {
        let req = self
            .requests
            .remove(&rid)
            .ok_or_else(|| anyhow::anyhow!("request {rid} not registered"))?;
        self.free.extend(req.blocks.into_iter().rev());
        Ok(())
    }

    /// The mode-switch primitive measured in Table 2: binding/releasing a
    /// TP group changes no adaptor state at all — existing requests keep
    /// their layout tags, new requests are registered under the new degree.
    /// This method exists to document (and let benches measure) that the
    /// switch cost is O(1) metadata.
    pub fn switch_mode_metadata_cost(&self) -> usize {
        0 // no per-block work: the pool and ids are layout-invariant
    }

    /// Sanity invariant (checked in tests): every block is either free or
    /// owned by exactly one request, and block 0 is owned by nobody.
    pub fn check_invariants(&self) -> Result<()> {
        let mut seen = vec![0u8; self.cfg.n_blocks];
        seen[TRASH_BLOCK as usize] = 1;
        for &b in &self.free {
            if b == TRASH_BLOCK {
                bail!("trash block on free list");
            }
            if seen[b as usize] != 0 {
                bail!("block {b} double-tracked (free list)");
            }
            seen[b as usize] = 1;
        }
        for (rid, req) in &self.requests {
            let bt = self.cfg.block_tokens(req.layout_p);
            if req.seq_len > req.blocks.len() * bt {
                bail!("request {rid} seq_len beyond capacity");
            }
            for &b in &req.blocks {
                if b == TRASH_BLOCK {
                    bail!("request {rid} owns trash block");
                }
                if seen[b as usize] != 0 {
                    bail!("block {b} double-owned (request {rid})");
                }
                seen[b as usize] = 1;
            }
            // The incrementally-maintained row cache must agree with the
            // authoritative block list at all times.
            if req.row.len() != self.cfg.n_blocks {
                bail!("request {rid} row cache has wrong width");
            }
            for (i, &cell) in req.row.iter().enumerate() {
                let want = req.blocks.get(i).map(|&b| b as i32).unwrap_or(TRASH_BLOCK as i32);
                if cell != want {
                    bail!("request {rid} row cache stale at {i}: {cell} != {want}");
                }
            }
        }
        if seen.iter().any(|&s| s == 0) {
            bail!("leaked block (neither free nor owned)");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    fn cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            d_model: 32,
            n_layers: 2,
            n_heads: 8,
            n_kv_heads: 4,
            d_head: 8,
            ffn_hidden: 48,
            n_experts: 0,
            top_k: 0,
            n_blocks: 16,
            block_base: 4,
            max_ctx: 256,
            vocab: 258,
            pool_elems: 16 * 4 * 4 * 8,
        }
    }

    #[test]
    fn slot_mapping_dp() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.register(1, 1).unwrap();
        a.ensure_capacity(1, 9).unwrap(); // 3 blocks of 4 tokens
        let blocks = a.request(1).unwrap().blocks.clone();
        assert_eq!(blocks.len(), 3);
        assert_eq!(a.slot(1, 0).unwrap(), blocks[0] * 4);
        assert_eq!(a.slot(1, 5).unwrap(), blocks[1] * 4 + 1);
        assert_eq!(a.slot(1, 8).unwrap(), blocks[2] * 4);
        assert!(a.slot(1, 12).is_err());
    }

    #[test]
    fn slot_mapping_respects_layout() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.register(1, 2).unwrap(); // B(2) = 8 tokens per block
        a.ensure_capacity(1, 9).unwrap();
        assert_eq!(a.request(1).unwrap().blocks.len(), 2);
        let b = a.request(1).unwrap().blocks.clone();
        assert_eq!(a.slot(1, 7).unwrap(), b[0] * 8 + 7);
        assert_eq!(a.slot(1, 8).unwrap(), b[1] * 8);
    }

    #[test]
    fn oom_is_clean_and_state_preserving() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.register(1, 1).unwrap();
        // 15 usable blocks * 4 tokens = 60 tokens max.
        assert!(a.ensure_capacity(1, 60).is_ok());
        assert_eq!(a.free_blocks(), 0);
        a.register(2, 1).unwrap();
        assert!(a.ensure_capacity(2, 1).is_err());
        a.check_invariants().unwrap();
        a.release(1).unwrap();
        assert!(a.ensure_capacity(2, 1).is_ok());
        a.check_invariants().unwrap();
    }

    #[test]
    fn capacity_grows_with_layout_tp4() {
        let c = cfg();
        let mut a = KvCacheAdaptor::new(c.clone());
        a.register(1, 4).unwrap();
        // Under 4TP one request can cache 15 * 16 = 240 tokens.
        assert!(a.ensure_capacity(1, c.tp_token_capacity(4)).is_ok());
        assert!(a.ensure_capacity(1, c.tp_token_capacity(4) + 1).is_err());
    }

    #[test]
    fn hard_preempt_pause_keeps_blocks() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.register(1, 1).unwrap();
        a.ensure_capacity(1, 10).unwrap();
        a.set_seq_len(1, 10).unwrap();
        let before = a.request(1).unwrap().blocks.clone();
        a.pause(1).unwrap();
        // A TP request arrives and allocates from the same pool.
        a.register(2, 2).unwrap();
        a.ensure_capacity(2, 20).unwrap();
        assert_eq!(a.request(1).unwrap().blocks, before);
        assert_eq!(a.request(1).unwrap().seq_len, 10);
        a.resume(1).unwrap();
        assert!(!a.request(1).unwrap().paused);
        a.check_invariants().unwrap();
    }

    #[test]
    fn soft_preempt_relayout_frees_and_retags() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.register(1, 1).unwrap();
        a.ensure_capacity(1, 12).unwrap();
        a.set_seq_len(1, 12).unwrap();
        let free_before = a.free_blocks();
        let recompute = a.relayout_for_recompute(1, 4).unwrap();
        assert_eq!(recompute, 12);
        assert_eq!(a.request(1).unwrap().layout_p, 4);
        assert_eq!(a.request(1).unwrap().seq_len, 0);
        assert_eq!(a.free_blocks(), free_before + 3);
        a.check_invariants().unwrap();
    }

    #[test]
    fn table_row_pads_with_trash() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.register(1, 1).unwrap();
        a.ensure_capacity(1, 5).unwrap();
        let row = a.table_row(1).unwrap();
        assert_eq!(row.len(), cfg().n_blocks);
        assert!(row[2..].iter().all(|&b| b == TRASH_BLOCK as i32));
        assert!(row[0] != TRASH_BLOCK as i32 && row[1] != TRASH_BLOCK as i32);
    }

    #[test]
    fn table_row_ref_is_borrowed_and_incremental() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.register(1, 1).unwrap();
        a.ensure_capacity(1, 5).unwrap(); // 2 blocks
        let snapshot: Vec<i32> = a.table_row_ref(1).unwrap().to_vec();
        assert_eq!(snapshot, a.table_row(1).unwrap());
        // Growing must extend the cached row in place, not rebuild it.
        a.ensure_capacity(1, 13).unwrap(); // 4 blocks
        let row = a.table_row_ref(1).unwrap();
        assert_eq!(row.len(), cfg().n_blocks);
        assert_eq!(&row[..2], &snapshot[..2], "existing prefix must be stable");
        assert!(row[2] != TRASH_BLOCK as i32 && row[3] != TRASH_BLOCK as i32);
        assert!(row[4..].iter().all(|&b| b == TRASH_BLOCK as i32));
        a.check_invariants().unwrap();
    }

    #[test]
    fn relayout_resets_cached_row() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.register(1, 1).unwrap();
        a.ensure_capacity(1, 12).unwrap();
        a.set_seq_len(1, 12).unwrap();
        a.relayout_for_recompute(1, 2).unwrap();
        assert!(a
            .table_row_ref(1)
            .unwrap()
            .iter()
            .all(|&b| b == TRASH_BLOCK as i32));
        // Re-grow under the new layout repopulates from the front.
        a.ensure_capacity(1, 9).unwrap(); // 2 blocks of 8 under p=2
        let row = a.table_row_ref(1).unwrap();
        assert!(row[0] != TRASH_BLOCK as i32 && row[1] != TRASH_BLOCK as i32);
        assert!(row[2..].iter().all(|&b| b == TRASH_BLOCK as i32));
        a.check_invariants().unwrap();
    }

    #[test]
    fn mode_switch_is_metadata_only() {
        let a = KvCacheAdaptor::new(cfg());
        assert_eq!(a.switch_mode_metadata_cost(), 0);
    }

    #[test]
    fn prop_pool_never_double_allocates() {
        prop_check("kv pool exclusive ownership", 150, |g| {
            let c = cfg();
            let mut a = KvCacheAdaptor::new(c.clone());
            let mut live: Vec<u64> = Vec::new();
            let mut next_rid = 0u64;
            for _ in 0..g.usize(1, 60) {
                match g.usize(0, 3) {
                    0 => {
                        let p = *g.choose(&[1usize, 2, 4]);
                        next_rid += 1;
                        a.register(next_rid, p).map_err(|e| e.to_string())?;
                        live.push(next_rid);
                    }
                    1 if !live.is_empty() => {
                        let rid = *g.choose(&live);
                        let want = g.usize(0, 80);
                        let _ = a.ensure_capacity(rid, want); // OOM allowed
                    }
                    2 if !live.is_empty() => {
                        let i = g.raw_usize(0, live.len() - 1);
                        let rid = live.swap_remove(i);
                        a.release(rid).map_err(|e| e.to_string())?;
                    }
                    3 if !live.is_empty() => {
                        let rid = *g.choose(&live);
                        let p = *g.choose(&[1usize, 2, 4]);
                        let _ = a.relayout_for_recompute(rid, p);
                    }
                    _ => {}
                }
                a.check_invariants().map_err(|e| e.to_string())?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_slots_unique_within_request() {
        prop_check("slots unique per (rid,pos)", 60, |g| {
            let c = cfg();
            let mut a = KvCacheAdaptor::new(c.clone());
            let p = *g.choose(&[1usize, 2, 4]);
            a.register(1, p).map_err(|e| e.to_string())?;
            let n = g.usize(1, c.tp_token_capacity(p).min(100));
            a.ensure_capacity(1, n).map_err(|e| e.to_string())?;
            let mut seen = std::collections::BTreeSet::new();
            for pos in 0..n {
                let s = a.slot(1, pos).map_err(|e| e.to_string())?;
                crate::prop_assert!(seen.insert(s), "slot {s} repeated at pos {pos}");
                // Slot must lie inside the pool and outside the trash block.
                let bt = c.block_tokens(p) as u32;
                crate::prop_assert!(s >= bt, "slot {s} inside trash block");
                crate::prop_assert!(
                    (s as usize) < c.n_blocks * c.block_tokens(p),
                    "slot {s} out of pool"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_mixed_layouts_disjoint_physical_ranges() {
        // DP- and TP-layout requests in one pool must map to disjoint
        // physical byte ranges (Hard Preempt coexistence).
        prop_check("mixed layouts disjoint", 60, |g| {
            let c = cfg();
            let mut a = KvCacheAdaptor::new(c.clone());
            a.register(1, 1).map_err(|e| e.to_string())?;
            a.register(2, *g.choose(&[2usize, 4])).map_err(|e| e.to_string())?;
            let n1 = g.usize(1, 20);
            let n2 = g.usize(1, 20);
            a.ensure_capacity(1, n1).map_err(|e| e.to_string())?;
            a.ensure_capacity(2, n2).map_err(|e| e.to_string())?;
            // Physical range of a block is the same regardless of layout
            // (Eq. 2), so block-id disjointness == byte disjointness.
            let b1: std::collections::BTreeSet<u32> =
                a.request(1).unwrap().blocks.iter().copied().collect();
            let b2: std::collections::BTreeSet<u32> =
                a.request(2).unwrap().blocks.iter().copied().collect();
            crate::prop_assert!(b1.is_disjoint(&b2), "block overlap");
            Ok(())
        });
    }
}
